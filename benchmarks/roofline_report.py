"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_*.json
"""

from __future__ import annotations

import glob
import json
import sys


def load(paths: list[str]) -> list[dict]:
    # later files supersede earlier ones per (arch, shape, mesh) — re-run
    # sweeps (post-optimization) are named to sort after the originals
    by_key: dict[tuple, dict] = {}
    for p in paths:
        for r in json.load(open(p)):
            by_key[(r["arch"], r["shape"], r["mesh"])] = r
    rows = list(by_key.values())
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return rows


def fmt(v, digits=3):
    if v is None:
        return "—"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-4 or abs(v) >= 1e5:
            return f"{v:.2e}"
        return f"{v:.{digits}f}"
    return str(v)


def render(rows: list[dict], single_pod_only_roofline: bool = True) -> str:
    out = []
    out.append("### Dry-run status (10 arch × 4 shapes × 2 meshes)\n")
    out.append("| arch | shape | 16x16 | 2x16x16 |")
    out.append("|---|---|---|---|")
    by_key: dict[tuple, dict] = {}
    for r in rows:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    archs = sorted({r["arch"] for r in rows})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in archs:
        for s in shapes:
            cells = []
            for m in ("16x16", "2x16x16"):
                r = by_key.get((a, s, m))
                if r is None:
                    cells.append("—")
                elif r["status"] == "ok":
                    cells.append(f"ok ({r['wall_s']:.0f}s)")
                elif r["status"] == "skipped":
                    cells.append("skip")
                else:
                    cells.append("**ERROR**")
            out.append(f"| {a} | {s} | {cells[0]} | {cells[1]} |")
    out.append("")
    out.append("### Roofline terms (single-pod 16x16, per chip, seconds/step)\n")
    out.append(
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "HLO GF | HBM GB | coll GB | model/HLO flops | peak mem GB |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok" or (single_pod_only_roofline and r["mesh"] != "16x16"):
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
            f"**{r['dominant']}** | {fmt(r['hlo_gflops'], 0)} | "
            f"{fmt(r['hbm_gb'], 1)} | {fmt(r['coll_gb'], 2)} | "
            f"{fmt(r['model_flops_ratio'], 3)} | {fmt(r['peak_mem_gb'], 2)} |"
        )
    out.append("")
    return "\n".join(out)


if __name__ == "__main__":
    paths = sys.argv[1:] or sorted(glob.glob("results/dryrun_*.json"))
    print(render(load(paths)))
