"""Plan-artifact serving benchmark: compile→artifact→serve, measured.

For each benchmarked architecture (reduced configs — this runs on CPU CI)
the benchmark:

1. AOT-compiles the decode-step plan with ``--search`` (order annealing +
   fusion search on the *transformer decode graph* — the ROADMAP retarget)
   and records the searched-vs-greedy planned footprint;
2. publishes the v3 bundle (activation plan + cross-step state plan +
   AOT-serialized decode executables), cold-starts an
   ``InferenceEngine`` from it and serves one token, asserting — via
   the instrumentation counters — that the bundle path performs ZERO
   jaxpr traces, ZERO planner calls, ZERO state layouts, and ZERO XLA
   compiles (plans AND programs ship in the artifact);
3. cold-starts a plan-at-construction engine (plan cache cleared) and
   serves one token from it too, so both the construction-only
   cold-start win and the **time-to-first-token** win (the baseline
   pays its lazy decode-jit XLA compile here) are committed numbers,
   not claims.

Hard checks (regressions fail CI):
* searched footprint <= greedy footprint on EVERY arch (never-worse);
* searched footprint strictly smaller on >= 2 archs;
* unified footprint (activation + state) never exceeds the sum of the
  two independently-planned halves, per bucket;
* the bundle-served engine does zero traces/plans/state layouts AND
  zero XLA compiles through its first served token;
* the lazy baseline pays >= 1 decode compile (the comparison is real);
* time-to-first-token from the bundle is >= 5x faster than
  plan-at-construction on >= 3 of the 4 benched archs;
* state residency: the bundle-served engine's LIVE device state bytes
  equal the bundled ``StatePlan.total_size`` exactly (one plan-backed
  allocation — planned == live, per arch);
* paged state: on at least one token-indexed-state arch the paged
  plan's live pool bytes at 25% fill are >= 3x under the symmetric
  ``StatePlan.total_size`` (SSM archs with length-independent state
  legitimately stay near 1x); per-arch 10/50/100%-fill live bytes and
  slots-per-GiB ride in the committed rows.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py --quick \
        --out BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax
import numpy as np

import repro.core.planner as planner
import repro.core.unified as unified
import repro.runtime.residency as residency
import repro.trace.jaxpr_liveness as tracer
from repro.configs.base import get_reduced
from repro.core import plan_io
from repro.core.unified import (
    PlanSession,
    detect_state_axes,
    plan_paged_state,
    plan_state,
    state_records_from_pytree,
)
from repro.launch.compile import compile_and_publish
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine

ARCHS = ("qwen3-0.6b", "granite-moe-3b-a800m", "mamba2-2.7b", "zamba2-7b")
KB = 2**10


def bench_arch(arch: str, bundle_dir: str, *, iters: int,
               fusion_rounds: int, emit=print) -> dict:
    cfg = get_reduced(arch)
    res = compile_and_publish(
        cfg, bundle_dir, n_slots=2, max_len=64,
        search=True, search_iters=iters, fusion_rounds=fusion_rounds,
        command="benchmarks/serve_bench.py",
    )
    greedy = res.greedy_plan.total_size
    searched = res.bundle.plan.total_size
    assert searched <= greedy, (
        f"{arch}: searched plan {searched} > greedy {greedy} "
        f"(never-worse contract broken)"
    )
    # unified-footprint contract: the bundled (activation + state) total
    # must never exceed the sum of the two independently-planned halves
    model = Model.for_config(cfg)
    state_alone = plan_state(
        state_records_from_pytree(
            jax.eval_shape(lambda: model.init_cache(2, 64)), n_slots=2
        ),
        n_slots=2, max_len=64,
    ).total_size
    state_bytes = res.bundle.state_plan.total_size
    unified_bytes = res.bundle.total_size
    assert unified_bytes <= searched + state_alone, (
        f"{arch}: unified {unified_bytes} > independently planned "
        f"{searched} + {state_alone}"
    )

    params = model.init(jax.random.PRNGKey(0))
    prompt = (
        np.random.default_rng(1).integers(0, cfg.vocab, size=8)
        .astype(np.int32)
    )

    traces0, plans0, states0, compiles0 = (
        tracer.TRACE_CALLS, planner.PLAN_CALLS, unified.STATE_PLAN_CALLS,
        residency.COMPILE_CALLS,
    )
    t0 = time.perf_counter()
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                             session=PlanSession.from_manifest(bundle_dir))
    cold_with = time.perf_counter() - t0
    assert engine.memory_report.plan_source == "bundle", (
        f"{arch}: expected bundle-served plan, got "
        f"{engine.memory_report.plan_source} "
        f"({engine.memory_report.bundle_warning})"
    )
    assert engine.memory_report.aot_warning is None, (
        f"{arch}: AOT executables refused: "
        f"{engine.memory_report.aot_warning}"
    )
    # first token from the bundle: zero traces, zero planner calls, zero
    # state layouts, zero XLA compiles — the whole program shipped
    engine.submit(prompt, max_new_tokens=1)
    engine.run_until_done()
    ttft_with = time.perf_counter() - t0
    assert (
        tracer.TRACE_CALLS == traces0
        and planner.PLAN_CALLS == plans0
        and unified.STATE_PLAN_CALLS == states0
    ), f"{arch}: bundle path traced/planned/laid out state at construction"
    compiles_with = residency.COMPILE_CALLS - compiles0
    assert compiles_with == 0, (
        f"{arch}: bundle-served engine paid {compiles_with} XLA "
        f"compile(s) to its first token; expected zero"
    )
    # planned == live: the engine's cross-step state is ONE device buffer
    # of exactly the bundled StatePlan's total (state residency)
    rep = engine.memory_report
    assert rep.state_residency, f"{arch}: state residency unexpectedly off"
    assert rep.state_live_bytes == state_bytes == engine.state.live_bytes, (
        f"{arch}: live device state {rep.state_live_bytes} B != planned "
        f"{state_bytes} B"
    )

    plan_io.default_cache().clear()  # true cold start for the baseline
    compiles0 = residency.COMPILE_CALLS
    t0 = time.perf_counter()
    baseline = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    cold_without = time.perf_counter() - t0
    baseline.submit(prompt, max_new_tokens=1)
    baseline.run_until_done()
    ttft_without = time.perf_counter() - t0
    compiles_without = residency.COMPILE_CALLS - compiles0
    assert compiles_without >= 1, (
        f"{arch}: lazy baseline paid no decode compile — the TTFT "
        f"comparison is not measuring what it claims"
    )

    row = {
        "arch": arch,
        "ops": len(res.graph.ops),
        "records": len(res.bundle.plan.records),
        "greedy_bytes": greedy,
        "searched_bytes": searched,
        "delta_bytes": greedy - searched,
        "state_bytes": state_bytes,
        "state_planned_bytes": state_bytes,
        "state_live_bytes": rep.state_live_bytes,
        "unified_bytes": unified_bytes,
        "searched_strategy": res.bundle.plan.strategy,
        "fused_groups": (
            res.fusion_result.n_fused_groups if res.fusion_result else 0
        ),
        "compile_wall_s": round(res.wall_s, 3),
        "cold_start_with_bundle_s": round(cold_with, 4),
        "cold_start_without_s": round(cold_without, 4),
        "cold_start_speedup": round(cold_without / max(cold_with, 1e-9), 2),
        "ttft_with_bundle_s": round(ttft_with, 4),
        "ttft_without_s": round(ttft_without, 4),
        "ttft_speedup": round(ttft_without / max(ttft_with, 1e-9), 2),
        "compile_calls_with_bundle": compiles_with,
        "compile_calls_without": compiles_without,
        "aot_executables": len(res.bundle.executables.entries),
        "aot_bytes": res.bundle.executables.nbytes,
    }
    # --- paged-state economics: live pool bytes scale with live tokens,
    # not with n_slots * slot_stride. Derived from the same page-granular
    # plan the paged backend serves (token spans + pool carving); the
    # runtime twin of these numbers (engine peak live pages) is
    # differential-asserted in tests/test_paging.py.
    page_size = 1024
    paged = plan_paged_state(
        state_records_from_pytree(
            jax.eval_shape(lambda: model.init_cache(2, 64)), n_slots=2
        ),
        n_slots=2, max_len=64, page_size=page_size,
        axes=detect_state_axes(model.init_cache, n_slots=2, max_len=64),
    )
    fills = {}
    for pct in (10, 25, 50, 100):
        length = max(1, round(paged.max_len * pct / 100))
        fills[pct] = paged.n_slots * paged.live_bytes(length)
    row.update({
        "paged_page_size": page_size,
        "paged_pool_pages": paged.n_pages_pool,
        "paged_phys_bytes": paged.phys_total_size,
        "paged_live_bytes_10pct": fills[10],
        "paged_live_bytes_50pct": fills[50],
        "paged_live_bytes_100pct": fills[100],
        # symmetric always pays total_size; paged pays the live pages
        "paged_vs_symmetric_at_25pct": round(
            state_bytes / max(fills[25], 1), 2
        ),
        "slots_per_gib_symmetric": 2**30 // paged.slot_stride,
        "slots_per_gib_paged_10pct": (
            2**30 // max(fills[10] // paged.n_slots, 1)
        ),
    })
    emit(
        f"{arch}: paged pool {paged.n_pages_pool} x {page_size} B; live "
        f"{fills[10] / KB:.0f}/{fills[50] / KB:.0f}/{fills[100] / KB:.0f} "
        f"KiB at 10/50/100% fill vs {state_bytes / KB:.0f} KiB symmetric "
        f"({row['paged_vs_symmetric_at_25pct']}x smaller at 25%); "
        f"{row['slots_per_gib_paged_10pct']} paged slots/GiB at 10% vs "
        f"{row['slots_per_gib_symmetric']} symmetric"
    )
    emit(
        f"{arch}: greedy {greedy / KB:.0f} KiB -> searched "
        f"{searched / KB:.0f} KiB ({row['fused_groups']} fused groups) "
        f"+ state {state_bytes / KB:.0f} KiB = {unified_bytes / KB:.0f} KiB "
        f"unified; live state {rep.state_live_bytes / KB:.0f} KiB "
        f"(== planned); cold start {cold_with:.3f}s with bundle vs "
        f"{cold_without:.3f}s without ({row['cold_start_speedup']}x); "
        f"first token {ttft_with:.3f}s/{compiles_with} compiles with vs "
        f"{ttft_without:.3f}s/{compiles_without} without "
        f"({row['ttft_speedup']}x)"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--archs", nargs="*", default=list(ARCHS))
    args = ap.parse_args()
    iters = 120 if args.quick else 300
    fusion_rounds = 20 if args.quick else 40

    rows = []
    with tempfile.TemporaryDirectory() as bundle_dir:
        for arch in args.archs:
            rows.append(
                bench_arch(arch, bundle_dir, iters=iters,
                           fusion_rounds=fusion_rounds)
            )

    strict = sum(r["delta_bytes"] > 0 for r in rows)
    assert strict >= 2, (
        f"search strictly improved only {strict} arch(es); expected >= 2 "
        f"on transformer decode graphs"
    )
    print(f"# {strict}/{len(rows)} archs strictly improved by search")

    # token-indexed state (attention KV) must show the paged win; SSM
    # archs with length-independent state legitimately stay near 1x
    paged_wins = sum(r["paged_vs_symmetric_at_25pct"] >= 3 for r in rows)
    assert paged_wins >= 1, (
        f"no arch's live paged bytes were >= 3x under the symmetric plan "
        f"at 25% fill: "
        f"{[(r['arch'], r['paged_vs_symmetric_at_25pct']) for r in rows]}"
    )
    print(f"# {paged_wins}/{len(rows)} archs >= 3x smaller live state "
          f"under paging at 25% fill")

    fast = sum(r["ttft_speedup"] >= 5 for r in rows)
    need = min(3, len(rows))
    assert fast >= need, (
        f"time-to-first-token from the v3 bundle was >= 5x faster on only "
        f"{fast}/{len(rows)} arch(es); expected >= {need}"
    )
    print(f"# {fast}/{len(rows)} archs served their first token >= 5x "
          f"faster from the AOT bundle")

    if args.out:
        doc = {
            "bench": "plan_artifact_serve",
            "n_slots": 2,
            "max_len": 64,
            "search_iters": iters,
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
