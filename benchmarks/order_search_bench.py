"""Memory-aware order/fusion search over the paper's six networks.

For every model config this benchmark plans the default order (the
paper's setting), then runs the two outer searches built on the cached
planner — topological-order annealing (``core/order_search``) and
MAFAT-style fusion search (``core/fusion_search``) — and reports the
planned-footprint delta and the plan-cache hit rate per config. A second
sweep over the same configs with the shared cache shows the outer-loop
regime the cache was built for (every evaluation a hit).

It also micro-benchmarks the incremental usage-record updater against the
legacy per-candidate rebuild (reorder + ``Graph.validate()`` +
``usage_records()``), the loop the old search paid on every iteration.

Hard checks (the PR's acceptance criteria, enforced here so regressions
fail CI):
* searched footprint <= default-order footprint on EVERY config;
* strictly smaller on >= 3 configs.

Usage:
    PYTHONPATH=src python benchmarks/order_search_bench.py --quick \
        --out BENCH_search.json
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core.fusion_search import fusion_search
from repro.core.graph import Graph
from repro.core.order_search import IncrementalRecords, search_order
from repro.core.plan_io import PlanCache
from repro.models.convnets import PAPER_NETWORKS

MB = 2**20


def sweep(iters: int, *, cache: PlanCache, emit=print) -> list[dict]:
    rows = []
    for name, fn in PAPER_NETWORKS.items():
        g = fn()
        order_res = search_order(g, iters=iters, seed=0, cache=cache)
        fusion_res = fusion_search(g, cache=cache)
        baseline = order_res.baseline_plan.total_size
        best = min(order_res.plan.total_size, fusion_res.plan.total_size)
        row = {
            "config": name,
            "ops": len(g.ops),
            "records": len(order_res.baseline_plan.records),
            "baseline_bytes": baseline,
            "searched_order_bytes": order_res.plan.total_size,
            "fused_bytes": fusion_res.plan.total_size,
            "best_bytes": best,
            "delta_bytes": baseline - best,
            "fused_groups": fusion_res.n_fused_groups,
            "internalized_bytes": fusion_res.internalized_bytes,
            "evaluations": order_res.evaluations + fusion_res.evaluations,
            "order_cache_hit_rate": round(order_res.cache_hit_rate, 4),
            "fusion_cache_hit_rate": round(fusion_res.cache_hit_rate, 4),
            "wall_s": round(order_res.wall_s + fusion_res.wall_s, 4),
        }
        rows.append(row)
        emit(
            f"{name}: baseline {baseline / MB:.3f} MiB -> best "
            f"{best / MB:.3f} MiB (delta {row['delta_bytes'] / MB:+.3f}, "
            f"{row['fused_groups']} fused groups, "
            f"{row['evaluations']} plan calls, {row['wall_s']:.2f}s)"
        )
    return rows


def resweep_hit_rate(iters: int, cache: PlanCache) -> float:
    """Re-run the searches against the warm shared cache — the outer-sweep
    regime (config sweeps, repeated engine construction) where every plan
    call should be a hit."""
    h0, m0 = cache.hits, cache.misses
    for name, fn in PAPER_NETWORKS.items():
        g = fn()
        search_order(g, iters=iters, seed=0, cache=cache)
        fusion_search(g, cache=cache)
    hits, misses = cache.hits - h0, cache.misses - m0
    return hits / max(hits + misses, 1)


def micro_incremental_vs_rebuild(
    n_swaps: int = 300, emit=print
) -> dict:
    """Per-candidate cost of deriving records after an adjacent swap:
    incremental updater vs the legacy rebuild the old annealing loop ran
    (reorder the op list, re-validate the whole graph, re-extract every
    record)."""
    g = PAPER_NETWORKS["inception_v3"]()
    probe = IncrementalRecords(g)
    rng = random.Random(0)
    n = len(g.ops)
    ks: list[int] = []
    while len(ks) < n_swaps:
        k = rng.randrange(n - 1)
        if probe.can_swap(k):
            probe.swap(k)
            ks.append(k)

    inc = IncrementalRecords(g)
    t0 = time.perf_counter()
    for k in ks:
        inc.swap(k)
        inc.records()
    t_inc = time.perf_counter() - t0

    order = list(range(n))
    t0 = time.perf_counter()
    for k in ks:
        order[k], order[k + 1] = order[k + 1], order[k]
        g2 = Graph(
            name=g.name,
            ops=[g.ops[i] for i in order],
            tensors=g.tensors,
            boundary_ids=g.boundary_ids,
        )
        g2.validate()
        g2.usage_records()
    t_full = time.perf_counter() - t0

    assert sorted(inc.records()) == sorted(g2.usage_records()), (
        "incremental records diverged from the full rebuild"
    )
    out = {
        "graph": g.name,
        "n_swaps": n_swaps,
        "incremental_us_per_swap": round(t_inc / n_swaps * 1e6, 2),
        "rebuild_us_per_swap": round(t_full / n_swaps * 1e6, 2),
        "speedup": round(t_full / max(t_inc, 1e-9), 2),
    }
    emit(
        f"incremental updater: {out['incremental_us_per_swap']} us/swap vs "
        f"rebuild {out['rebuild_us_per_swap']} us/swap "
        f"({out['speedup']}x)"
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sweep: fewer annealing iterations")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args()
    iters = args.iters or (250 if args.quick else 1000)

    cache = PlanCache()
    rows = sweep(iters, cache=cache)
    warm = resweep_hit_rate(iters, cache)
    print(f"warm resweep plan-cache hit rate: {warm:.3f}")
    micro = micro_incremental_vs_rebuild()

    worse = [r["config"] for r in rows if r["best_bytes"] > r["baseline_bytes"]]
    assert not worse, f"search regressed the footprint on: {worse}"
    strict = sum(r["delta_bytes"] > 0 for r in rows)
    assert strict >= 3, f"only {strict} configs strictly improved (need >= 3)"
    print(f"# {strict}/{len(rows)} configs strictly improved, none regressed")

    result = {
        "bench": "order_fusion_search",
        "iters": iters,
        "rows": rows,
        "warm_resweep_hit_rate": round(warm, 4),
        "strict_improvements": strict,
        "micro_incremental_vs_rebuild": micro,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
