"""Planner runtime scaling: fast interval-set engine vs the frozen oracle.

The paper discusses O(k·n²) vs O(k·n·log n); this benchmark makes the gap
a tracked number. For growing synthetic graphs it times each strategy on
both implementations, asserts their totals agree (a last-ditch
differential check at sizes the test harness doesn't reach), and writes a
JSON trajectory (``BENCH_planner.json``) consumed by scripts/ci.sh.

Usage:
    PYTHONPATH=src python benchmarks/planner_scaling.py --quick \
        --out BENCH_planner.json
    PYTHONPATH=src python benchmarks/planner_scaling.py --sizes 100 1000

The oracle is skipped above ``--oracle-max-n`` (it is quadratic by
design); fast-path timings keep scaling beyond it.
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core import baselines, offsets, reference, shared_objects
from repro.core.records import TensorUsageRecord

STRATEGY_PAIRS = (
    # (name, fast fn, oracle fn)
    ("shared_objects/greedy_by_size",
     shared_objects.greedy_by_size, reference.greedy_by_size),
    ("offsets/greedy_by_size",
     offsets.greedy_by_size_offsets, reference.greedy_by_size_offsets),
    ("offsets/strip_packing_bestfit",
     baselines.strip_packing_bestfit, reference.strip_packing_bestfit),
)


def synth_records(n: int, seed: int = 0) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    recs = []
    n_ops = max(n, 2)
    for i in range(n):
        a = rng.randrange(n_ops - 1)
        b = min(a + rng.randrange(1, 8), n_ops - 1)
        recs.append(
            TensorUsageRecord(a, b, rng.randrange(1, 1 << 20) * 64, tensor_id=i)
        )
    return recs


def _time(fn, recs) -> tuple[float, int]:
    t0 = time.perf_counter()
    total = fn(recs).total_size
    return time.perf_counter() - t0, total


def bench(sizes, *, oracle_max_n: int = 5000, emit=print) -> dict:
    rows = []
    for n in sizes:
        recs = synth_records(n)
        for name, fast_fn, oracle_fn in STRATEGY_PAIRS:
            fast_s, fast_total = _time(fast_fn, recs)
            row = {
                "n": n,
                "strategy": name,
                "fast_s": round(fast_s, 6),
                "total_size": fast_total,
            }
            if n <= oracle_max_n:
                oracle_s, oracle_total = _time(oracle_fn, recs)
                if oracle_total != fast_total:
                    raise AssertionError(
                        f"{name} n={n}: fast total {fast_total} != "
                        f"oracle {oracle_total} — differential violation"
                    )
                row["oracle_s"] = round(oracle_s, 6)
                row["speedup"] = round(oracle_s / max(fast_s, 1e-9), 2)
            rows.append(row)
            emit(
                f"{name} n={n}: fast {fast_s * 1e3:.1f} ms"
                + (
                    f", oracle {row['oracle_s'] * 1e3:.1f} ms "
                    f"({row['speedup']}x)"
                    if "oracle_s" in row
                    else " (oracle skipped)"
                )
                + f", total={fast_total}"
            )
    return {"bench": "planner_scaling", "rows": rows}


def run(emit=print) -> None:
    """Back-compat entry for benchmarks/run.py: small fast-only sweep in
    the historical ``name,us_per_call,derived`` CSV shape."""
    emit("name,us_per_call,derived")
    for n in (100, 300, 1000, 3000):
        recs = synth_records(n)
        for name, fn in (
            ("gbs_shared_objects", shared_objects.greedy_by_size),
            ("gbs_offsets", offsets.greedy_by_size_offsets),
        ):
            dt, total = _time(fn, recs)
            emit(f"{name}_n{n},{dt * 1e6:.0f},total={total}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sweep: n in (500, 2000, 5000)")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--oracle-max-n", type=int, default=5000)
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args()
    sizes = args.sizes or ((500, 2000, 5000) if args.quick
                           else (100, 300, 1000, 3000, 5000, 10000))
    result = bench(sizes, oracle_max_n=args.oracle_max_n)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
