"""Planner runtime scaling — validates the paper's O(k·n²)/O(k·n·log n)
complexity discussion on synthetic graphs of growing size."""

from __future__ import annotations

import random
import time

from repro.core import offsets, shared_objects
from repro.core.records import TensorUsageRecord


def synth_records(n: int, seed: int = 0) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    recs = []
    n_ops = max(n, 2)
    for i in range(n):
        a = rng.randrange(n_ops - 1)
        b = min(a + rng.randrange(1, 8), n_ops - 1)
        recs.append(
            TensorUsageRecord(a, b, rng.randrange(1, 1 << 20) * 64, tensor_id=i)
        )
    return recs


def run(emit=print) -> None:
    emit("name,us_per_call,derived")
    for n in (100, 300, 1000, 3000):
        recs = synth_records(n)
        for name, fn in (
            ("gbs_shared_objects", shared_objects.greedy_by_size),
            ("gbs_offsets", offsets.greedy_by_size_offsets),
        ):
            t0 = time.perf_counter()
            total = fn(recs).total_size
            dt = (time.perf_counter() - t0) * 1e6
            emit(f"{name}_n{n},{dt:.0f},total={total}")
