"""Planner runtime scaling: fast interval-set engine vs the frozen oracle,
plus full-scale real-config planning.

The paper discusses O(k·n²) vs O(k·n·log n); this benchmark makes the gap
a tracked number. For growing synthetic graphs it times each strategy on
both implementations, asserts their totals agree (a last-ditch
differential check at sizes the test harness doesn't reach), and writes a
JSON trajectory (``BENCH_planner.json``) consumed by scripts/ci.sh.

A second section plans *real* decode graphs for the full-scale configs
(gemma3-27b, llama4-maverick-400b-a17b, nemotron-4-340b) end to end:
trace → portfolio plan → soundness certification → searched strategies
(order annealing and fusion descent), with wall-clock, arena footprint,
and a per-config time-budget column. Fusion search is the expensive leg
(each round re-plans every adjacent merge), so ``--quick`` caps it to
graphs small enough for CI and logs exactly what it dropped.

Usage:
    PYTHONPATH=src python benchmarks/planner_scaling.py --quick \
        --out BENCH_planner.json
    PYTHONPATH=src python benchmarks/planner_scaling.py --sizes 100 1000
    PYTHONPATH=src python benchmarks/planner_scaling.py --no-full-scale

The oracle is skipped above ``--oracle-max-n`` (it is quadratic by
design); fast-path timings keep scaling beyond it.
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core import baselines, offsets, reference, shared_objects
from repro.core.records import TensorUsageRecord

STRATEGY_PAIRS = (
    # (name, fast fn, oracle fn, oracle cap) — the cap bounds the sizes
    # where the frozen oracle still runs (None defers to --oracle-max-n).
    # The improved oracle re-scans every (tensor, object) pair per stage,
    # so it blows past the generic cutoff long before the others (~25 s
    # at n=2000 already); the heap fast path keeps scaling regardless.
    ("shared_objects/greedy_by_size",
     shared_objects.greedy_by_size, reference.greedy_by_size, None),
    ("shared_objects/greedy_by_size_improved",
     shared_objects.greedy_by_size_improved,
     reference.greedy_by_size_improved, 2000),
    ("offsets/greedy_by_size",
     offsets.greedy_by_size_offsets, reference.greedy_by_size_offsets,
     None),
    ("offsets/strip_packing_bestfit",
     baselines.strip_packing_bestfit, reference.strip_packing_bestfit,
     None),
)

# (arch, n_slots, max_len, budget_s) — budget_s bounds the whole
# per-config pipeline (trace + plan + certify + both searches) and is
# reported alongside the measured wall so regressions show as a flipped
# ``within_budget`` bit, not just a bigger number.
FULL_SCALE = (
    ("gemma3-27b", 8, 2048, 180.0),
    ("llama4-maverick-400b-a17b", 8, 2048, 60.0),
    ("nemotron-4-340b", 8, 2048, 30.0),
)


def synth_records(n: int, seed: int = 0) -> list[TensorUsageRecord]:
    rng = random.Random(seed)
    recs = []
    n_ops = max(n, 2)
    for i in range(n):
        a = rng.randrange(n_ops - 1)
        b = min(a + rng.randrange(1, 8), n_ops - 1)
        recs.append(
            TensorUsageRecord(a, b, rng.randrange(1, 1 << 20) * 64, tensor_id=i)
        )
    return recs


def _time(fn, recs) -> tuple[float, int]:
    t0 = time.perf_counter()
    total = fn(recs).total_size
    return time.perf_counter() - t0, total


def bench(sizes, *, oracle_max_n: int = 5000, emit=print) -> dict:
    rows = []
    for n in sizes:
        recs = synth_records(n)
        for name, fast_fn, oracle_fn, oracle_cap in STRATEGY_PAIRS:
            fast_s, fast_total = _time(fast_fn, recs)
            row = {
                "n": n,
                "strategy": name,
                "fast_s": round(fast_s, 6),
                "total_size": fast_total,
            }
            if n <= min(oracle_max_n, oracle_cap or oracle_max_n):
                oracle_s, oracle_total = _time(oracle_fn, recs)
                if oracle_total != fast_total:
                    raise AssertionError(
                        f"{name} n={n}: fast total {fast_total} != "
                        f"oracle {oracle_total} — differential violation"
                    )
                row["oracle_s"] = round(oracle_s, 6)
                row["speedup"] = round(oracle_s / max(fast_s, 1e-9), 2)
            rows.append(row)
            emit(
                f"{name} n={n}: fast {fast_s * 1e3:.1f} ms"
                + (
                    f", oracle {row['oracle_s'] * 1e3:.1f} ms "
                    f"({row['speedup']}x)"
                    if "oracle_s" in row
                    else " (oracle skipped)"
                )
                + f", total={fast_total}"
            )
    return {"bench": "planner_scaling", "rows": rows}


def bench_full_scale(
    configs=FULL_SCALE,
    *,
    search_iters: int = 300,
    fusion_ops_cap: int | None = None,
    emit=print,
) -> list[dict]:
    """Plan real decode graphs end to end and time the searched
    strategies too. Every plan (baseline, order-searched, fused) is
    certified with the soundness pass — a bench row for an unsound plan
    is worse than no row.

    ``fusion_ops_cap`` skips fusion search on graphs with more ops than
    the cap (it re-plans every adjacent merge each round, ~1 min/round at
    ~1.5k ops); skips are logged and recorded as ``null`` columns, never
    silently dropped.
    """
    from repro.analysis import soundness
    from repro.configs.base import get_config
    from repro.core.fusion_search import fusion_search
    from repro.core.order_search import search_order
    from repro.core.planner import plan_graph
    from repro.launch.compile import trace_decode_graph

    def certify(plan, label: str) -> None:
        findings = soundness.certify_plan(plan, label=label)
        errors = [f for f in findings if f.severity == "error"]
        if errors:
            raise AssertionError(
                f"{label}: plan failed soundness certification: "
                + "; ".join(f.message for f in errors)
            )

    rows = []
    for arch, n_slots, max_len, budget_s in configs:
        wall0 = time.perf_counter()
        cfg = get_config(arch)

        t0 = time.perf_counter()
        graph = trace_decode_graph(cfg, n_slots=n_slots, max_len=max_len)
        trace_s = time.perf_counter() - t0
        n_records = len(graph.usage_records())

        t0 = time.perf_counter()
        plan = plan_graph(graph)
        plan_s = time.perf_counter() - t0
        certify(plan, f"{arch}-decode[{plan.strategy}]")

        t0 = time.perf_counter()
        order = search_order(graph, iters=search_iters)
        order_s = time.perf_counter() - t0
        certify(order.plan, f"{arch}-decode[order_search]")

        row = {
            "arch": arch,
            "n_slots": n_slots,
            "max_len": max_len,
            "n_ops": len(graph.ops),
            "n_records": n_records,
            "trace_s": round(trace_s, 3),
            "plan_s": round(plan_s, 3),
            "strategy": plan.strategy,
            "total_size": plan.total_size,
            "lower_bound": plan.lower_bound,
            "order_search_s": round(order_s, 3),
            "order_search_total": order.plan.total_size,
            "order_search_evals": order.evaluations,
        }

        if fusion_ops_cap is not None and len(graph.ops) > fusion_ops_cap:
            emit(
                f"{arch}: fusion search skipped "
                f"({len(graph.ops)} ops > cap {fusion_ops_cap}; run "
                f"without --quick for the full sweep)"
            )
            row["fusion_search_s"] = None
            row["fusion_search_total"] = None
        else:
            t0 = time.perf_counter()
            fused = fusion_search(graph, max_rounds=1)
            fusion_s = time.perf_counter() - t0
            certify(fused.plan, f"{arch}-decode[fusion_search]")
            row["fusion_search_s"] = round(fusion_s, 3)
            row["fusion_search_total"] = fused.plan.total_size
            row["fusion_search_evals"] = fused.evaluations

        wall_s = time.perf_counter() - wall0
        row["budget_s"] = budget_s
        row["wall_s"] = round(wall_s, 3)
        row["within_budget"] = wall_s <= budget_s
        rows.append(row)
        emit(
            f"{arch} slots={n_slots} len={max_len}: "
            f"{row['n_ops']} ops / {n_records} records, "
            f"plan {plan_s * 1e3:.0f} ms → "
            f"{plan.total_size / 2**20:.1f} MiB [{plan.strategy}], "
            f"order {order_s:.1f}s"
            + (
                f", fusion {row['fusion_search_s']}s"
                if row["fusion_search_s"] is not None
                else ""
            )
            + f"; wall {wall_s:.1f}s / budget {budget_s:.0f}s "
            f"({'OK' if row['within_budget'] else 'OVER'}), certified"
        )
    return rows


def run(emit=print) -> None:
    """Back-compat entry for benchmarks/run.py: small fast-only sweep in
    the historical ``name,us_per_call,derived`` CSV shape."""
    emit("name,us_per_call,derived")
    for n in (100, 300, 1000, 3000):
        recs = synth_records(n)
        for name, fn in (
            ("gbs_shared_objects", shared_objects.greedy_by_size),
            ("gbs_offsets", offsets.greedy_by_size_offsets),
        ):
            dt, total = _time(fn, recs)
            emit(f"{name}_n{n},{dt * 1e6:.0f},total={total}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sweep: n in (500, 2000, 5000); fusion "
                         "search capped to graphs <= 512 ops")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--oracle-max-n", type=int, default=5000)
    ap.add_argument("--no-full-scale", action="store_true",
                    help="skip the real-config planning section")
    ap.add_argument("--search-iters", type=int, default=None,
                    help="order-search annealing iterations per config")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args()
    sizes = args.sizes or ((500, 2000, 5000) if args.quick
                           else (100, 300, 1000, 3000, 5000, 10000))
    result = bench(sizes, oracle_max_n=args.oracle_max_n)
    if not args.no_full_scale:
        result["full_scale"] = bench_full_scale(
            search_iters=args.search_iters
            or (100 if args.quick else 300),
            fusion_ops_cap=512 if args.quick else None,
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()