"""The paper's planner applied to the assigned transformer architectures.

Beyond-paper experiment: extract tensor usage records from the decode-step
jaxpr of each (reduced) assigned architecture, plan with every strategy,
and compare against the naive footprint and XLA's own temp allocation for
the same program. Shows the planner is architecture-agnostic (dense, MoE,
SSM, hybrid, VLM) — cf. DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_reduced
from repro.core.planner import plan_graph
from repro.models.api import Model
from repro.trace.jaxpr_liveness import trace_graph

MB = 2**20


def run(emit=print) -> None:
    emit("name,us_per_call,derived")
    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        model = Model.for_config(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, T = 2, 64
        caches = model.init_cache(B, T)
        tok = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        act = jnp.ones((B,), bool)

        def step(p, t, c, po, a):
            return model.decode_step(p, t, c, po, active=a)

        graph = trace_graph(step, params, tok, caches, pos, act,
                            name=f"{arch}-decode")
        t0 = time.perf_counter()
        plan = plan_graph(graph, mode="offsets", strategy="auto")
        dt = (time.perf_counter() - t0) * 1e6
        xla_temp = ""
        try:
            compiled = jax.jit(step).lower(params, tok, caches, pos, act).compile()
            ma = compiled.memory_analysis()
            xla_temp = f"{getattr(ma, 'temp_size_in_bytes', 0) / MB:.3f}"
        except Exception:
            pass
        emit(
            f"plan_{arch},{dt:.0f},"
            f"plan={plan.total_size / MB:.3f}MiB naive={plan.naive_size / MB:.3f} "
            f"lb={plan.lower_bound / MB:.3f} xla_temp={xla_temp} "
            f"reduction={plan.reduction_vs_naive:.2f}x"
        )
