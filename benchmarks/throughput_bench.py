"""Decode-throughput benchmark: scan-block decode vs the host loop.

PR 5 made state bytes live == planned; this benchmark gives decode SPEED
the same committed-trajectory footprint (``BENCH_throughput.json``). For
each decoder arch (reduced configs — runs on CPU CI) it serves one
identical greedy workload twice:

* single-wave HOST loop (``block_size=1``): one decode dispatch + one
  host sync + numpy sampling per wave — the correctness oracle;
* SCAN-BLOCK loop (``block_size=K``): K waves per dispatch via
  ``lax.scan`` over the donated state buffer, sampling + stop detection
  on device, ONE host sync per block, and ``run_until_done``'s async
  pipelining (next block dispatched off the in-flight device carry
  before the previous block's results are fetched).

Measured per mode: tokens/s (wall of the real serving loop), p50/p99
per-token latency (a separate synchronous pass timing each sync unit —
``step()`` / ``step_block()`` — so percentiles are not polluted by the
async overlap), and host syncs per token (the ``engine.HOST_SYNCS``
counter).

Hard checks (regressions fail CI):
* greedy block decode is BYTE-IDENTICAL to the host loop: same tokens
  per request and same slot log;
* host syncs per scan block == 1 (the counter discipline);
* block tokens/s > host-loop tokens/s on every arch (the tentpole's
  measured speedup).

Usage:
    PYTHONPATH=src python benchmarks/throughput_bench.py --quick \
        --out BENCH_throughput.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.runtime.engine as engine_mod
from repro.configs.base import get_reduced
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine

ARCHS = ("qwen3-0.6b", "granite-moe-3b-a800m", "mamba2-2.7b", "zamba2-7b")


def _make_engine(cfg, params, *, n_slots, max_len, block_size):
    return InferenceEngine(
        cfg, params, n_slots=n_slots, max_len=max_len, block_size=block_size
    )


def _submit_all(engine, prompts, max_new):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)


def _warmup(engine, cfg, rng, *, max_new):
    """Compile every jit the measured run will hit (decode, reset, and —
    in block mode — the scan-block jit at the block lengths the workload
    produces), then drain."""
    engine.submit(rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                  max_new_tokens=max_new)
    engine.run_until_done()


def _timed_units(engine, prompts, max_new):
    """Synchronous pass for latency percentiles: wall-clock each sync
    unit (wave or block) and spread it over the waves it covered — one
    per-token latency sample per wave."""
    _submit_all(engine, prompts, max_new)
    samples = []
    step = engine.step if engine.block_size <= 1 else engine.step_block
    while engine._active or engine._queue:
        w0 = engine._wave
        t0 = time.perf_counter()
        step()
        wall = time.perf_counter() - t0
        waves = max(engine._wave - w0, 1)
        samples.extend([wall / waves] * waves)
    return samples


def bench_arch(arch: str, *, n_slots, max_len, requests, max_new,
               block_size, emit=print) -> dict:
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(requests)]

    results = {}
    for mode, bs in (("host", 1), ("block", block_size)):
        # throughput: the real serving loop (async pipelining included)
        engine = _make_engine(cfg, params, n_slots=n_slots, max_len=max_len,
                              block_size=bs)
        _warmup(engine, cfg, rng, max_new=min(block_size, max_new))
        _submit_all(engine, prompts, max_new)
        syncs0, blocks0, waves0 = (
            engine_mod.HOST_SYNCS, engine.n_blocks, engine._wave,
        )
        t0 = time.perf_counter()
        done = engine.run_until_done()
        wall = time.perf_counter() - t0
        syncs = engine_mod.HOST_SYNCS - syncs0
        blocks = engine.n_blocks - blocks0
        waves = engine._wave - waves0
        toks = sum(len(r.tokens) for r in done)
        assert len(done) == requests, f"{arch}/{mode}: lost requests"
        if bs > 1:
            assert syncs == blocks, (
                f"{arch}: {syncs} host syncs over {blocks} scan blocks — "
                f"the block path must sync exactly once per block"
            )
        # latency percentiles: synchronous pass on a fresh engine
        lat_engine = _make_engine(cfg, params, n_slots=n_slots,
                                  max_len=max_len, block_size=bs)
        _warmup(lat_engine, cfg, rng, max_new=min(block_size, max_new))
        samples = _timed_units(lat_engine, prompts, max_new)
        results[mode] = {
            "engine": engine,
            "tokens": toks,
            "wall_s": wall,
            "tokens_per_s": toks / wall,
            "host_syncs": syncs,
            "syncs_per_token": syncs / toks,
            "waves": waves,
            "blocks": blocks,
            "done": {r.request_id: list(r.tokens) for r in done},
            "slot_log": [tuple(x) for x in engine.slot_log],
            "p50_ms": float(np.percentile(samples, 50) * 1e3),
            "p99_ms": float(np.percentile(samples, 99) * 1e3),
        }

    host, block = results["host"], results["block"]
    assert block["done"] == host["done"], (
        f"{arch}: greedy block decode tokens differ from the host loop"
    )
    assert block["slot_log"] == host["slot_log"], (
        f"{arch}: block decode slot log differs from the host loop"
    )
    speedup = block["tokens_per_s"] / host["tokens_per_s"]
    assert speedup > 1.0, (
        f"{arch}: scan-block decode ({block['tokens_per_s']:.1f} tok/s) "
        f"not faster than the host loop ({host['tokens_per_s']:.1f} tok/s)"
    )

    row = {
        "arch": arch,
        "tokens": host["tokens"],
        "host_tokens_per_s": round(host["tokens_per_s"], 2),
        "block_tokens_per_s": round(block["tokens_per_s"], 2),
        "speedup": round(speedup, 3),
        "host_waves": host["waves"],
        "block_syncs": block["host_syncs"],
        "blocks": block["blocks"],
        "host_syncs_per_token": round(host["syncs_per_token"], 4),
        "block_syncs_per_token": round(block["syncs_per_token"], 4),
        "host_p50_ms": round(host["p50_ms"], 3),
        "host_p99_ms": round(host["p99_ms"], 3),
        "block_p50_ms": round(block["p50_ms"], 3),
        "block_p99_ms": round(block["p99_ms"], 3),
        "greedy_identical": True,
    }
    emit(
        f"{arch}: host {host['tokens_per_s']:.1f} tok/s "
        f"({host['host_syncs']} syncs) -> block "
        f"{block['tokens_per_s']:.1f} tok/s ({block['host_syncs']} syncs, "
        f"{speedup:.2f}x); per-token p50 {host['p50_ms']:.2f} -> "
        f"{block['p50_ms']:.2f} ms, p99 {host['p99_ms']:.2f} -> "
        f"{block['p99_ms']:.2f} ms; greedy tokens + slot log identical"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--archs", nargs="*", default=list(ARCHS))
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args()
    requests = 4 if args.quick else 8
    max_new = 16 if args.quick else 32
    n_slots, max_len = 2, 128

    rows = [
        bench_arch(arch, n_slots=n_slots, max_len=max_len,
                   requests=requests, max_new=max_new,
                   block_size=args.block_size)
        for arch in args.archs
    ]

    if args.out:
        doc = {
            "bench": "decode_throughput",
            "n_slots": n_slots,
            "max_len": max_len,
            "requests": requests,
            "max_new": max_new,
            "block_size": args.block_size,
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
