# One function per paper table. Prints ``name,us_per_call,derived`` CSV
# blocks plus the side-by-side paper comparison for Tables 1 and 2.
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import planner_scaling, transformer_footprint
    from benchmarks.tables import (
        table1_shared_objects,
        table2_offsets,
        validate_paper_claims,
    )

    print("# === Table 1: Shared Objects (paper Table 1) ===")
    t1 = table1_shared_objects()
    print("# === Table 2: Offset Calculation (paper Table 2) ===")
    t2 = table2_offsets()
    print("# === paper-claim validation ===")
    failures = validate_paper_claims(t1, t2)
    print("# === planner runtime scaling ===")
    planner_scaling.run()
    print("# === planner on the 10 assigned architectures (decode step) ===")
    transformer_footprint.run()
    print("# === beyond paper: order search (paper §7.1) + optimality gap ===")
    from benchmarks import beyond_paper

    beyond_paper.order_search()
    beyond_paper.optimality_gap()
    if failures:
        print(f"# {len(failures)} claim checks failed", file=sys.stderr)
        sys.exit(1)
    print("# all paper-claim checks passed")


if __name__ == "__main__":
    main()
