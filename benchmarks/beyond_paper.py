"""Beyond-paper experiments:

1. **Order/fusion search** (the paper's §7.1 future work + MAFAT-style
   fusion): how much do re-ordering the op schedule and fusing adjacent
   op groups shrink the PLANNED footprint on the paper's six networks?
   Every candidate is costed by the real planner through the plan cache
   (see also benchmarks/order_search_bench.py for the tracked artifact).
2. **Exact optimality gap**: branch-and-bound optima on random small
   instances vs each greedy strategy (the paper only reports distance to
   its lower *bounds*, which may be unachievable).
"""

from __future__ import annotations

import random
import time

from repro.core import offsets, optimal, shared_objects
from repro.core.fusion_search import fusion_search
from repro.core.order_search import search_order
from repro.core.plan_io import PlanCache
from repro.core.records import TensorUsageRecord
from repro.models.convnets import PAPER_NETWORKS

MB = 2**20


def order_search(emit=print) -> None:
    emit("name,us_per_call,derived")
    cache = PlanCache()
    for net, fn in PAPER_NETWORKS.items():
        g = fn()
        t0 = time.perf_counter()
        order_res = search_order(g, iters=600, seed=0, cache=cache)
        t1 = time.perf_counter()
        fusion_res = fusion_search(g, cache=cache)
        t2 = time.perf_counter()
        base = order_res.baseline_plan.total_size
        best = min(order_res.plan.total_size, fusion_res.plan.total_size)
        emit(
            f"order_search_{net},{(t2 - t0) * 1e6:.0f},"
            f"fixed={base / MB:.3f}MiB order={order_res.plan.total_size / MB:.3f} "
            f"({(t1 - t0) * 1e3:.0f}ms) fused={fusion_res.plan.total_size / MB:.3f} "
            f"({(t2 - t1) * 1e3:.0f}ms) "
            f"best_delta={(base - best) / MB:+.3f} "
            f"hit_rate={(order_res.cache_hit_rate + fusion_res.cache_hit_rate) / 2:.2f}"
        )


def optimality_gap(n_instances: int = 40, n_tensors: int = 9, emit=print) -> None:
    emit("name,us_per_call,derived")
    rng = random.Random(0)
    sums = {"gbs_off": 0.0, "gbb_off": 0.0, "gbs_so": 0.0, "gbsi_so": 0.0, "gbb_so": 0.0}
    exact_off = exact_so = 0
    t0 = time.perf_counter()
    for i in range(n_instances):
        recs = []
        n_ops = 8
        for t in range(n_tensors):
            a = rng.randrange(n_ops - 1)
            b = min(a + rng.randrange(1, 4), n_ops - 1)
            recs.append(TensorUsageRecord(a, b, 64 * rng.randrange(1, 64), tensor_id=t))
        opt_off = optimal.optimal_offsets_total(recs)
        opt_so = optimal.optimal_shared_objects_total(recs)
        gbs_o = offsets.greedy_by_size_offsets(recs).total_size
        gbb_o = offsets.greedy_by_breadth_offsets(recs).total_size
        gbs_s = shared_objects.greedy_by_size(recs).total_size
        gbsi_s = shared_objects.greedy_by_size_improved(recs).total_size
        gbb_s = shared_objects.greedy_by_breadth(recs).total_size
        sums["gbs_off"] += gbs_o / opt_off
        sums["gbb_off"] += gbb_o / opt_off
        sums["gbs_so"] += gbs_s / opt_so
        sums["gbsi_so"] += gbsi_s / opt_so
        sums["gbb_so"] += gbb_s / opt_so
        exact_off += gbs_o == opt_off
        exact_so += gbsi_s == opt_so
    dt = (time.perf_counter() - t0) * 1e6 / n_instances
    for k, v in sums.items():
        emit(f"optgap_{k},{dt:.0f},mean_ratio={v / n_instances:.4f}")
    emit(f"optgap_exact,{dt:.0f},gbs_off_optimal={exact_off}/{n_instances} "
         f"gbsi_so_optimal={exact_so}/{n_instances}")


if __name__ == "__main__":
    order_search()
    optimality_gap()
