"""Beyond-paper experiments:

1. **Topological-order search** (the paper's §7.1 future work): how much
   does re-ordering the op schedule shrink the offsets footprint on the
   paper's six networks?
2. **Exact optimality gap**: branch-and-bound optima on random small
   instances vs each greedy strategy (the paper only reports distance to
   its lower *bounds*, which may be unachievable).
"""

from __future__ import annotations

import random
import time

from repro.core import offsets, optimal, shared_objects
from repro.core.order_search import memory_aware_topo_order, simulated_annealing_order
from repro.core.records import TensorUsageRecord, offsets_lower_bound
from repro.models.convnets import PAPER_NETWORKS

MB = 2**20


def order_search(emit=print) -> None:
    emit("name,us_per_call,derived")
    for net, fn in PAPER_NETWORKS.items():
        g = fn()
        base = offsets.greedy_by_size_offsets(g.usage_records()).total_size
        t0 = time.perf_counter()
        g2 = memory_aware_topo_order(g)
        greedy_total = offsets.greedy_by_size_offsets(g2.usage_records()).total_size
        t1 = time.perf_counter()
        g3 = simulated_annealing_order(g, iters=600, seed=0)
        sa_total = offsets.greedy_by_size_offsets(g3.usage_records()).total_size
        t2 = time.perf_counter()
        emit(
            f"order_search_{net},{(t2 - t0) * 1e6:.0f},"
            f"fixed={base / MB:.3f}MiB memaware={greedy_total / MB:.3f} "
            f"({(t1 - t0) * 1e3:.0f}ms) anneal={sa_total / MB:.3f} "
            f"({(t2 - t1) * 1e3:.0f}ms) "
            f"best_delta={(base - min(greedy_total, sa_total)) / MB:+.3f}"
        )


def optimality_gap(n_instances: int = 40, n_tensors: int = 9, emit=print) -> None:
    emit("name,us_per_call,derived")
    rng = random.Random(0)
    sums = {"gbs_off": 0.0, "gbb_off": 0.0, "gbs_so": 0.0, "gbsi_so": 0.0, "gbb_so": 0.0}
    exact_off = exact_so = 0
    t0 = time.perf_counter()
    for i in range(n_instances):
        recs = []
        n_ops = 8
        for t in range(n_tensors):
            a = rng.randrange(n_ops - 1)
            b = min(a + rng.randrange(1, 4), n_ops - 1)
            recs.append(TensorUsageRecord(a, b, 64 * rng.randrange(1, 64), tensor_id=t))
        opt_off = optimal.optimal_offsets_total(recs)
        opt_so = optimal.optimal_shared_objects_total(recs)
        gbs_o = offsets.greedy_by_size_offsets(recs).total_size
        gbb_o = offsets.greedy_by_breadth_offsets(recs).total_size
        gbs_s = shared_objects.greedy_by_size(recs).total_size
        gbsi_s = shared_objects.greedy_by_size_improved(recs).total_size
        gbb_s = shared_objects.greedy_by_breadth(recs).total_size
        sums["gbs_off"] += gbs_o / opt_off
        sums["gbb_off"] += gbb_o / opt_off
        sums["gbs_so"] += gbs_s / opt_so
        sums["gbsi_so"] += gbsi_s / opt_so
        sums["gbb_so"] += gbb_s / opt_so
        exact_off += gbs_o == opt_off
        exact_so += gbsi_s == opt_so
    dt = (time.perf_counter() - t0) * 1e6 / n_instances
    for k, v in sums.items():
        emit(f"optgap_{k},{dt:.0f},mean_ratio={v / n_instances:.4f}")
    emit(f"optgap_exact,{dt:.0f},gbs_off_optimal={exact_off}/{n_instances} "
         f"gbsi_so_optimal={exact_so}/{n_instances}")


if __name__ == "__main__":
    order_search()
    optimality_gap()
