"""Reproduce the paper's Table 1 (Shared Objects) and Table 2 (Offsets).

For each of the six evaluation networks, run all our strategies + prior
work + bounds, print MB side-by-side with the paper's reported numbers,
and validate the paper's qualitative claims.
"""

from __future__ import annotations

import time

from repro.core import baselines, offsets, shared_objects
from repro.core.fusion_search import fusion_search
from repro.core.order_search import search_order
from repro.core.plan_io import PlanCache
from repro.core.records import (
    naive_consumption,
    offsets_lower_bound,
    shared_objects_lower_bound,
)
from repro.models.convnets import (
    PAPER_NETWORKS,
    PAPER_TABLE1,
    PAPER_TABLE2,
)

MB = 2**20


def _records():
    return {name: fn().usage_records() for name, fn in PAPER_NETWORKS.items()}


def table1_shared_objects(emit=print) -> dict:
    recs = _records()
    strategies = {
        "greedy_by_size": shared_objects.greedy_by_size,
        "greedy_by_size_improved": shared_objects.greedy_by_size_improved,
        "greedy_by_breadth": shared_objects.greedy_by_breadth,
        "tflite_greedy (Lee'19)": baselines.tflite_greedy_in_order,
        "min_cost_flow (Lee'19)": baselines.min_cost_flow_assignment,
    }
    out: dict = {}
    emit("table,network,strategy,ours_mb,paper_mb,us_per_call")
    for net, rs in recs.items():
        for sname, fn in strategies.items():
            t0 = time.perf_counter()
            total = fn(rs).total_size / MB
            dt = (time.perf_counter() - t0) * 1e6
            key = sname.split(" ")[0]
            paper = PAPER_TABLE1.get(key, {}).get(net, "")
            emit(f"table1,{net},{sname},{total:.3f},{paper},{dt:.0f}")
            out.setdefault(net, {})[sname] = total
        lb = shared_objects_lower_bound(rs) / MB
        nv = naive_consumption(rs) / MB
        emit(f"table1,{net},lower_bound,{lb:.3f},{PAPER_TABLE1['lower_bound'][net]},0")
        emit(f"table1,{net},naive,{nv:.3f},{PAPER_TABLE1['naive'][net]},0")
        out[net]["lower_bound"] = lb
        out[net]["naive"] = nv
    return out


def table2_offsets(emit=print) -> dict:
    recs = _records()
    strategies = {
        "greedy_by_size": offsets.greedy_by_size_offsets,
        "greedy_by_breadth": offsets.greedy_by_breadth_offsets,
        "tflite_greedy (Lee'19)": baselines.tflite_greedy_in_order_offsets,
        "strip_packing (Sekiyama'18)": baselines.strip_packing_bestfit,
    }
    out: dict = {}
    search_cache = PlanCache()
    emit("table,network,strategy,ours_mb,paper_mb,us_per_call")
    for net, rs in recs.items():
        for sname, fn in strategies.items():
            t0 = time.perf_counter()
            total = fn(rs).total_size / MB
            dt = (time.perf_counter() - t0) * 1e6
            key = sname.split(" ")[0]
            paper = PAPER_TABLE2.get(key, {}).get(net, "")
            emit(f"table2,{net},{sname},{total:.3f},{paper},{dt:.0f}")
            out.setdefault(net, {})[sname] = total
        # beyond the paper (§7.1): memory-aware order + fusion search over
        # the graph, every candidate planned through the plan cache; the
        # paper has no such column, so paper_mb is blank
        g = PAPER_NETWORKS[net]()
        t0 = time.perf_counter()
        order_res = search_order(g, iters=300, seed=0, cache=search_cache)
        fusion_res = fusion_search(g, cache=search_cache)
        searched = min(
            order_res.plan.total_size, fusion_res.plan.total_size
        ) / MB
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"table2,{net},searched_order (ours),{searched:.3f},,{dt:.0f}")
        out[net]["searched_order"] = searched
        # the search's own fixed-order baseline (auto portfolio) — the
        # honest comparator for "did the SEARCH shrink the plan"
        out[net]["fixed_order_auto"] = order_res.baseline_plan.total_size / MB
        lb = offsets_lower_bound(rs) / MB
        nv = naive_consumption(rs) / MB
        emit(f"table2,{net},lower_bound,{lb:.3f},{PAPER_TABLE2['lower_bound'][net]},0")
        emit(f"table2,{net},naive,{nv:.3f},{PAPER_TABLE2['naive'][net]},0")
        out[net]["lower_bound"] = lb
        out[net]["naive"] = nv
    return out


def validate_paper_claims(t1: dict, t2: dict, emit=print) -> list[str]:
    """The paper's qualitative claims, checked against OUR graphs."""
    failures = []

    def check(cond, msg):
        emit(("PASS " if cond else "FAIL ") + msg)
        if not cond:
            failures.append(msg)

    # §6: Offsets Greedy-by-Size achieves the lower bound on all nets
    # except DeepLab v3 (within 8% there).
    for net in t2:
        gbs, lb = t2[net]["greedy_by_size"], t2[net]["lower_bound"]
        if net == "deeplab_v3":
            check(gbs <= 1.10 * lb, f"t2 {net}: GBS within 10% of LB ({gbs:.3f} vs {lb:.3f})")
        else:
            check(abs(gbs - lb) < 1e-6, f"t2 {net}: GBS == LB ({gbs:.3f} vs {lb:.3f})")
    # abstract: up to ~10.5x smaller than naive (we check >5x somewhere)
    best_red = max(t2[n]["naive"] / t2[n]["greedy_by_size"] for n in t2)
    check(best_red > 5.0, f"t2 best reduction vs naive = {best_red:.1f}x (paper: up to 10.5x)")
    # §4.4: GBS-Improved never worse than GBS for shared objects
    for net in t1:
        check(
            t1[net]["greedy_by_size_improved"] <= t1[net]["greedy_by_size"] + 1e-9,
            f"t1 {net}: GBS-I <= GBS",
        )
    # our strategies never lose to the naive baseline
    for net in t1:
        check(t1[net]["greedy_by_size_improved"] <= t1[net]["naive"], f"t1 {net} <= naive")
    # beyond paper: the planner-driven order/fusion search never loses to
    # the fixed-order plan, and strictly shrinks the arena on most nets.
    # Strictness is judged against the search's OWN fixed-order auto
    # baseline, not GBS — strategy choice alone must not count as a win.
    strict = 0
    for net in t2:
        srch, base = t2[net]["searched_order"], t2[net]["fixed_order_auto"]
        check(srch <= base + 1e-9, f"t2 {net}: searched <= fixed-order plan")
        strict += srch < base - 1e-9
    check(strict >= 3, f"searched order/fusion strictly improves {strict}/6 nets (need >= 3)")
    return failures
