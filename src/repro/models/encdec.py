"""Encoder–decoder backbone (seamless-m4t style, arXiv:2308.11596).

Per the assignment carve-out the audio frontend (mel-spectrogram + conv
feature extractor) is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, S_enc, d_model). We implement the transformer itself:
bidirectional encoder + causal decoder with cross-attention.

Decoder decode_step keeps (self-attn KV cache, precomputed cross-attn KV).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import init_rms, mlp_apply, mlp_init, rms_norm

Constrain = Callable[[jax.Array, str], jax.Array] | None


def _enc_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms(cfg.d_model),
        "attn": attn.attn_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm, dtype,
        ),
        "ln2": init_rms(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _dec_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_init(key, cfg, dtype)
    p["ln_x"] = init_rms(cfg.d_model)
    p["xattn"] = attn.cross_attn_init(
        k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
    )
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kd, kemb = jax.random.split(key, 3)
    import numpy as np

    return {
        "embed": (
            jax.random.normal(kemb, (cfg.vocab, cfg.d_model), jnp.float32)
            / np.sqrt(cfg.d_model)
        ).astype(dtype),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
            jax.random.split(ke, cfg.encoder_layers)
        ),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
            jax.random.split(kd, cfg.n_layers)
        ),
        "ln_f": init_rms(cfg.d_model),
        "ln_enc": init_rms(cfg.d_model),
    }


def _bidir_attn(p, x, cfg, constrain):
    """Full bidirectional self-attention for the encoder (chunked)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = attn._project_qkv(
        p, x, cfg.n_heads, cfg.n_kv_heads, hd,
        jnp.arange(S)[None, :].astype(jnp.int32), cfg.rope_theta, cfg.rms_eps,
    )
    if constrain is not None:
        q = constrain(q, "heads")
    import numpy as np

    s = attn._gqa_scores(q, k) / np.sqrt(hd)
    probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    return attn._gqa_out(probs, v) @ p["wo"]


def encode(params, cfg: ArchConfig, frames: jax.Array, constrain: Constrain = None):
    """frames: (B, S_enc, D) stub embeddings -> encoder output (B, S_enc, D)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    if constrain is not None:
        h = constrain(h, "hidden")

    def body(h, lp):
        x = rms_norm(h, lp["ln1"], cfg.rms_eps)
        h = h + _bidir_attn(lp["attn"], x, cfg, constrain)
        h = h + mlp_apply(lp["mlp"], rms_norm(h, lp["ln2"], cfg.rms_eps), cfg.act, constrain)
        if constrain is not None:
            h = constrain(h, "hidden")
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc"])
    return rms_norm(h, params["ln_enc"], cfg.rms_eps)


def cross_kv(params, cfg: ArchConfig, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V (stacked over layers)."""
    hd = cfg.resolved_head_dim

    def one(lp):
        return attn.encode_kv(lp["xattn"], enc_out, cfg.n_kv_heads, hd)

    return jax.vmap(one, in_axes=0)(params["dec"])


def _dec_block(lp, cfg, h, enc_kv, constrain, cache=None, pos=None, decode=False, active=None):
    hd = cfg.resolved_head_dim
    x = rms_norm(h, lp["ln1"], cfg.rms_eps)
    kwargs = dict(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
        theta=cfg.rope_theta, window=None, eps=cfg.rms_eps, constrain=constrain,
    )
    if decode:
        a, kv = attn.attn_decode(lp["attn"], x, cache, pos, active=active, **kwargs)
    else:
        a, kv = attn.attn_prefill(lp["attn"], x, **kwargs)
    h = h + a
    h = h + attn.cross_attn(
        lp["xattn"], rms_norm(h, lp["ln_x"], cfg.rms_eps), enc_kv,
        n_heads=cfg.n_heads, head_dim=hd, constrain=constrain,
    )
    h = h + mlp_apply(lp["mlp"], rms_norm(h, lp["ln2"], cfg.rms_eps), cfg.act, constrain)
    if constrain is not None:
        h = constrain(h, "hidden")
    return h, kv


def forward(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S_dec)
    frames: jax.Array,  # (B, S_enc, D)
    constrain: Constrain = None,
    remat: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward -> (logits, aux=0)."""
    enc_out = encode(params, cfg, frames, constrain)
    kvs = cross_kv(params, cfg, enc_out)
    h = params["embed"][tokens]

    def body(h, xs):
        lp, kv = xs
        h, _ = _dec_block(lp, cfg, h, kv, constrain)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, (params["dec"], kvs))
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    logits = h @ params["embed"].T
    if constrain is not None:
        logits = constrain(logits, "logits")
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, enc_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "self": (
            jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        ),
        "cross": (
            jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd), dtype),
        ),
    }


def prefill(params, cfg, tokens, frames, constrain: Constrain = None):
    """Encode + teacher-forced pass over the prompt; returns (last logits,
    caches dict with 'self' and 'cross')."""
    enc_out = encode(params, cfg, frames, constrain)
    kvs = cross_kv(params, cfg, enc_out)
    h = params["embed"][tokens]

    def body(h, xs):
        lp, kv = xs
        h, self_kv = _dec_block(lp, cfg, h, kv, constrain)
        return h, self_kv

    h, self_kvs = jax.lax.scan(body, h, (params["dec"], kvs))
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    logits = h[:, -1] @ params["embed"].T
    return logits, {"self": self_kvs, "cross": kvs}


def decode_step(
    params: dict,
    cfg: ArchConfig,
    token: jax.Array,  # (B, 1)
    caches: dict,
    pos: jax.Array,
    constrain: Constrain = None,
    active: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    h = params["embed"][token]
    if constrain is not None:
        h = constrain(h, "hidden")

    def body(h, xs):
        lp, self_kv, kv = xs
        h, new_kv = _dec_block(lp, cfg, h, kv, constrain, self_kv, pos,
                               decode=True, active=active)
        return h, new_kv

    h, new_self = jax.lax.scan(body, h, (params["dec"], caches["self"], caches["cross"]))
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    logits = h[:, 0] @ params["embed"].T
    if constrain is not None:
        logits = constrain(logits, "logits")
    return logits, {"self": new_self, "cross": caches["cross"]}
