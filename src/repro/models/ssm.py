"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Forward (prefill/train) uses the chunked SSD algorithm:
  * within-chunk: quadratic attention-like term with decay mask
  * across-chunk: sequential state recurrence via ``lax.scan`` over chunks
Decode is the O(1) recurrent update on the (B, H, P, N) state.

Block layout follows Mamba2: in_proj -> [z | xBC | dt], causal depthwise
conv over xBC, SSD core, gated RMSNorm, out_proj. Decode carries
(conv_state (B, K-1, conv_dim), ssm_state (B, H, P, N)).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, init_rms, rms_norm

Constrain = Callable[[jax.Array, str], jax.Array] | None


def ssm_dims(d_model: int, expand: int, head_dim: int, ngroups: int, dstate: int):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * ngroups * dstate
    return d_inner, nheads, conv_dim


def mamba_init(key, d_model: int, *, expand: int, head_dim: int,
               ngroups: int, dstate: int, conv: int, dtype) -> dict:
    d_inner, nheads, conv_dim = ssm_dims(d_model, expand, head_dim, ngroups, dstate)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(k1, d_model, 2 * d_inner + 2 * ngroups * dstate + nheads, dtype),
        "conv_w": (jax.random.normal(k2, (conv, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": init_rms(d_inner),
        "out_proj": init_linear(k4, d_inner, d_model, dtype),
    }


def _split_proj(cfg_dims, zxbcdt):
    d_inner, nheads, _ = cfg_dims["d_inner"], cfg_dims["nheads"], None
    ngroups, dstate = cfg_dims["ngroups"], cfg_dims["dstate"]
    z, xBC, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner + 2 * ngroups * dstate],
        axis=-1,
    )
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xBC: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):  # K is small (4); unrolled taps
        out = out + pad[:, i : i + xBC.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def mamba_prefill(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    expand: int,
    head_dim: int,
    ngroups: int,
    dstate: int,
    conv: int,
    chunk: int = 256,
    eps: float = 1e-6,
    constrain: Constrain = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (out (B,S,D), (conv_state, ssm_state))."""
    B, S, D = x.shape
    d_inner, nheads, conv_dim = ssm_dims(D, expand, head_dim, ngroups, dstate)
    dims = dict(d_inner=d_inner, nheads=nheads, ngroups=ngroups, dstate=dstate)
    zxbcdt = x @ p["in_proj"]
    z, xBC_raw, dt = _split_proj(dims, zxbcdt)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ngroups * dstate], axis=-1)
    H, P, G, N = nheads, head_dim, ngroups, dstate
    xs = xs.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    if constrain is not None:
        xs = constrain(xs, "ssm_heads")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    dA = dt * A  # (B,S,H)

    # ---- chunked SSD ----
    C_len = min(chunk, S)
    n_chunks = -(-S // C_len)
    pad = n_chunks * C_len - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    L = C_len
    NC = n_chunks

    def rs(t, tail):  # (B, S', ...) -> (NC, B, L, ...)
        return t.reshape(B, NC, L, *tail).transpose(1, 0, 2, *range(3, 3 + len(tail)))

    xs_c, Bm_c, Cm_c = rs(xs, (H, P)), rs(Bm, (G, N)), rs(Cm, (G, N))
    dt_c, dA_c = rs(dt, (H,)), rs(dA, (H,))

    # broadcast groups to heads (G divides H)
    rep = H // G

    def scan_body(state, inp):
        # state: (B,H,P,N) carried across chunks
        xc, Bc, Cc, dtc, dAc = inp  # (B,L,H,P), (B,L,G,N), ..., (B,L,H)
        cum = jnp.cumsum(dAc, axis=1)  # (B,L,H)
        total = cum[:, -1]  # (B,H)
        Bh = jnp.repeat(Bc, rep, axis=2)  # (B,L,H,N)
        Ch = jnp.repeat(Cc, rep, axis=2)
        # within-chunk (attention-like) term
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Lq,Lk,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        # mask BEFORE exp: exp of the (large positive) upper triangle would
        # be inf and poison gradients through the where
        seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        qk = jnp.einsum("blhn,bmhn->blmh", Ch, Bh)  # (B,Lq,Lk,H)
        W = qk * decay * dtc[:, None, :, :]  # weight on x_m
        y_intra = jnp.einsum("blmh,bmhp->blhp", W.astype(xc.dtype), xc)
        # contribution of the incoming state
        state_decay = jnp.exp(cum)  # (B,L,H)
        y_inter = jnp.einsum(
            "blhn,bhpn->blhp", (Ch * state_decay[..., None]).astype(xc.dtype), state
        )
        # update state for next chunk
        rem = jnp.exp(total[:, None, :] - cum)  # (B,L,H) decay from l to end
        dBx = jnp.einsum(
            "blhn,blhp->bhpn",
            (Bh * (rem * dtc)[..., None]).astype(xc.dtype),
            xc,
        )
        new_state = state * jnp.exp(total)[..., None, None].astype(state.dtype) + dBx
        return new_state, y_intra + y_inter

    state0 = jnp.zeros((B, H, P, N), x.dtype)
    final_state, ys = jax.lax.scan(
        scan_body, state0, (xs_c, Bm_c, Cm_c, dt_c, dA_c)
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, NC * L, H, P)[:, :S]
    y = y + xs[:, :S] * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], eps)
    out = y @ p["out_proj"]
    conv_state = xBC_raw[:, max(S - (conv - 1), 0) :]
    if S < conv - 1:
        conv_state = jnp.pad(conv_state, ((0, 0), (conv - 1 - S, 0), (0, 0)))
    return out, (conv_state, final_state)


def mamba_decode(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: tuple[jax.Array, jax.Array],  # conv_state (B,K-1,conv_dim), ssm (B,H,P,N)
    *,
    expand: int,
    head_dim: int,
    ngroups: int,
    dstate: int,
    conv: int,
    eps: float = 1e-6,
    constrain: Constrain = None,
    active: jax.Array | None = None,  # (B,) bool — freeze inactive states
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    B, _, D = x.shape
    d_inner, nheads, conv_dim = ssm_dims(D, expand, head_dim, ngroups, dstate)
    dims = dict(d_inner=d_inner, nheads=nheads, ngroups=ngroups, dstate=dstate)
    conv_state, state = cache
    zxbcdt = x @ p["in_proj"]  # (B,1,·)
    z, xBC_new, dt = _split_proj(dims, zxbcdt)
    window = jnp.concatenate([conv_state, xBC_new], axis=1)  # (B,K,conv_dim)
    w = p["conv_w"]  # (K, C)
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"])[:, None]
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ngroups * dstate], axis=-1)
    H, P, G, N = nheads, head_dim, ngroups, dstate
    xs = xs.reshape(B, H, P)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)  # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    dt_ = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_ * A)  # (B,H)
    new_state = (
        state * decay[..., None, None].astype(state.dtype)
        + jnp.einsum("bhp,bhn->bhpn", (xs * dt_[..., None].astype(xs.dtype)), Bm)
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cm)
    y = y + xs * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], eps)
    out = y @ p["out_proj"]
    new_conv = window[:, 1:]
    if active is not None:
        new_state = jnp.where(active[:, None, None, None], new_state, state)
        new_conv = jnp.where(active[:, None, None], new_conv, conv_state)
    return out, (new_conv, new_state)
