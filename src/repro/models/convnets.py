"""Tensor-size graph builders for the paper's six evaluation networks.

The planner needs only op ordering + intermediate tensor SHAPES, so each
builder reconstructs the network as a ``Graph`` from the published
architecture spec (fp32, NHWC, 64-byte alignment — the paper's setting).

Fidelity validation: the paper's *Naive* and *Lower Bound* rows are
strategy-independent functions of the graph, so matching them means the
reconstruction is faithful (benchmarks/table*.py prints our values next
to the paper's). MobileNet v1/v2 and Inception v3 follow their papers
exactly; DeepLab v3 (MobileNetV2-OS16 + ASPP head, 257²), PoseNet
(MobileNetV1-101 backbone + 4 heads, 257²) and BlazeFace (128²,
5×5 dw BlazeBlocks) are reconstructed from the cited papers/TFLite model
cards — deviations show up directly in the Naive/LB comparison and are
discussed in EXPERIMENTS.md.
"""

from __future__ import annotations

import math

from repro.core.graph import Graph, GraphBuilder


def _conv_out(size: int, stride: int) -> int:
    """TF 'SAME' padding output size."""
    return -(-size // stride)


def mobilenet_v1(input_size: int = 224, alpha: float = 1.0,
                 name: str = "mobilenet_v1") -> Graph:
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    s = _conv_out(s, 2)
    c = int(32 * alpha)
    x = g.op("conv3x3_s2", [x], (1, s, s, c))
    # 13 depthwise-separable blocks: (out_channels, stride)
    blocks = [
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
        (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
        (1024, 1),
    ]
    for out_c, stride in blocks:
        out_c = int(out_c * alpha)
        s2 = _conv_out(s, stride)
        x = g.op("dw3x3", [x], (1, s2, s2, c))
        s = s2
        x = g.op("pw1x1", [x], (1, s, s, out_c))
        c = out_c
    x = g.op("avgpool", [x], (1, 1, 1, c))
    logits = g.op("fc", [x], (1, 1001))
    g.mark_output(logits)
    return g.build()


def mobilenet_v2(input_size: int = 224, name: str = "mobilenet_v2") -> Graph:
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    s = _conv_out(s, 2)
    x = g.op("conv3x3_s2", [x], (1, s, s, 32))
    c = 32

    def bottleneck(x, c_in, c_out, stride, t, s_in, dilation=1):
        nonlocal g
        s_out = _conv_out(s_in, stride)
        h = x
        exp = c_in * t
        if t != 1:
            h = g.op("expand1x1", [h], (1, s_in, s_in, exp))
        h = g.op("dw3x3", [h], (1, s_out, s_out, exp))
        h = g.op("project1x1", [h], (1, s_out, s_out, c_out))
        if stride == 1 and c_in == c_out:
            h = g.op("add", [x, h], (1, s_out, s_out, c_out))
        return h, s_out

    # (t, c, n, s)
    cfg = [
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
    ]
    for t, c_out, n, stride in cfg:
        for i in range(n):
            x, s = bottleneck(x, c, c_out, stride if i == 0 else 1, t, s)
            c = c_out
    x = g.op("conv1x1_1280", [x], (1, s, s, 1280))
    x = g.op("avgpool", [x], (1, 1, 1, 1280))
    logits = g.op("fc", [x], (1, 1001))
    g.mark_output(logits)
    return g.build()


def inception_v3(input_size: int = 299, name: str = "inception_v3") -> Graph:
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    # stem (VALID padding like TF slim)
    s = (s - 3) // 2 + 1  # 149
    x = g.op("conv3x3_s2", [x], (1, s, s, 32))
    s = s - 2  # 147
    x = g.op("conv3x3", [x], (1, s, s, 32))
    x = g.op("conv3x3_pad", [x], (1, s, s, 64))
    s = (s - 3) // 2 + 1  # 73
    x = g.op("maxpool", [x], (1, s, s, 64))
    x = g.op("conv1x1", [x], (1, s, s, 80))
    s = s - 2  # 71
    x = g.op("conv3x3", [x], (1, s, s, 192))
    s = (s - 3) // 2 + 1  # 35
    x = g.op("maxpool", [x], (1, s, s, 192))

    def branch(x, s, chans, name_prefix):
        h = x
        for i, (c, _) in enumerate(chans):
            h = g.op(f"{name_prefix}_{i}", [h], (1, s, s, c))
        return h

    def inception_a(x, s, pool_c):
        b0 = branch(x, s, [(64, 1)], "a_b0")
        b1 = branch(x, s, [(48, 1), (64, 5)], "a_b1")
        b2 = branch(x, s, [(64, 1), (96, 3), (96, 3)], "a_b2")
        p = g.op("a_pool", [x], (1, s, s, x_c[0]))
        b3 = g.op("a_poolproj", [p], (1, s, s, pool_c))
        out_c = 64 + 64 + 96 + pool_c
        return g.op("a_concat", [b0, b1, b2, b3], (1, s, s, out_c)), out_c

    x_c = [192]
    x, c = inception_a(x, s, 32); x_c = [c]
    x, c = inception_a(x, s, 64); x_c = [c]
    x, c = inception_a(x, s, 64); x_c = [c]

    # reduction A: 35 -> 17
    s2 = (s - 3) // 2 + 1  # 17
    b0 = g.op("ra_b0", [x], (1, s2, s2, 384))
    h = g.op("ra_b1_0", [x], (1, s, s, 64))
    h = g.op("ra_b1_1", [h], (1, s, s, 96))
    b1 = g.op("ra_b1_2", [h], (1, s2, s2, 96))
    b2 = g.op("ra_pool", [x], (1, s2, s2, c))
    x = g.op("ra_concat", [b0, b1, b2], (1, s2, s2, 384 + 96 + c))
    s, c = s2, 384 + 96 + c  # 768

    def inception_b(x, s, c7):
        b0 = branch(x, s, [(192, 1)], "b_b0")
        b1 = branch(x, s, [(c7, 1), (c7, 7), (192, 7)], "b_b1")
        b2 = branch(x, s, [(c7, 1), (c7, 7), (c7, 7), (c7, 7), (192, 7)], "b_b2")
        p = g.op("b_pool", [x], (1, s, s, c))
        b3 = g.op("b_poolproj", [p], (1, s, s, 192))
        return g.op("b_concat", [b0, b1, b2, b3], (1, s, s, 768))

    for c7 in (128, 160, 160, 192):
        x = inception_b(x, s, c7)

    # reduction B: 17 -> 8
    s2 = (s - 3) // 2 + 1  # 8
    h = g.op("rb_b0_0", [x], (1, s, s, 192))
    b0 = g.op("rb_b0_1", [h], (1, s2, s2, 320))
    h = g.op("rb_b1_0", [x], (1, s, s, 192))
    h = g.op("rb_b1_1", [h], (1, s, s, 192))
    h = g.op("rb_b1_2", [h], (1, s, s, 192))
    b1 = g.op("rb_b1_3", [h], (1, s2, s2, 192))
    b2 = g.op("rb_pool", [x], (1, s2, s2, 768))
    x = g.op("rb_concat", [b0, b1, b2], (1, s2, s2, 1280))
    s, c = s2, 1280

    def inception_c(x, s, c_in):
        b0 = branch(x, s, [(320, 1)], "c_b0")
        h = g.op("c_b1_0", [x], (1, s, s, 384))
        b1a = g.op("c_b1_1a", [h], (1, s, s, 384))
        b1b = g.op("c_b1_1b", [h], (1, s, s, 384))
        b1 = g.op("c_b1_cat", [b1a, b1b], (1, s, s, 768))
        h = g.op("c_b2_0", [x], (1, s, s, 448))
        h = g.op("c_b2_1", [h], (1, s, s, 384))
        b2a = g.op("c_b2_2a", [h], (1, s, s, 384))
        b2b = g.op("c_b2_2b", [h], (1, s, s, 384))
        b2 = g.op("c_b2_cat", [b2a, b2b], (1, s, s, 768))
        p = g.op("c_pool", [x], (1, s, s, c_in))
        b3 = g.op("c_poolproj", [p], (1, s, s, 192))
        return g.op("c_concat", [b0, b1, b2, b3], (1, s, s, 2048))

    x = inception_c(x, s, 1280)
    x = inception_c(x, s, 2048)
    x = g.op("avgpool", [x], (1, 1, 1, 2048))
    logits = g.op("fc", [x], (1, 1001))
    g.mark_output(logits)
    return g.build()


def deeplab_v3(input_size: int = 257, name: str = "deeplab_v3") -> Graph:
    """DeepLab v3 with MobileNetV2 backbone at output-stride 16 + ASPP
    (the TFLite mobile segmentation model, 257×257, 21 classes)."""
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    s = _conv_out(s, 2)  # 129
    x = g.op("conv3x3_s2", [x], (1, s, s, 32))
    c = 32

    def bottleneck(x, c_in, c_out, stride, t, s_in):
        s_out = _conv_out(s_in, stride)
        h = x
        exp = c_in * t
        if t != 1:
            h = g.op("expand1x1", [h], (1, s_in, s_in, exp))
        h = g.op("dw3x3", [h], (1, s_out, s_out, exp))
        h = g.op("project1x1", [h], (1, s_out, s_out, c_out))
        if stride == 1 and c_in == c_out:
            h = g.op("add", [x, h], (1, s_out, s_out, c_out))
        return h, s_out

    # OS16: the final stride-2 stage becomes stride-1 (atrous)
    cfg = [
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 1), (6, 320, 1, 1),
    ]
    for t, c_out, n, stride in cfg:
        for i in range(n):
            x, s = bottleneck(x, c, c_out, stride if i == 0 else 1, t, s)
            c = c_out
    # ASPP (mobile variant: 1x1 conv + image pooling branch)
    b0 = g.op("aspp_conv1x1", [x], (1, s, s, 256))
    p = g.op("aspp_image_pool", [x], (1, 1, 1, c))
    p = g.op("aspp_pool_conv", [p], (1, 1, 1, 256))
    p = g.op("aspp_pool_upsample", [p], (1, s, s, 256))
    x = g.op("aspp_concat", [b0, p], (1, s, s, 512))
    x = g.op("aspp_project", [x], (1, s, s, 256))
    x = g.op("classifier", [x], (1, s, s, 21))
    out = g.op("upsample_bilinear", [x], (1, input_size, input_size, 21))
    g.mark_output(out)
    return g.build()


def posenet(input_size: int = 257, name: str = "posenet") -> Graph:
    """PoseNet TFLite: MobileNet v1 backbone (257², OS16 via last stride 1)
    + heatmap/offset/displacement heads (17 keypoints)."""
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    s = _conv_out(s, 2)  # 129
    c = 32
    x = g.op("conv3x3_s2", [x], (1, s, s, c))
    blocks = [
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
        (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 1),
        (1024, 1),
    ]
    for out_c, stride in blocks:
        s2 = _conv_out(s, stride)
        x = g.op("dw3x3", [x], (1, s2, s2, c))
        s = s2
        x = g.op("pw1x1", [x], (1, s, s, out_c))
        c = out_c
    # heads at 1/16 resolution (17x17 for 257 input)
    hm = g.op("heatmap", [x], (1, s, s, 17))
    of = g.op("offsets", [x], (1, s, s, 34))
    df = g.op("disp_fwd", [x], (1, s, s, 32))
    db = g.op("disp_bwd", [x], (1, s, s, 32))
    for t in (hm, of, df, db):
        g.mark_output(t)
    return g.build()


def blazeface(input_size: int = 128, name: str = "blazeface") -> Graph:
    """BlazeFace (arXiv:1907.05047): 5x5 depthwise BlazeBlocks, 128² input,
    feature maps 64² -> 32² -> 16² -> 8², two detection heads. Residual
    adds are fused into the trailing pointwise conv (TFLite GPU behavior),
    so a block's add does not materialize a separate tensor."""
    g = GraphBuilder(name)
    s = input_size
    x = g.input((1, s, s, 3))
    s = _conv_out(s, 2)  # 64
    c = 24
    x = g.op("conv5x5_s2", [x], (1, s, s, c))

    def blaze(x, c_in, c_out, stride, s_in):
        s_out = _conv_out(s_in, stride)
        h = g.op("dw5x5", [x], (1, s_out, s_out, c_in))
        if stride == 2:
            p = g.op("pool_pad", [x], (1, s_out, s_out, c_out))
            h = g.op("pw1x1_add", [h, p], (1, s_out, s_out, c_out))
        else:
            h = g.op("pw1x1_add", [h, x], (1, s_out, s_out, c_out))
        return h, s_out

    def double_blaze(x, c_in, c_out, mid, stride, s_in):
        s_out = _conv_out(s_in, stride)
        h = g.op("dw5x5", [x], (1, s_out, s_out, c_in))
        h = g.op("pw1x1_proj", [h], (1, s_out, s_out, mid))
        h = g.op("dw5x5_2", [h], (1, s_out, s_out, mid))
        if stride == 2:
            p = g.op("pool_pad", [x], (1, s_out, s_out, c_out))
            h = g.op("pw1x1_add", [h, p], (1, s_out, s_out, c_out))
        else:
            h = g.op("pw1x1_add", [h, x], (1, s_out, s_out, c_out))
        return h, s_out

    x, s = blaze(x, 24, 24, 1, s)
    x, s = blaze(x, 24, 24, 1, s)
    x, s = blaze(x, 24, 48, 2, s)  # 32²
    x, s = blaze(x, 48, 48, 1, s)
    x, s = blaze(x, 48, 48, 1, s)
    x, s = double_blaze(x, 48, 96, 24, 2, s)  # 16²
    x, s = double_blaze(x, 96, 96, 24, 1, s)
    x, s = double_blaze(x, 96, 96, 24, 1, s)
    x16 = x
    x, s8 = double_blaze(x, 96, 96, 24, 2, s)  # 8²
    x, s8 = double_blaze(x, 96, 96, 24, 1, s8)
    x, s8 = double_blaze(x, 96, 96, 24, 1, s8)
    # detection heads (scores + boxes per scale; outputs are boundary)
    h16 = g.op("head16", [x16], (1, 16, 16, 2 * 18))
    h8 = g.op("head8", [x], (1, 8, 8, 6 * 18))
    g.mark_output(h16)
    g.mark_output(h8)
    return g.build()


PAPER_NETWORKS = {
    "mobilenet_v1": mobilenet_v1,
    "mobilenet_v2": mobilenet_v2,
    "deeplab_v3": deeplab_v3,
    "inception_v3": inception_v3,
    "posenet": posenet,
    "blazeface": blazeface,
}

# The paper's Tables 1-2, in MB (fp32). Keys: (table, strategy) -> net -> MB
PAPER_TABLE1 = {  # Shared Objects
    "greedy_by_size": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 7.178, "deeplab_v3": 6.437,
        "inception_v3": 10.337, "posenet": 6.347, "blazeface": 0.592,
    },
    "greedy_by_size_improved": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 6.891, "deeplab_v3": 6.437,
        "inception_v3": 10.337, "posenet": 6.347, "blazeface": 0.518,
    },
    "greedy_by_breadth": {
        "mobilenet_v1": 6.125, "mobilenet_v2": 6.699, "deeplab_v3": 6.437,
        "inception_v3": 10.676, "posenet": 8.390, "blazeface": 0.675,
    },
    "lower_bound": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 6.604, "deeplab_v3": 6.105,
        "inception_v3": 8.955, "posenet": 6.347, "blazeface": 0.518,
    },
    "naive": {
        "mobilenet_v1": 19.248, "mobilenet_v2": 26.313, "deeplab_v3": 48.642,
        "inception_v3": 54.010, "posenet": 28.556, "blazeface": 2.698,
    },
}

PAPER_TABLE2 = {  # Offset Calculation
    "greedy_by_size": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 5.742, "deeplab_v3": 4.653,
        "inception_v3": 7.914, "posenet": 6.271, "blazeface": 0.492,
    },
    "greedy_by_breadth": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 5.742, "deeplab_v3": 4.653,
        "inception_v3": 7.914, "posenet": 7.359, "blazeface": 0.656,
    },
    "lower_bound": {
        "mobilenet_v1": 4.594, "mobilenet_v2": 5.742, "deeplab_v3": 4.320,
        "inception_v3": 7.914, "posenet": 6.271, "blazeface": 0.492,
    },
    "naive": {
        "mobilenet_v1": 19.248, "mobilenet_v2": 26.313, "deeplab_v3": 48.642,
        "inception_v3": 54.010, "posenet": 28.556, "blazeface": 2.698,
    },
}
