"""Mixture-of-Experts FFN: top-k router + capacity-based one-hot dispatch.

This is the TPU-native (GShard/Switch) MoE form: tokens are dispatched to
experts via one-hot einsums with a fixed per-expert capacity, which keeps
all shapes static for XLA and maps the routing all-to-all onto sharded
einsums. Expert weights are stacked (E, d_model, d_ff) and sharded on the
experts axis when divisible by the model-parallel degree (llama4: 128/16),
else on d_ff (granite: 40 experts, d_ff 512).

Aux load-balancing loss (Switch-style) is returned for the trainer.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import activation, init_linear

Constrain = Callable[[jax.Array, str], jax.Array] | None


def moe_init(key, d_model: int, d_ff: int, n_experts: int, act: str,
             shared_expert: bool, dtype) -> dict:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": init_linear(kr, d_model, n_experts, jnp.float32),
        "w_in": jax.vmap(lambda k: init_linear(k, d_model, d_ff, dtype))(
            jax.random.split(k1, n_experts)
        ),
        "w_gate": jax.vmap(lambda k: init_linear(k, d_model, d_ff, dtype))(
            jax.random.split(k2, n_experts)
        ),
        "w_out": jax.vmap(lambda k: init_linear(k, d_ff, d_model, dtype))(
            jax.random.split(k3, n_experts)
        ),
    }
    if shared_expert:
        from repro.models.layers import mlp_init

        p["shared"] = mlp_init(ks, d_model, d_ff, act, dtype)
    return p


def dispatch_group_size(d_ff: int, top_k: int, seq_len: int,
                        capacity_factor: float = 1.25) -> int:
    """One-hot dispatch costs S·(S·k·cf)·D per group (quadratic in group
    size) while expert compute is S·k·6·D·F — so cap the group size at
    ~0.6·F/cf to keep dispatch ≲10% of expert FLOPs (GShard sizing)."""
    target = max(int(0.6 * d_ff / capacity_factor), 128)
    g = 128
    while g * 2 <= min(target, 4096):
        g *= 2
    return min(g, max(seq_len, 1))


def moe_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    n_experts: int,
    top_k: int,
    act: str,
    capacity_factor: float = 1.25,
    constrain: Constrain = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,D), aux_loss scalar).

    GShard-style grouped dispatch: the sequence is split into groups of
    ``dispatch_group_size`` tokens; capacity is per group. Keeps the
    dispatch one-hot (B,G,g,E,C) linear in sequence length.
    """
    B, S, D = x.shape
    f = activation(act)
    logits = (x.astype(jnp.float32) @ p["router"])  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gating
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (B,S,k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )  # renormalize among chosen

    # Switch aux loss: E * sum_e (fraction_tokens_e * mean_prob_e)
    sel_onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.float32)  # (B,S,k,E)
    tokens_per_expert = sel_onehot.sum((1, 2)) / (S * top_k)  # (B,E)
    mean_prob = probs.mean(1)  # (B,E)
    aux = (tokens_per_expert * mean_prob).sum(-1).mean() * n_experts

    # ---- grouped capacity dispatch
    d_ff = p["w_in"].shape[-1]
    g = dispatch_group_size(d_ff, top_k, S, capacity_factor)
    pad = (-S) % g
    if pad:
        x_p = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        sel_p = jnp.pad(sel_onehot, ((0, 0), (0, pad), (0, 0), (0, 0)))
        gate_p = jnp.pad(gate_vals, ((0, 0), (0, pad), (0, 0)))
    else:
        x_p, sel_p, gate_p = x, sel_onehot, gate_vals
    Sp = S + pad
    G = Sp // g
    capacity = max(int(capacity_factor * g * top_k / n_experts), 1)

    sel_g = sel_p.reshape(B, G, g, top_k, n_experts)
    # rank of each (token, k) among same-expert selections within the group
    flat = sel_g.reshape(B, G, g * top_k, n_experts)
    pos = (jnp.cumsum(flat, axis=2) - flat).reshape(
        B, G, g, top_k, n_experts
    )
    within = pos < capacity
    cap_oh = jax.nn.one_hot(
        jnp.where(within, pos, capacity).astype(jnp.int32),
        capacity + 1, dtype=jnp.float32,
    )[..., :capacity]  # (B,G,g,k,E,C)
    dispatch = (sel_g[..., None] * cap_oh).sum(3)  # (B,G,g,E,C)
    combine = ((sel_g * gate_p.reshape(B, G, g, top_k)[..., None])[..., None]
               * cap_oh).sum(3)  # (B,G,g,E,C)

    xg = x_p.reshape(B, G, g, D)
    xin = jnp.einsum("bnsec,bnsd->bnecd", dispatch.astype(x.dtype), xg)
    if constrain is not None:
        xin = constrain(xin, "experts")
    h = jnp.einsum("bnecd,edf->bnecf", xin, p["w_in"])
    gt = jnp.einsum("bnecd,edf->bnecf", xin, p["w_gate"])
    h = f(gt) * h
    if constrain is not None:
        h = constrain(h, "experts_ff")
    eo = jnp.einsum("bnecf,efd->bnecd", h, p["w_out"])  # (B,G,E,C,D)
    out = jnp.einsum("bnsec,bnecd->bnsd", combine.astype(x.dtype), eo)
    out = out.reshape(B, Sp, D)[:, :S]

    if "shared" in p:
        from repro.models.layers import mlp_apply

        out = out + mlp_apply(p["shared"], x, act, constrain)
    return out, aux.astype(jnp.float32)
