"""GQA attention: chunked-causal prefill + single-token cached decode.

Design points (TPU-shaped):
* prefill uses query-chunked attention (``lax.map`` over q blocks) so the
  score matrix never materializes at (S, S) — flash-attention's memory
  behavior expressed at the XLA level; block size 512 aligns to the MXU.
* decode attends one new token against a fixed-capacity KV cache.
* sliding-window layers keep a RING-BUFFER cache of size ``window`` —
  this is what makes gemma3's long_500k decode O(window) in memory for
  local layers (the paper-style liveness argument applied to KV state).
* GQA: kv heads broadcast to q heads via reshape (G groups).
* optional qk-norm (Qwen3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, init_rms, rms_norm, rope

Constrain = Callable[[jax.Array, str], jax.Array] | None
NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_linear(kq, d_model, n_heads * head_dim, dtype),
        "wk": init_linear(kk, d_model, n_kv * head_dim, dtype),
        "wv": init_linear(kv, d_model, n_kv * head_dim, dtype),
        "wo": init_linear(ko, n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms(head_dim)
        p["k_norm"] = init_rms(head_dim)
    return p


def _project_qkv(p, x, n_heads, n_kv, head_dim, positions, theta, eps):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,H,D), k: (B,T,KV,D) -> scores (B,H,S,T) with GQA broadcast."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", q, k)
    return s.reshape(B, KV * G, S, k.shape[1])


def _gqa_out(probs, v):
    """probs: (B,H,S,T), v: (B,T,KV,D) -> (B,S,H*D)."""
    B, H, S, T = probs.shape
    KV = v.shape[2]
    G = H // KV
    p = probs.reshape(B, KV, G, S, T)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, S, H * v.shape[-1])


def attn_prefill(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int | None,
    eps: float = 1e-6,
    q_chunk: int = 512,
    constrain: Constrain = None,
    positions: jax.Array | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Causal (optionally windowed) attention over the full sequence.
    Returns (out (B,S,H*D), (k_cache, v_cache))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, positions, theta, eps)
    if constrain is not None:
        q = constrain(q, "heads")
        k = constrain(k, "kv_heads")
        v = constrain(v, "kv_heads")
    scale = 1.0 / np.sqrt(head_dim)

    # Unrolled causal K-slicing halves score traffic but lets XLA overlap
    # chunk buffers (peak-memory regression at 32k) — so unroll only for
    # moderate S; long sequences use the sequential masked map (§Perf log).
    causal_unroll = window is None and S <= 8192
    if causal_unroll:
        q_chunk = max(q_chunk, -(-S // 16))  # bound the unroll at 16 bodies
    C = min(q_chunk, S)
    n_chunks = -(-S // C)
    pad = n_chunks * C - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, C, n_heads, head_dim).transpose(1, 0, 2, 3, 4)

    def _attend(qi, ki, vi, qpos, kpos):
        """qi (B,C,H,D) vs ki/vi (B,Lk,KV,D) with position masks."""
        s = _gqa_scores(qi, ki) * scale  # (B,H,C,Lk)
        mask = kpos[:, None, :] <= qpos[..., None]  # (B,C,Lk)
        if window is not None:
            mask = mask & (kpos[:, None, :] > qpos[..., None] - window)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        return _gqa_out(probs, vi)  # (B,C,H*D)

    if window is not None and S > C:
        # Sliding-window: each q chunk only needs the last `window`+C keys.
        # Static slice length + dynamic start keeps lax.map applicable —
        # 32k prefill with a 1k window touches Lk=1.5k keys per 512-chunk
        # instead of all 32k (§Perf: local-layer score traffic ÷ ~21).
        Lk = min(S, (-(-(window - 1) // C) + 1) * C)

        def one_chunk(args):
            qi, start = args
            qpos = (start + jnp.arange(C))[None, :]
            k_start = jnp.clip(start + C - Lk, 0, S - Lk)
            ki = jax.lax.dynamic_slice_in_dim(k, k_start, Lk, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, k_start, Lk, axis=1)
            kpos = (k_start + jnp.arange(Lk))[None, :]
            return _attend(qi, ki, vi, qpos, kpos)

        starts = jnp.arange(n_chunks) * C
        outs = jax.lax.map(one_chunk, (qc, starts))
        out = outs.transpose(1, 0, 2, 3)
    elif causal_unroll and S > C:
        # Causal: chunk i attends keys [0, (i+1)·C) — an unrolled loop with
        # static per-chunk key lengths halves score FLOPs+bytes vs masking
        # a full (C, S) tile (§Perf). Chunk count is bounded by q_chunk
        # sizing above (≤ 16 bodies).
        outs = []
        kT = jnp.arange(S)[None, :]
        for i in range(n_chunks):
            hi = min((i + 1) * C, S)
            qpos = (i * C + jnp.arange(C))[None, :]
            outs.append(
                _attend(qc[i], k[:, :hi], v[:, :hi], qpos, kT[:, :hi])
            )
        out = jnp.stack(outs, axis=1)  # (B, n_chunks, C, H*D)
    elif S > C:
        # long-S causal: sequential masked map (flat memory profile)
        def one_chunk(args):
            qi, start = args
            qpos = (start + jnp.arange(C))[None, :]
            return _attend(qi, k, v, qpos, jnp.arange(S)[None, :])

        starts = jnp.arange(n_chunks) * C
        outs = jax.lax.map(one_chunk, (qc, starts))
        out = outs.transpose(1, 0, 2, 3)
    else:
        qpos = jnp.arange(S)[None, :]
        out = _attend(q, k, v, qpos, qpos)[:, None]
    out = out.reshape(B, n_chunks * C, n_heads * head_dim)[:, :S]
    out = out @ p["wo"]
    if window is not None:
        # ring-buffer cache: last `window` keys/values, slot i holds
        # position (S - window + i) when S >= window (see decode)
        W = window
        if S >= W:
            k_c, v_c = k[:, S - W :], v[:, S - W :]
        else:
            k_c = jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
            v_c = jnp.pad(v, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        # roll so that cache slot = position % W  (ring invariant)
        shift = jnp.asarray((S - W) % W if S >= W else 0)
        k_c = jnp.roll(k_c, shift=shift, axis=1)
        v_c = jnp.roll(v_c, shift=shift, axis=1)
        return out, (k_c, v_c)
    return out, (k, v)


def attn_decode(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: tuple[jax.Array, jax.Array],  # (B, T, KV, D) x2; T = cap or window
    pos: jax.Array,  # int32 scalar OR (B,) — per-slot positions (0-based)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int | None,
    eps: float = 1e-6,
    constrain: Constrain = None,
    active: jax.Array | None = None,  # (B,) bool — continuous batching mask
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode; returns (out (B,1,D_model-in), new cache).

    ``pos`` may be a vector for continuous batching: every batch row
    advances at its own position (scatter into its own cache row).
    Rows with ``active == False`` leave their cache untouched.
    """
    B = x.shape[0]
    k_cache, v_cache = cache
    T = k_cache.shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, pos_b[:, None], theta, eps)
    slot_b = pos_b % T if window is not None else jnp.minimum(pos_b, T - 1)
    if active is not None:
        slot_b = jnp.where(active, slot_b, T)  # T is OOB -> dropped
    # scatter one row per batch element (O(1) cache-bytes touched, unlike a
    # one-hot masked rewrite of the full cache)
    rows = jnp.arange(B)
    k_cache = k_cache.at[rows, slot_b].set(k[:, 0], mode="drop")
    v_cache = v_cache.at[rows, slot_b].set(v[:, 0], mode="drop")
    if constrain is not None:
        k_cache = constrain(k_cache, "kv_heads")
        v_cache = constrain(v_cache, "kv_heads")
    scale = 1.0 / np.sqrt(head_dim)
    s = _gqa_scores(q, k_cache) * scale  # (B,H,1,T)
    idx = jnp.arange(T)[None, None, None, :]
    pb = pos_b[:, None, None, None]
    if window is None:
        mask = idx <= pb
    else:
        # slot i holds position: the largest p <= pos with p % T == i
        slot_pos = pb - ((pb - idx) % T)
        mask = (slot_pos >= 0) & (slot_pos <= pb) & (slot_pos > pb - window)
    s = jnp.where(mask, s, NEG_INF)
    probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v_cache)  # (B,1,H*D)
    return out @ p["wo"], (k_cache, v_cache)


def attn_decode_kernel(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: tuple[jax.Array, jax.Array],
    pos: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int | None,
    eps: float = 1e-6,
    constrain: Constrain = None,
    active: jax.Array | None = None,
    interpret: bool = True,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """attn_decode with the Pallas flash_decode kernel as the attention
    core (single-pass K/V streaming; see kernels/flash_decode.py). Global
    attention only — ring-buffer window layers need per-slot position
    masks the kernel does not model. ``interpret=True`` on CPU."""
    from repro.kernels.flash_decode import flash_decode

    if window is not None:
        return attn_decode(
            p, x, cache, pos, n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
            theta=theta, window=window, eps=eps, constrain=constrain,
            active=active,
        )
    B = x.shape[0]
    k_cache, v_cache = cache
    T = k_cache.shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, pos_b[:, None], theta, eps)
    slot_b = jnp.minimum(pos_b, T - 1)
    if active is not None:
        slot_b = jnp.where(active, slot_b, T)
    rows = jnp.arange(B)
    k_cache = k_cache.at[rows, slot_b].set(k[:, 0], mode="drop")
    v_cache = v_cache.at[rows, slot_b].set(v[:, 0], mode="drop")
    G = n_heads // n_kv
    q_k = q.reshape(B, 1, n_kv, G, head_dim)[:, 0].transpose(0, 1, 2, 3)
    lengths = jnp.minimum(pos_b + 1, T).astype(jnp.int32)
    o = flash_decode(q_k, k_cache, v_cache, lengths, interpret=interpret)
    out = o.reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"], (k_cache, v_cache)


def cross_attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype) -> dict:
    return attn_init(key, d_model, n_heads, n_kv, head_dim, False, dtype)


def cross_attn(
    p: dict,
    x: jax.Array,  # (B, S, D) decoder side
    enc_kv: tuple[jax.Array, jax.Array],  # precomputed (B, T, KV, D) x2
    *,
    n_heads: int,
    head_dim: int,
    constrain: Constrain = None,
) -> jax.Array:
    B, S, _ = x.shape
    k, v = enc_kv
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    if constrain is not None:
        q = constrain(q, "heads")
    s = _gqa_scores(q, k) / np.sqrt(head_dim)
    probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    return _gqa_out(probs, v) @ p["wo"]


def encode_kv(p: dict, enc_out: jax.Array, n_kv: int, head_dim: int):
    """Project encoder output once into cross-attention K/V."""
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, n_kv, head_dim)
    v = (enc_out @ p["wv"]).reshape(B, T, n_kv, head_dim)
    return k, v
