"""Uniform model API over all 10 architectures.

``Model.for_config(cfg)`` returns an object with:
  init(key)                                   -> params
  forward(params, batch, constrain, remat)    -> (logits, aux)
  prefill(params, batch, constrain)           -> (last_logits, caches)
  decode_step(params, token, caches, pos, constrain) -> (logits, caches)
  init_cache(batch, cache_len)                -> caches
  input_specs(shape)                          -> ShapeDtypeStruct batch

Modality frontends (VLM patches / audio frames) are stubs per the
assignment: ``input_specs`` includes the precomputed embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    @staticmethod
    def for_config(cfg: ArchConfig) -> "Model":
        return EncDecModel(cfg) if cfg.family == "audio" else DecoderModel(cfg)

    # ----- shared helpers
    def supports_shape(self, shape: ShapeSpec) -> tuple[bool, str]:
        if shape.name == "long_500k" and not self.cfg.supports_long_context:
            return False, "pure full-attention arch; long_500k needs sub-quadratic"
        return True, ""


class DecoderModel(Model):
    def init(self, key):
        return transformer.init_params(self.cfg, key)

    def _prefix(self, batch):
        return batch.get("prefix_embeds")

    def forward(self, params, batch, constrain=None, remat=False):
        return transformer.forward(
            params, self.cfg, batch["tokens"], self._prefix(batch),
            constrain=constrain, remat=remat,
        )

    def prefill(self, params, batch, constrain=None):
        return transformer.prefill(
            params, self.cfg, batch["tokens"], self._prefix(batch),
            constrain=constrain,
        )

    def decode_step(self, params, token, caches, pos, constrain=None, active=None):
        return transformer.decode_step(
            params, self.cfg, token, caches, pos, constrain=constrain,
            active=active,
        )

    def init_cache(self, batch: int, cache_len: int):
        return transformer.init_cache(self.cfg, batch, cache_len)

    def reset_slots(self, caches, keep):
        return transformer.reset_slots(caches, keep)

    def input_specs(self, shape: ShapeSpec) -> dict[str, Any]:
        cfg = self.cfg
        B = shape.global_batch
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        S = shape.seq_len
        specs: dict[str, Any] = {}
        if cfg.n_prefix_tokens:
            S = max(S - cfg.n_prefix_tokens, 1)
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_tokens, cfg.d_model), dt
            )
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            # labels cover the TEXT positions only (prefix positions have
            # no next-token target); see make_loss_fn.
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs


class EncDecModel(Model):
    def init(self, key):
        return encdec.init_params(self.cfg, key)

    def forward(self, params, batch, constrain=None, remat=False):
        return encdec.forward(
            params, self.cfg, batch["tokens"], batch["frames"],
            constrain=constrain, remat=remat,
        )

    def prefill(self, params, batch, constrain=None):
        return encdec.prefill(
            params, self.cfg, batch["tokens"], batch["frames"], constrain=constrain
        )

    def decode_step(self, params, token, caches, pos, constrain=None, active=None):
        return encdec.decode_step(
            params, self.cfg, token, caches, pos, constrain=constrain,
            active=active,
        )

    def init_cache(self, batch: int, cache_len: int):
        enc_len = max(cache_len // self.cfg.enc_len_ratio, 1)
        return encdec.init_cache(self.cfg, batch, cache_len, enc_len)

    def reset_slots(self, caches, keep):
        # all encdec cache leaves are (L, B, ...): batch on axis 1
        def mask(leaf):
            shape = [1] * leaf.ndim
            shape[1] = leaf.shape[1]
            return leaf * keep.astype(leaf.dtype).reshape(shape)

        return jax.tree_util.tree_map(mask, caches)

    def input_specs(self, shape: ShapeSpec) -> dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        S_enc = max(S // cfg.enc_len_ratio, 1)
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "frames": jax.ShapeDtypeStruct((B, S_enc, cfg.d_model), dt),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return specs
