"""Config-driven decoder stack: dense / MoE / SSM / hybrid / VLM prefix.

The layer program is ``period × n_periods + remainder`` (configs/base.py).
Scanned period params are stacked with a leading ``n_periods`` axis, so the
HLO contains ONE period body regardless of depth — nemotron's 96 layers
compile as a 96-iteration scan of a single block.

Three entry points per model:
  * ``forward``      — full-sequence logits (training fwd)
  * ``prefill``      — full-sequence logits + per-layer caches
  * ``decode_step``  — one token with caches (serve_step for decode shapes)

Caches are pytrees mirroring the period structure:
  attn layers   -> (k, v) with capacity ``cache_len`` (ring buffer of
                   ``window`` for sliding-window layers)
  mamba layers  -> (conv_state, ssm_state)
Zamba2-style ``shared_attn`` blocks keep their own (k, v) at 2·d_model
width; their params are shared across all insertions (closure, not
scanned).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import init_linear, init_rms, mlp_apply, mlp_init, rms_norm

Constrain = Callable[[jax.Array, str], jax.Array] | None


# --------------------------------------------------------------- init


def _layer_init(key, cfg: ArchConfig, spec: LayerSpec) -> dict:
    keys = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {"ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model)}
    if spec.mixer == "attn":
        p["attn"] = attn.attn_init(
            keys[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm, dtype,
        )
    elif spec.mixer == "mamba":
        p["mamba"] = ssm_mod.mamba_init(
            keys[0], cfg.d_model, expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim, ngroups=cfg.ssm_groups,
            dstate=cfg.ssm_state, conv=cfg.ssm_conv, dtype=dtype,
        )
    if spec.ffn == "mlp":
        p["mlp"] = mlp_init(keys[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif spec.ffn == "moe":
        p["moe"] = moe_mod.moe_init(
            keys[1], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.act,
            cfg.shared_expert, dtype,
        )
    return p


def _shared_attn_init(key, cfg: ArchConfig) -> dict:
    """Zamba2 shared block: concat(h, emb0) -> attn+MLP at 2*d_model,
    projected back to d_model."""
    dtype = jnp.dtype(cfg.dtype)
    d2 = 2 * cfg.d_model
    hd = d2 // cfg.shared_attn_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln": init_rms(d2),
        "attn": attn.attn_init(
            k1, d2, cfg.shared_attn_heads, cfg.shared_attn_heads, hd, False, dtype
        ),
        "mlp": mlp_init(k2, d2, cfg.d_ff, cfg.act, dtype),
        "ln2": init_rms(d2),
        "out": init_linear(k3, d2, cfg.d_model, dtype),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_per, k_rem, k_shared, k_out = jax.random.split(key, 5)
    emb_scale = 1.0 / np.sqrt(cfg.d_model)
    params: dict[str, Any] = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), jnp.float32)
            * emb_scale
        ).astype(dtype),
        "ln_f": init_rms(cfg.d_model),
    }
    # scanned period params: one pytree per period position, each stacked
    # over n_periods
    period_params = []
    pkeys = jax.random.split(k_per, max(len(cfg.period), 1))
    for i, spec in enumerate(cfg.period):
        stacked = jax.vmap(lambda k: _layer_init(k, cfg, spec))(
            jax.random.split(pkeys[i], cfg.n_periods)
        )
        period_params.append(stacked)
    params["period"] = tuple(period_params)
    rkeys = jax.random.split(k_rem, max(len(cfg.remainder), 1))
    params["remainder"] = tuple(
        _layer_init(rkeys[i], cfg, spec) for i, spec in enumerate(cfg.remainder)
    )
    if any(s.shared_attn for s in (*cfg.period, *cfg.remainder)):
        params["shared_attn"] = _shared_attn_init(k_shared, cfg)
    return params


# --------------------------------------------------------------- blocks


def _apply_shared_attn(sp, h, emb0, cfg, constrain, cache=None, pos=None, active=None):
    """Returns (delta, new_cache)."""
    x = jnp.concatenate([h, emb0], axis=-1)
    x = rms_norm(x, sp["ln"], cfg.rms_eps)
    d2 = 2 * cfg.d_model
    hd = d2 // cfg.shared_attn_heads
    if cache is None:
        a, kv = attn.attn_prefill(
            sp["attn"], x, n_heads=cfg.shared_attn_heads,
            n_kv=cfg.shared_attn_heads, head_dim=hd, theta=cfg.rope_theta,
            window=None, eps=cfg.rms_eps, constrain=constrain,
        )
    else:
        a, kv = attn.attn_decode(
            sp["attn"], x, cache, pos, n_heads=cfg.shared_attn_heads,
            n_kv=cfg.shared_attn_heads, head_dim=hd, theta=cfg.rope_theta,
            window=None, eps=cfg.rms_eps, constrain=constrain, active=active,
        )
    y = x + a
    y = y + mlp_apply(sp["mlp"], rms_norm(y, sp["ln2"], cfg.rms_eps), cfg.act, constrain)
    return y @ sp["out"], kv


def _block(
    p: dict,
    spec: LayerSpec,
    cfg: ArchConfig,
    h: jax.Array,
    emb0: jax.Array,
    shared_p: dict | None,
    constrain: Constrain,
    cache: Any = None,
    pos: Any = None,
    decode: bool = False,
    active: jax.Array | None = None,
) -> tuple[jax.Array, Any, jax.Array]:
    """Apply one layer. Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    cache = cache or {}
    if spec.shared_attn:
        delta, kv = _apply_shared_attn(
            shared_p, h, emb0, cfg, constrain,
            cache.get("shared") if decode else None,
            pos if decode else None,
            active if decode else None,
        )
        h = h + delta
        new_cache["shared"] = kv
    if spec.mixer == "attn":
        x = rms_norm(h, p["ln1"], cfg.rms_eps)
        kwargs = dict(
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, theta=cfg.rope_theta,
            window=spec.window, eps=cfg.rms_eps, constrain=constrain,
        )
        if decode:
            a, kv = attn.attn_decode(p["attn"], x, cache["attn"], pos,
                                     active=active, **kwargs)
        else:
            a, kv = attn.attn_prefill(p["attn"], x, **kwargs)
        h = h + a
        new_cache["attn"] = kv
    elif spec.mixer == "mamba":
        x = rms_norm(h, p["ln1"], cfg.rms_eps)
        kwargs = dict(
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            ngroups=cfg.ssm_groups, dstate=cfg.ssm_state, conv=cfg.ssm_conv,
            eps=cfg.rms_eps, constrain=constrain,
        )
        if decode:
            m, st = ssm_mod.mamba_decode(p["mamba"], x, cache["mamba"],
                                         active=active, **kwargs)
        else:
            m, st = ssm_mod.mamba_prefill(p["mamba"], x, **kwargs)
        h = h + m
        new_cache["mamba"] = st
    if spec.ffn == "mlp":
        h = h + mlp_apply(p["mlp"], rms_norm(h, p["ln2"], cfg.rms_eps), cfg.act, constrain)
    elif spec.ffn == "moe":
        delta, aux = moe_mod.moe_apply(
            p["moe"], rms_norm(h, p["ln2"], cfg.rms_eps),
            n_experts=cfg.n_experts, top_k=cfg.top_k, act=cfg.act,
            capacity_factor=cfg.capacity_factor, constrain=constrain,
        )
        h = h + delta
    if constrain is not None:
        h = constrain(h, "hidden")
    return h, new_cache, aux


# --------------------------------------------------------------- stacks


def _empty_cache_for_spec(
    spec: LayerSpec, cfg: ArchConfig, batch: int, cache_len: int, dtype
) -> dict:
    c: dict[str, Any] = {}
    if spec.shared_attn:
        d2 = 2 * cfg.d_model
        hd = d2 // cfg.shared_attn_heads
        c["shared"] = (
            jnp.zeros((batch, cache_len, cfg.shared_attn_heads, hd), dtype),
            jnp.zeros((batch, cache_len, cfg.shared_attn_heads, hd), dtype),
        )
    if spec.mixer == "attn":
        T = min(spec.window, cache_len) if spec.window else cache_len
        hd = cfg.resolved_head_dim
        c["attn"] = (
            jnp.zeros((batch, T, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((batch, T, cfg.n_kv_heads, hd), dtype),
        )
    elif spec.mixer == "mamba":
        d_inner, nheads, conv_dim = ssm_mod.ssm_dims(
            cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups,
            cfg.ssm_state,
        )
        c["mamba"] = (
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
            jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        )
    return c


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """Pytree of decode caches; scanned positions stacked over n_periods."""
    dtype = jnp.dtype(cfg.dtype)
    period = tuple(
        jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)),
            _empty_cache_for_spec(spec, cfg, batch, cache_len, dtype),
        )
        for spec in cfg.period
    )
    remainder = tuple(
        _empty_cache_for_spec(spec, cfg, batch, cache_len, dtype)
        for spec in cfg.remainder
    )
    return {"period": period, "remainder": remainder}


def _run_stack(
    params: dict,
    cfg: ArchConfig,
    h: jax.Array,
    emb0: jax.Array,
    constrain: Constrain,
    caches: dict | None,
    pos: Any,
    decode: bool,
    remat: bool = False,
    active: jax.Array | None = None,
) -> tuple[jax.Array, dict | None, jax.Array]:
    shared_p = params.get("shared_attn")

    def period_body(carry, xs):
        h, aux = carry
        layer_params = xs[: len(cfg.period)]
        layer_caches = xs[len(cfg.period) :] if caches is not None else [None] * len(cfg.period)
        new_caches = []
        for spec, lp, lc in zip(cfg.period, layer_params, layer_caches):
            h, nc, a = _block(
                lp, spec, cfg, h, emb0, shared_p, constrain, lc, pos, decode,
                active,
            )
            new_caches.append(nc)
            aux = aux + a
        return (h, aux), tuple(new_caches)

    if remat:
        period_body = jax.checkpoint(period_body)

    aux0 = jnp.zeros((), jnp.float32)
    xs: tuple = tuple(params["period"])
    if caches is not None:
        xs = xs + tuple(caches["period"])
    if cfg.n_periods > 0 and len(cfg.period) > 0:
        (h, aux), new_period_caches = jax.lax.scan(period_body, (h, aux0), xs)
    else:
        new_period_caches = tuple()
        aux = aux0
    new_rem_caches = []
    for i, spec in enumerate(cfg.remainder):
        lc = caches["remainder"][i] if caches is not None else None
        h, nc, a = _block(
            params["remainder"][i], spec, cfg, h, emb0, shared_p, constrain,
            lc, pos, decode, active,
        )
        new_rem_caches.append(nc)
        aux = aux + a
    out_caches = None
    if caches is not None or not decode:
        out_caches = {"period": new_period_caches, "remainder": tuple(new_rem_caches)}
    return h, out_caches, aux


def reset_slots(caches: dict, keep: jax.Array) -> dict:
    """Zero cache rows where ``keep[b]`` is False (slot recycling: stale
    SSM states / conv windows must not leak into the next request; stale
    attention entries are already hidden by position masks but are zeroed
    too for hygiene). Period caches carry batch on axis 1 (after the
    n_periods axis), remainder caches on axis 0."""

    def mask(leaf, axis):
        shape = [1] * leaf.ndim
        shape[axis] = leaf.shape[axis]
        return leaf * keep.astype(leaf.dtype).reshape(shape)

    return {
        "period": jax.tree_util.tree_map(lambda x: mask(x, 1), caches["period"]),
        "remainder": jax.tree_util.tree_map(
            lambda x: mask(x, 0), caches["remainder"]
        ),
    }


def grow_caches(cfg: ArchConfig, caches: dict, new_len: int) -> dict:
    """Pad attention caches (axis=1 of (…, B, T, KV, hd)) to ``new_len``
    so decode can continue past the prefill length. Ring-buffer (window)
    caches and mamba states keep their size."""

    def pad_kv(kv, keep: int | None):
        k, v = kv
        T = k.shape[-3]
        target = min(keep, new_len) if keep else new_len
        if T >= target:
            return (k, v)
        pad = [(0, 0)] * k.ndim
        pad[-3] = (0, target - T)
        return (jnp.pad(k, pad), jnp.pad(v, pad))

    def grow_spec(spec: LayerSpec, c: dict) -> dict:
        out = dict(c)
        if "attn" in c:
            out["attn"] = pad_kv(c["attn"], spec.window)
        if "shared" in c:
            out["shared"] = pad_kv(c["shared"], None)
        return out

    return {
        "period": tuple(
            grow_spec(spec, c) for spec, c in zip(cfg.period, caches["period"])
        ),
        "remainder": tuple(
            grow_spec(spec, c) for spec, c in zip(cfg.remainder, caches["remainder"])
        ),
    }


# --------------------------------------------------------------- API


def embed_tokens(params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    return params["embed"][tokens]


def logits_from_hidden(params, cfg: ArchConfig, h: jax.Array, constrain: Constrain):
    h = rms_norm(h, params["ln_f"], cfg.rms_eps)
    logits = h @ params["embed"].T
    if constrain is not None:
        logits = constrain(logits, "logits")
    return logits


def forward(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S) int32
    prefix_embeds: jax.Array | None = None,  # (B, P, D) VLM stub output
    constrain: Constrain = None,
    remat: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence logits (B, S_total, vocab) + aux loss."""
    h = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    if constrain is not None:
        h = constrain(h, "hidden")
    emb0 = h
    h, _, aux = _run_stack(
        params, cfg, h, emb0, constrain, None, None, decode=False, remat=remat
    )
    return logits_from_hidden(params, cfg, h, constrain), aux


def prefill(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    prefix_embeds: jax.Array | None = None,
    constrain: Constrain = None,
) -> tuple[jax.Array, dict]:
    """Returns (logits for the LAST position (B, vocab), caches)."""
    h = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    emb0 = h
    h, caches, _ = _run_stack(
        params, cfg, h, emb0, constrain, None, None, decode=False
    )
    return logits_from_hidden(params, cfg, h[:, -1:], constrain)[:, 0], caches


def decode_step(
    params: dict,
    cfg: ArchConfig,
    token: jax.Array,  # (B, 1) int32
    caches: dict,
    pos: jax.Array,  # int32 scalar or (B,) per-slot positions
    constrain: Constrain = None,
    active: jax.Array | None = None,  # (B,) continuous-batching mask
) -> tuple[jax.Array, dict]:
    """serve_step: ONE new token against the caches. Returns (logits
    (B, vocab), new caches)."""
    h = embed_tokens(params, cfg, token)
    # Zamba2's shared block concatenates the ORIGINAL embedding; during
    # decode that is the current token's embedding.
    emb0 = h
    if constrain is not None:
        h = constrain(h, "hidden")
    h, new_caches, _ = _run_stack(
        params, cfg, h, emb0, constrain, caches, pos, decode=True,
        active=active,
    )
    return logits_from_hidden(params, cfg, h, constrain)[:, 0], new_caches
