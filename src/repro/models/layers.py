"""Shared neural-net layers (pure functions over param pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to
    (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rot1, rot2, x[..., 2 * half :]], axis=-1)
    return out.astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":  # Nemotron-4 (arXiv:2402.16819)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def init_linear(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32, -scale, scale)).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": init_linear(k2, d_ff, d_model, dtype)}
    if act == "sq_relu":  # no gate (Nemotron style)
        p["w_in"] = init_linear(k1, d_model, d_ff, dtype)
    else:  # gated (SwiGLU/GeGLU)
        p["w_in"] = init_linear(k1, d_model, d_ff, dtype)
        p["w_gate"] = init_linear(k3, d_model, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, act: str, constrain=None) -> jax.Array:
    f = activation(act)
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = f(x @ p["w_gate"]) * h
    else:
        h = f(h)
    if constrain is not None:
        h = constrain(h, "ffn")
    return h @ p["w_out"]
