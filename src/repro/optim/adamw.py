"""Minimal AdamW with fp32 master accumulators + cosine schedule.

Pure-pytree implementation (no optax dependency): state = (step, m, v).
Accumulators are fp32 regardless of param dtype; update is cast back.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(
    cfg: AdamWConfig, params: Any, grads: Any, state: dict
) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
