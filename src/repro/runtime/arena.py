"""Flat memory arena materializing an Offset Calculation plan (paper §5).

One ``bytearray``-backed numpy buffer of ``total_size`` bytes; every
intermediate tensor is a zero-copy view at its planned offset. This is the
TFLite-style deployment of the paper's result: allocate once, reuse across
the whole inference — and across inferences.

The arena is deliberately decoupled from the planner: it consumes an
:class:`ArenaLayout` (offsets + per-tensor slot sizes + total), which can
come from a freshly computed :class:`~repro.core.planner.MemoryPlan`,
straight from a precompiled :class:`~repro.core.artifact.PlanBundle`'s
stored offsets, or from the cross-step
:class:`~repro.core.unified.StatePlan` (slot/KV layout) — both arenas of
a :class:`~repro.core.unified.UnifiedPlan` materialize from that one
object (:meth:`ArenaLayout.from_unified`). The serving path never needs
planner objects to materialize its memory.

Two arena implementations share the layout contract: the numpy
:class:`Arena` (host buffers — the executor's deployment path) and the
jax :class:`DeviceArena` (one flat ``uint8`` device buffer whose views
are carved with ``lax.dynamic_slice`` + bitcast — the engine's
cross-step state residency, see ``runtime/residency.py``).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping

import numpy as np

if TYPE_CHECKING:
    from repro.core.artifact import PlanBundle
    from repro.core.planner import MemoryPlan
    from repro.core.unified import StatePlan, UnifiedPlan


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Everything an arena needs: where each tensor lives and how big the
    buffer is. ``sizes`` are the *planned slot* sizes (alignment-rounded)
    used for bounds enforcement."""

    total_size: int
    offsets: Mapping[int, int]  # tensor_id -> byte offset
    sizes: Mapping[int, int]  # tensor_id -> planned slot bytes

    @staticmethod
    def from_plan(plan: "MemoryPlan") -> "ArenaLayout":
        return ArenaLayout(
            total_size=plan.total_size,
            offsets=dict(plan.offsets),
            sizes={r.tensor_id: r.size for r in plan.records},
        )

    @staticmethod
    def from_bundle(bundle: "PlanBundle") -> "ArenaLayout":
        """Materialize straight from a plan artifact's stored offsets."""
        return ArenaLayout.from_plan(bundle.plan)

    @staticmethod
    def from_state_plan(state: "StatePlan | None") -> "ArenaLayout":
        """Cross-step state arena: one dense tensor id per (slot, leaf)
        pair (``slot * n_leaves + leaf_index``), addressed through the
        plan's :meth:`~repro.core.unified.StatePlan.leaf_view_spec` — the
        same spec the device arena and the residency views consume.

        Unlike activation layouts, state regions must be pairwise
        DISJOINT (every slot's state is live across the whole decode), so
        this constructor validates non-overlap in addition to bounds."""
        if state is None:
            raise ValueError(
                "no cross-step state plan to materialize (state_plan is "
                "None — a v1 bundle ships only the activation half; "
                "recompile with launch/compile.py for a v2 bundle)"
            )
        offsets: dict[int, int] = {}
        sizes: dict[int, int] = {}
        for view in state.leaf_view_spec():
            offsets[view.tensor_id] = view.offset
            sizes[view.tensor_id] = view.slot_nbytes
        layout = ArenaLayout(
            total_size=state.total_size, offsets=offsets, sizes=sizes
        )
        layout.validate()
        layout.validate_disjoint()
        return layout

    @staticmethod
    def from_unified(
        plan: "UnifiedPlan",
    ) -> "tuple[ArenaLayout | None, ArenaLayout | None]":
        """Both arenas from one object: (activation, cross-step state)."""
        return plan.arena_layouts()

    def validate(self) -> None:
        """Every planned slot must lie inside the buffer — a corrupt or
        hand-edited artifact fails here, before any bytes are aliased."""
        for tid, off in self.offsets.items():
            size = self.sizes.get(tid, 0)
            if off < 0 or off + size > self.total_size:
                raise ValueError(
                    f"tensor {tid}: slot [{off}, {off + size}) outside "
                    f"arena of {self.total_size} B"
                )

    def validate_disjoint(self) -> None:
        """No two planned slots may share bytes. Activation layouts alias
        on purpose (disjoint lifetimes sharing memory IS the paper's
        win), so this is NOT part of :meth:`validate`; cross-step state
        regions are all live at once and must never overlap — a corrupt
        state plan fails here with the offending pair named."""
        spans = sorted(
            (off, off + self.sizes.get(tid, 0), tid)
            for tid, off in self.offsets.items()
        )
        for (s1, e1, t1), (s2, e2, t2) in zip(spans, spans[1:]):
            if s2 < e1:
                raise ValueError(
                    f"state regions overlap: tensor {t1} [{s1}, {e1}) and "
                    f"tensor {t2} [{s2}, {e2}) share bytes"
                )


class Arena:
    def __init__(self, layout: "ArenaLayout | MemoryPlan"):
        if not isinstance(layout, ArenaLayout):
            layout = ArenaLayout.from_plan(layout)
        layout.validate()
        self.layout = layout
        self.buf = np.zeros(max(layout.total_size, 1), dtype=np.uint8)
        self._sizes = layout.sizes

    @property
    def nbytes(self) -> int:
        return self.buf.nbytes

    def store(self, tensor_id: int, value: np.ndarray) -> np.ndarray:
        """Copy ``value``'s bytes to the tensor's planned slot; return a
        view aliasing arena memory (C-contiguous, same shape/dtype)."""
        off = self.layout.offsets[tensor_id]
        raw = np.ascontiguousarray(value)
        nbytes = raw.nbytes
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        dst = self.buf[off : off + nbytes]
        dst[:] = raw.reshape(-1).view(np.uint8)
        return self.view(tensor_id, raw.shape, raw.dtype)

    def view(self, tensor_id: int, shape, dtype) -> np.ndarray:
        off = self.layout.offsets[tensor_id]
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        # a too-large view would silently alias the NEXT tensor's planned
        # slot — enforce both the per-tensor slot size and the arena end
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: view of {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        if off + nbytes > self.buf.nbytes:
            raise ValueError(
                f"tensor {tensor_id}: view [{off}, {off + nbytes}) exceeds "
                f"arena of {self.buf.nbytes} B"
            )
        return (
            self.buf[off : off + nbytes]
            .view(np.dtype(dtype))
            .reshape(shape)
        )


class DeviceArena:
    """jax twin of :class:`Arena`: the same :class:`ArenaLayout` and the
    same bounds-checked view contract, but the backing store is a flat
    ``uint8`` device buffer threaded *functionally* — ``store`` returns a
    NEW buffer value instead of mutating, so it composes with jit; under
    a donated jit argument XLA updates the one physical allocation in
    place, which is exactly how the engine's decode step keeps the whole
    cross-step state in ONE device buffer across waves.

    All offsets/sizes are Python ints (the plan is static), so every
    ``dynamic_slice``/``dynamic_update_slice`` lowers to a static-index
    slice XLA can fuse or alias away.
    """

    def __init__(self, layout: "ArenaLayout"):
        layout.validate()
        self.layout = layout
        self._sizes = layout.sizes

    @property
    def nbytes(self) -> int:
        return max(self.layout.total_size, 1)

    def allocate(self):
        """A fresh zeroed device buffer of the arena's full size."""
        import jax.numpy as jnp

        return jnp.zeros((self.nbytes,), jnp.uint8)

    def _check(self, tensor_id: int, nbytes: int) -> int:
        off = self.layout.offsets[tensor_id]
        # same contract as Arena.view: an oversized view would silently
        # alias the NEXT tensor's planned slot
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: view of {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        if off + nbytes > self.layout.total_size:
            raise ValueError(
                f"tensor {tensor_id}: view [{off}, {off + nbytes}) exceeds "
                f"arena of {self.layout.total_size} B"
            )
        return off

    def view(self, buf, tensor_id: int, shape, dtype):
        """Read the tensor's planned bytes out of ``buf`` as a
        ``shape``/``dtype`` jax array (slice + bitcast + reshape)."""
        import jax
        import jax.numpy as jnp

        dt = jnp.dtype(dtype)
        nbytes = int(np.prod(shape)) * dt.itemsize
        off = self._check(tensor_id, nbytes)
        raw = jax.lax.dynamic_slice(buf, (off,), (nbytes,))
        if dt.itemsize > 1:
            raw = raw.reshape(-1, dt.itemsize)
        return jax.lax.bitcast_convert_type(raw, dt).reshape(shape)

    def store(self, buf, tensor_id: int, value):
        """Return a new buffer with ``value``'s bytes at the tensor's
        planned offset (functional twin of :meth:`Arena.store`)."""
        import jax
        import jax.numpy as jnp

        dt = jnp.dtype(value.dtype)
        nbytes = int(np.prod(value.shape)) * dt.itemsize
        off = self._check(tensor_id, nbytes)
        raw = jax.lax.bitcast_convert_type(value, jnp.uint8).reshape(-1)
        return jax.lax.dynamic_update_slice(buf, raw, (off,))
