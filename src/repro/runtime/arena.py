"""Flat memory arena materializing an Offset Calculation plan (paper §5).

One ``bytearray``-backed numpy buffer of ``plan.total_size`` bytes; every
intermediate tensor is a zero-copy view at its planned offset. This is the
TFLite-style deployment of the paper's result: allocate once, reuse across
the whole inference — and across inferences.
"""

from __future__ import annotations

import numpy as np

from repro.core.planner import MemoryPlan


class Arena:
    def __init__(self, plan: MemoryPlan):
        self.plan = plan
        self.buf = np.zeros(max(plan.total_size, 1), dtype=np.uint8)
        self._sizes = {r.tensor_id: r.size for r in plan.records}

    @property
    def nbytes(self) -> int:
        return self.buf.nbytes

    def store(self, tensor_id: int, value: np.ndarray) -> np.ndarray:
        """Copy ``value``'s bytes to the tensor's planned slot; return a
        view aliasing arena memory (C-contiguous, same shape/dtype)."""
        off = self.plan.offsets[tensor_id]
        raw = np.ascontiguousarray(value)
        nbytes = raw.nbytes
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        dst = self.buf[off : off + nbytes]
        dst[:] = raw.reshape(-1).view(np.uint8)
        return self.view(tensor_id, raw.shape, raw.dtype)

    def view(self, tensor_id: int, shape, dtype) -> np.ndarray:
        off = self.plan.offsets[tensor_id]
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        # a too-large view would silently alias the NEXT tensor's planned
        # slot — enforce both the per-tensor slot size and the arena end
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: view of {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        if off + nbytes > self.buf.nbytes:
            raise ValueError(
                f"tensor {tensor_id}: view [{off}, {off + nbytes}) exceeds "
                f"arena of {self.buf.nbytes} B"
            )
        return (
            self.buf[off : off + nbytes]
            .view(np.dtype(dtype))
            .reshape(shape)
        )
