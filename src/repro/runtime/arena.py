"""Flat memory arena materializing an Offset Calculation plan (paper §5).

One ``bytearray``-backed numpy buffer of ``total_size`` bytes; every
intermediate tensor is a zero-copy view at its planned offset. This is the
TFLite-style deployment of the paper's result: allocate once, reuse across
the whole inference — and across inferences.

The arena is deliberately decoupled from the planner: it consumes an
:class:`ArenaLayout` (offsets + per-tensor slot sizes + total), which can
come from a freshly computed :class:`~repro.core.planner.MemoryPlan`,
straight from a precompiled :class:`~repro.core.artifact.PlanBundle`'s
stored offsets, or from the cross-step
:class:`~repro.core.unified.StatePlan` (slot/KV layout) — both arenas of
a :class:`~repro.core.unified.UnifiedPlan` materialize from that one
object (:meth:`ArenaLayout.from_unified`). The serving path never needs
planner objects to materialize its memory.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping

import numpy as np

if TYPE_CHECKING:
    from repro.core.artifact import PlanBundle
    from repro.core.planner import MemoryPlan
    from repro.core.unified import StatePlan, UnifiedPlan


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Everything an arena needs: where each tensor lives and how big the
    buffer is. ``sizes`` are the *planned slot* sizes (alignment-rounded)
    used for bounds enforcement."""

    total_size: int
    offsets: Mapping[int, int]  # tensor_id -> byte offset
    sizes: Mapping[int, int]  # tensor_id -> planned slot bytes

    @staticmethod
    def from_plan(plan: "MemoryPlan") -> "ArenaLayout":
        return ArenaLayout(
            total_size=plan.total_size,
            offsets=dict(plan.offsets),
            sizes={r.tensor_id: r.size for r in plan.records},
        )

    @staticmethod
    def from_bundle(bundle: "PlanBundle") -> "ArenaLayout":
        """Materialize straight from a plan artifact's stored offsets."""
        return ArenaLayout.from_plan(bundle.plan)

    @staticmethod
    def from_state_plan(state: "StatePlan") -> "ArenaLayout":
        """Cross-step state arena: one dense tensor id per (slot, leaf)
        pair (``slot * n_leaves + leaf_index``), offsets straight from the
        slot/KV layout's concrete offsets."""
        offsets: dict[int, int] = {}
        sizes: dict[int, int] = {}
        for tid, _slot, leaf, off in state.flat_entries():
            offsets[tid] = off
            sizes[tid] = leaf.slot_nbytes
        return ArenaLayout(
            total_size=state.total_size, offsets=offsets, sizes=sizes
        )

    @staticmethod
    def from_unified(
        plan: "UnifiedPlan",
    ) -> "tuple[ArenaLayout | None, ArenaLayout | None]":
        """Both arenas from one object: (activation, cross-step state)."""
        return plan.arena_layouts()

    def validate(self) -> None:
        """Every planned slot must lie inside the buffer — a corrupt or
        hand-edited artifact fails here, before any bytes are aliased."""
        for tid, off in self.offsets.items():
            size = self.sizes.get(tid, 0)
            if off < 0 or off + size > self.total_size:
                raise ValueError(
                    f"tensor {tid}: slot [{off}, {off + size}) outside "
                    f"arena of {self.total_size} B"
                )


class Arena:
    def __init__(self, layout: "ArenaLayout | MemoryPlan"):
        if not isinstance(layout, ArenaLayout):
            layout = ArenaLayout.from_plan(layout)
        layout.validate()
        self.layout = layout
        self.buf = np.zeros(max(layout.total_size, 1), dtype=np.uint8)
        self._sizes = layout.sizes

    @property
    def nbytes(self) -> int:
        return self.buf.nbytes

    def store(self, tensor_id: int, value: np.ndarray) -> np.ndarray:
        """Copy ``value``'s bytes to the tensor's planned slot; return a
        view aliasing arena memory (C-contiguous, same shape/dtype)."""
        off = self.layout.offsets[tensor_id]
        raw = np.ascontiguousarray(value)
        nbytes = raw.nbytes
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        dst = self.buf[off : off + nbytes]
        dst[:] = raw.reshape(-1).view(np.uint8)
        return self.view(tensor_id, raw.shape, raw.dtype)

    def view(self, tensor_id: int, shape, dtype) -> np.ndarray:
        off = self.layout.offsets[tensor_id]
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        # a too-large view would silently alias the NEXT tensor's planned
        # slot — enforce both the per-tensor slot size and the arena end
        if nbytes > self._sizes[tensor_id]:
            raise ValueError(
                f"tensor {tensor_id}: view of {nbytes} B exceeds planned "
                f"{self._sizes[tensor_id]} B"
            )
        if off + nbytes > self.buf.nbytes:
            raise ValueError(
                f"tensor {tensor_id}: view [{off}, {off + nbytes}) exceeds "
                f"arena of {self.buf.nbytes} B"
            )
        return (
            self.buf[off : off + nbytes]
            .view(np.dtype(dtype))
            .reshape(shape)
        )
