"""AOT decode executables: compile at publish time, serve with ZERO
XLA compiles.

The v3 :class:`~repro.core.artifact.PlanBundle` closes the last
cold-start gap (ROADMAP item 1): after PR 3 a bundle-served engine did
zero traces and zero planner calls, but the decode jits still compiled
lazily at the first wave — 3–17 s of XLA compile per bucket vs a
0.01–0.3 s bundle load. This module compiles those jits offline and
ships the *executables* with the plan, the same ahead-of-time argument
the paper makes for memory ("the memory manager needs to run only once
before the first inference", §5) applied to compilation:

* :func:`build_decode_executables` lowers + compiles every decode
  function a state backend would jit — the module-level impl factories
  in ``runtime/residency.py``, so the bundled executable IS the program
  the engine would have compiled — at the shape level (``jax.eval_shape``
  params, aval state buffer: no weights materialized), serializes each
  one through ``jax.experimental.serialize_executable``, and packs them
  into an :class:`~repro.core.artifact.ExecutablePack` keyed by
  ``jax.default_backend()`` + ``jax.__version__``;
* :func:`load_executables` is the serving side: refuse the whole pack
  with a one-line reason on a platform / jax-version / payload-integrity
  mismatch (serialized XLA executables are not portable across backends
  or jax releases) and let the engine fall back to lazy compile — a
  stale pack must never crash serving, and a *partial* pack is worse
  than none (the differential guarantees cover all-AOT or all-lazy).

Serialization is ``pickle`` of ``serialize_executable.serialize``'s
``(payload, in_tree, out_tree)`` triple — byte-deterministic for a fixed
program on the backends we CI (content addressing stays stable), and
donation metadata rides inside the executable (audited post-publish by
``analysis/decode_lint.lint_executables``).
"""

from __future__ import annotations

import hashlib
import pickle
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.artifact import (
    ExecutableEntry,
    ExecutablePack,
    PlanBundle,
    block_entry_name,
    executable_entry,
    expected_executable_entries,
)
from repro.core.unified import PagedStatePlan, StatePlan
from repro.runtime.paging import (
    PAGED_BLOCK_DONATE,
    PAGED_DECODE_DONATE,
    PAGED_RESET_DONATE,
    PagedStateResidency,
    paged_block_impl,
    paged_decode_impl,
    paged_reset_impl,
)
from repro.runtime.residency import (
    BLOCK_DONATE,
    DECODE_DONATE,
    RESET_DONATE,
    StateResidency,
    count_compile,
    pytree_block_impl,
    pytree_decode_impl,
    pytree_reset_impl,
    resident_block_impl,
    resident_decode_impl,
    resident_reset_impl,
)
from repro.runtime.sampling import SamplingParams, TokenSampler


def serialize_compiled(compiled: Any) -> bytes:
    """One compiled jax executable -> opaque bundle payload bytes."""
    from jax.experimental import serialize_executable as se

    return pickle.dumps(se.serialize(compiled))


def deserialize_compiled(payload: bytes) -> Any:
    """Inverse of :func:`serialize_compiled`: a loaded, callable
    ``Compiled`` (positional args must match the lowering avals)."""
    from jax.experimental import serialize_executable as se

    return se.deserialize_and_load(*pickle.loads(payload))


# re-export: the canonical name list lives jax-free in core/artifact so
# analysis/bundle_lint can audit completeness without importing jax
expected_entries = expected_executable_entries


def build_decode_executables(
    cfg: Any,
    state_plan: StatePlan,
    *,
    n_slots: int,
    max_len: int,
    block_size: int = 1,
    greedy: bool = True,
    temperature: float = 1.0,
    top_k: int = 0,
) -> tuple[ExecutablePack, int | None]:
    """Compile + serialize every decode function for one serving bucket.

    Returns ``(pack, xla_temp_bytes)`` — the temp-allocation measurement
    comes free from the ``pytree_decode`` compile (the same plain
    cache-pytree program ``compile.py`` used to measure separately), so
    an AOT compile run costs no extra compiles over the measurement it
    replaces. Every ``.compile()`` here charges ``COMPILE_CALLS``: the
    whole point is to spend these offline so serving spends none."""
    from repro.models.api import Model

    model = Model.for_config(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    caches = jax.eval_shape(lambda: model.init_cache(n_slots, max_len))
    paged = isinstance(state_plan, PagedStatePlan)
    if paged:
        residency = PagedStateResidency(state_plan, caches, n_slots=n_slots)
        buf = jax.ShapeDtypeStruct(
            (state_plan.phys_total_size,), jnp.uint8
        )
        pages = jax.ShapeDtypeStruct(
            (n_slots, state_plan.pages_per_slot), jnp.int32
        )
    else:
        residency = StateResidency(state_plan, caches, n_slots=n_slots)
        buf = jax.ShapeDtypeStruct((state_plan.total_size,), jnp.uint8)
        pages = None

    tok = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
    vec_i32 = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    vec_bool = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)
    keys = jax.ShapeDtypeStruct((n_slots, 2), jnp.uint32)
    eos = jax.ShapeDtypeStruct((), jnp.int32)

    entries: dict[str, ExecutableEntry] = {}

    def _compile(name, fn, avals, donate=()):
        compiled = jax.jit(fn, donate_argnums=donate).lower(*avals).compile()
        count_compile()
        entries[name] = executable_entry(serialize_compiled(compiled))
        return compiled

    pytree_decode = _compile(
        "pytree_decode",
        pytree_decode_impl(model),
        (params, tok, caches, vec_i32, vec_bool),
    )
    _compile(
        "pytree_reset", pytree_reset_impl(model), (caches, vec_bool)
    )
    if paged:
        _compile(
            "paged_decode",
            paged_decode_impl(model, residency),
            (params, tok, buf, vec_i32, vec_bool, pages),
            donate=PAGED_DECODE_DONATE,
        )
        _compile(
            "paged_reset",
            paged_reset_impl(model, residency),
            (buf, vec_bool, pages),
            donate=PAGED_RESET_DONATE,
        )
    else:
        _compile(
            "resident_decode",
            resident_decode_impl(model, residency),
            (params, tok, buf, vec_i32, vec_bool),
            donate=DECODE_DONATE,
        )
        _compile(
            "resident_reset",
            resident_reset_impl(model, residency),
            (buf, vec_bool),
            donate=RESET_DONATE,
        )
    if block_size > 1:
        sampler = TokenSampler(
            SamplingParams(
                greedy=greedy, temperature=temperature, top_k=top_k
            ),
            max_len=max_len,
        )
        if paged:
            _compile(
                block_entry_name("paged", block_size),
                paged_block_impl(model, residency, sampler, block_size),
                (params, buf, tok, vec_i32, vec_bool, vec_bool, vec_i32,
                 keys, eos, pages),
                donate=PAGED_BLOCK_DONATE,
            )
        else:
            _compile(
                block_entry_name("resident", block_size),
                resident_block_impl(model, residency, sampler, block_size),
                (params, buf, tok, vec_i32, vec_bool, vec_bool, vec_i32,
                 keys, eos),
                donate=BLOCK_DONATE,
            )
        _compile(
            block_entry_name("pytree", block_size),
            pytree_block_impl(model, sampler, block_size),
            (params, caches, tok, vec_i32, vec_bool, vec_bool, vec_i32,
             keys, eos),
        )

    try:
        ma = pytree_decode.memory_analysis()
        xla_temp = int(getattr(ma, "temp_size_in_bytes", 0)) or None
    except Exception:
        xla_temp = None
    pack = ExecutablePack(
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        entries=entries,
    )
    return pack, xla_temp


def load_executables(
    bundle: PlanBundle,
) -> tuple[dict[str, Any], str | None]:
    """The serving-side load-or-refuse gate: ``(loaded entries, warning)``.

    All-or-nothing — any refusal (platform/jax-version key mismatch,
    payload integrity failure, deserialization error) drops the WHOLE
    pack and returns the one-line reason; the engine warns once and
    lazy-compiles, exactly as if the bundle were v2. ``({}, None)`` for
    bundles that simply carry no executables."""
    pack = bundle.executables
    if pack is None:
        return {}, None
    platform = jax.default_backend()
    if pack.platform != platform:
        return {}, (
            f"AOT executables were compiled for platform "
            f"{pack.platform!r} but this process runs {platform!r}; "
            f"falling back to lazy compile"
        )
    if pack.jax_version != jax.__version__:
        return {}, (
            f"AOT executables were compiled under jax {pack.jax_version} "
            f"but this process runs jax {jax.__version__}; falling back "
            f"to lazy compile"
        )
    loaded: dict[str, Any] = {}
    for name, entry in sorted(pack.entries.items()):
        if hashlib.sha256(entry.payload).hexdigest() != entry.sha256:
            return {}, (
                f"AOT executable {name!r} failed its payload integrity "
                f"check; falling back to lazy compile"
            )
        try:
            loaded[name] = deserialize_compiled(entry.payload)
        except Exception as e:
            return {}, (
                f"AOT executable {name!r} failed to deserialize "
                f"({type(e).__name__}: {e}); falling back to lazy compile"
            )
    return loaded, None


__all__ = [
    "build_decode_executables",
    "deserialize_compiled",
    "expected_entries",
    "load_executables",
    "serialize_compiled",
]
