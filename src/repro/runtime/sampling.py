"""Token sampling for the serving loop — host-side and on-device.

Two halves, one contract:

* the HOST half (:func:`softmax` / :func:`host_probs`) backs the
  single-wave host loop's numpy sampling. Probabilities are computed in
  float64 and explicitly renormalized — the float32 path handed
  ``Generator.choice(p=...)`` vectors whose sum drifted past numpy's
  tolerance and raised "probabilities do not sum to 1" on large vocabs;
* the DEVICE half (:class:`TokenSampler`) folds token selection into the
  decode jit for the scan-block path (``runtime/residency.decode_block``):
  greedy argmax or temperature/top-k draws via ``jax.random.categorical``
  with per-slot PRNG keys, plus the per-wave stop bookkeeping (EOS /
  budget / max_len) that lets a whole block run without host involvement.

A slot's key advances only when the slot EMITS a token, so on-device
sampling depends only on the slot's emission index — the sampled
trajectory for a fixed seed is invariant to the scan block size, not just
reproducible run-to-run.

:class:`SamplingParams` is the canonical record of the knobs; the part of
it that shapes the compiled decode graph joins the decode fingerprint
(``core/artifact.serve_fingerprint``) so precompiled bundles stay
self-invalidating. The seed never joins: it is runtime data (a traced key
argument), not graph structure.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """The serving loop's sampling knobs.

    ``greedy=True`` ignores (and canonicalizes away) ``temperature`` and
    ``top_k`` — they do not shape the greedy graph. ``top_k=0`` means no
    top-k filtering.
    """

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0.0:
            raise ValueError(
                f"sampling temperature must be > 0, got {self.temperature}"
            )
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


def softmax(x: np.ndarray) -> np.ndarray:
    """float64 softmax with explicit renormalization.

    ``Generator.choice(p=...)`` validates ``abs(p.sum() - 1) < atol`` in
    the dtype of ``p``; a float32 softmax over a big vocab rounds past
    that tolerance often enough to raise in real runs. Promote first,
    renormalize explicitly after."""
    x = np.asarray(x, np.float64)
    e = np.exp(x - x.max())
    p = e / e.sum()
    return p / p.sum()


def host_probs(
    row: np.ndarray, *, temperature: float = 1.0, top_k: int = 0
) -> np.ndarray:
    """The host loop's sampling distribution for one logit row —
    temperature scaling + optional top-k masking, then the float64
    :func:`softmax`."""
    x = np.asarray(row, np.float64)
    if temperature != 1.0:
        x = x / temperature
    if top_k and top_k < x.size:
        kth = np.partition(x, -top_k)[-top_k]
        x = np.where(x < kth, -np.inf, x)
    return softmax(x)


class TokenSampler:
    """On-device token selection + per-wave stop bookkeeping.

    One instance per engine, closed over by the scan-block jit (its
    knobs are static: they select the traced graph). All methods are
    pure jax — safe inside ``lax.scan``.
    """

    def __init__(self, params: SamplingParams, *, max_len: int):
        self.params = params
        self.max_len = int(max_len)

    @staticmethod
    def init_keys(seed: int, n_slots: int):
        """Per-slot PRNG keys, (n_slots, 2) uint32 — one independent
        stream per slot, derived from the engine's sample seed."""
        return jax.random.split(jax.random.PRNGKey(int(seed)), n_slots)

    def _draw(self, logits, subkeys):
        x = logits.astype(jnp.float32) / self.params.temperature
        if self.params.top_k and self.params.top_k < x.shape[-1]:
            kth = jax.lax.top_k(x, self.params.top_k)[0][:, -1][:, None]
            x = jnp.where(x < kth, -jnp.inf, x)
        return jax.vmap(jax.random.categorical)(subkeys, x).astype(jnp.int32)

    def advance(self, logits, keys, tokens, pos, step_active, done, budget,
                eos):
        """One wave of post-logits bookkeeping, entirely on device.

        Selects the next token for every emitting slot; frozen slots
        (``~step_active``) keep their token, position, budget and key —
        a slot's key advances only on emission, so sampled trajectories
        are invariant to how waves are grouped into blocks. Folds the
        stop conditions (EOS, exhausted budget, max_len) into ``done``.
        ``eos`` is a traced int32 scalar; callers with no EOS pass -1
        (never matches a vocab token)."""
        if self.params.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            sub, carried = split[:, 0], split[:, 1]
            nxt = self._draw(logits, sub)
            keys = jnp.where(step_active[:, None], carried, keys)
        nxt = jnp.where(step_active, nxt, tokens[:, 0])
        new_pos = pos + step_active.astype(pos.dtype)
        new_budget = budget - step_active.astype(budget.dtype)
        stopped = step_active & (
            (nxt == eos)
            | (new_budget <= 0)
            | (new_pos >= self.max_len - 1)
        )
        return keys, nxt[:, None], new_pos, done | stopped, new_budget
