"""Plan-backed state residency: the engine's cross-step state in ONE
device buffer, laid out by the :class:`~repro.core.unified.StatePlan`.

PR 4 made the cross-step slot/KV layout a first-class planned object —
but it was accounting only: the engine's cache pytree was still a bag of
XLA-allocated buffers whose placement the plan merely described. This
module closes that gap (the MAFAT/FlashMem observation that the §4 win
comes from *owning* the physical buffers, not modeling them):

* :class:`StateResidency` binds a cache pytree *structure* to a
  :class:`~repro.core.unified.StatePlan`: every (slot, leaf) cell is
  addressed by the plan's :meth:`~repro.core.unified.StatePlan.leaf_view_spec`
  and carved out of one flat ``uint8`` buffer through a
  :class:`~repro.runtime.arena.DeviceArena` (``lax.dynamic_slice`` +
  bitcast views on read, ``dynamic_update_slice`` on write — all static
  offsets, fully fusible);
* :class:`ResidentState` is the serving backend built on it: the decode
  and slot-reset jits take the flat state buffer as a DONATED argument
  and return its successor, so XLA reuses the same physical allocation
  every wave — live device state bytes equal ``StatePlan.total_size``
  exactly, one allocation for the engine's whole cross-step lifecycle;
* :class:`PytreeState` preserves the previous XLA-allocated cache-pytree
  path behind the same interface (``REPRO_STATE_RESIDENCY=off`` escape
  hatch), which is also the baseline of the residency differential test:
  decode outputs through the arena views are byte-identical to it.

The initial buffer is packed on the host through the *numpy* arena
(``Arena.store`` over the same leaf-view spec) and shipped with one
``device_put`` — bounds-checked byte placement, no extra jit compile on
the cold-start path.

**Zero-compile serving (PlanBundle v3).** The decode/reset/scan-block
functions both backends jit are defined as *module-level factories*
(:func:`resident_decode_impl` & co.) so three consumers provably lower
the exact same computation: the serving backends here, the AOT compiler
(``runtime/aot.py``, which serializes the compiled executables into the
bundle), and the static decode lint. Each backend dispatches
load-or-compile per function: a deserialized AOT executable when the
bundle ships one, else a :class:`_LazyJit` — a ``jax.jit`` wrapper that
charges the module-global ``COMPILE_CALLS`` counter whenever a call
actually compiles, so the v3 zero-compile guarantee is counter-asserted
with the same discipline as the zero-trace/zero-plan ones.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import block_entry_name
from repro.core.unified import StatePlan
from repro.runtime.arena import Arena, ArenaLayout, DeviceArena

# Decode-path XLA compiles (lazy jit cache misses + explicit AOT/measure
# compiles via count_compile). NOT a count of every backend compilation
# the process ever does — eager-op warmup and host-side utility jits are
# out of scope; this counts the serving-path decode functions the v3
# bundle exists to pre-compile. Asserted ``== 0`` when serving from a v3
# bundle (tests + CI), mirroring TRACE_CALLS / PLAN_CALLS.
COMPILE_CALLS = 0


def count_compile(n: int = 1) -> None:
    """Charge ``n`` decode-path XLA compiles (AOT builds and the engine's
    xla_temp measurement compile call this explicitly; lazy jits are
    counted by :class:`_LazyJit`)."""
    global COMPILE_CALLS
    COMPILE_CALLS += n


class _LazyJit:
    """``jax.jit`` that counts actual compiles.

    A call that misses the jit cache compiles; one that hits does not.
    The cache-size delta is the exact signal (``_cache_size`` is
    jax-private but pinned by our CI smoke; when absent we degrade to
    charging the first call, which is right for the fixed-shape serving
    loop where each jit compiles at most once)."""

    def __init__(self, fn: Callable, **jit_kwargs: Any):
        self._jitted = jax.jit(fn, **jit_kwargs)
        self._called = False

    def _cache_size(self) -> int | None:
        try:
            return int(self._jitted._cache_size())
        except Exception:
            return None

    def __call__(self, *args: Any) -> Any:
        before = self._cache_size()
        out = self._jitted(*args)
        after = self._cache_size()
        if before is None or after is None:
            if not self._called:
                count_compile()
        elif after > before:
            count_compile(after - before)
        self._called = True
        return out


# Donated argument positions, shared by the serving jits here and the
# AOT lowering in runtime/aot.py — donation must survive serialization
# (audited by analysis/decode_lint.lint_executables).
DECODE_DONATE = (2,)  # (params, tokens, BUF, pos, active)
RESET_DONATE = (0,)  # (BUF, keep)
BLOCK_DONATE = (1,)  # (params, BUF, tokens, pos, active, ...)


def residency_enabled(override: bool | None = None) -> bool:
    """The ``REPRO_STATE_RESIDENCY`` knob: on unless explicitly disabled
    (``off``/``0``/``false``/``no``). An explicit ``override`` (engine
    kwarg) wins over the environment."""
    if override is not None:
        return override
    val = os.environ.get("REPRO_STATE_RESIDENCY", "on").strip().lower()
    return val not in ("off", "0", "false", "no")


def _slot_axis(keypath) -> int:
    """Which leaf axis carries the slot (request batch) dimension.

    The decoder cache contract (``models/transformer.init_cache``): leaves
    under ``"period"`` are stacked over ``n_periods`` first, so slots are
    axis 1; everything else (``"remainder"``, shared blocks) carries slots
    on axis 0. Validated against ``n_slots`` at binding time, so a model
    breaking the contract fails loudly, not silently."""
    if keypath and getattr(keypath[0], "key", None) == "period":
        return 1
    return 0


class StateResidency:
    """Bind a cache-pytree structure to a StatePlan's leaf-view spec.

    ``template`` may be concrete arrays or ``jax.eval_shape`` structs —
    only structure, shapes and dtypes are read. Construction validates
    the binding completely (path sets match, dtypes match, per-slot byte
    sizes match the plan, the slot axis really has ``n_slots`` extent),
    so a stale or foreign state plan fails here with a clear error
    instead of corrupting decode state."""

    def __init__(
        self,
        state_plan: StatePlan,
        template: Any,
        *,
        n_slots: int,
        layout: "ArenaLayout | None" = None,
    ):
        if state_plan.n_slots != n_slots:
            raise ValueError(
                f"state plan lays out {state_plan.n_slots} slots, engine "
                f"serves {n_slots}"
            )
        self.state_plan = state_plan
        self.n_slots = n_slots
        # callers that already materialized (and validated) the layout
        # from this plan pass it in; from_state_plan re-validates
        if layout is None:
            layout = ArenaLayout.from_state_plan(state_plan)
        self.arena = DeviceArena(layout)

        leaves, self.treedef = jax.tree_util.tree_flatten_with_path(template)
        views_by_path: dict[str, list] = {}
        for view in state_plan.leaf_view_spec():
            views_by_path.setdefault(view.path, []).append(view)

        tmpl_paths = {jax.tree_util.keystr(p) for p, _ in leaves}
        if tmpl_paths != set(views_by_path):
            missing = sorted(tmpl_paths - set(views_by_path))
            extra = sorted(set(views_by_path) - tmpl_paths)
            raise ValueError(
                f"state plan does not cover this cache pytree: "
                f"{len(missing)} leaf(s) unplanned {missing[:3]}, "
                f"{len(extra)} planned leaf(s) absent {extra[:3]}"
            )

        # per-leaf binding: (path, slot_axis, per-slot shape, dtype, views)
        self._bindings = []
        for keypath, leaf in leaves:
            path = jax.tree_util.keystr(keypath)
            axis = _slot_axis(keypath)
            shape = tuple(int(d) for d in leaf.shape)
            if axis >= len(shape) or shape[axis] != n_slots:
                raise ValueError(
                    f"state leaf {path!r}: expected {n_slots} slots on "
                    f"axis {axis} of shape {shape}"
                )
            dt = jnp.dtype(leaf.dtype)
            per_slot_shape = shape[:axis] + shape[axis + 1 :]
            per_slot_nbytes = int(np.prod(per_slot_shape)) * dt.itemsize
            views = sorted(views_by_path[path], key=lambda v: v.slot)
            for v in views:
                if v.dtype != dt.name:
                    raise ValueError(
                        f"state leaf {path!r}: plan dtype {v.dtype} != "
                        f"cache dtype {dt.name}"
                    )
                if v.used_nbytes != per_slot_nbytes:
                    raise ValueError(
                        f"state leaf {path!r}: plan expects "
                        f"{v.used_nbytes} B/slot, cache carries "
                        f"{per_slot_nbytes} B/slot"
                    )
            self._bindings.append((path, axis, per_slot_shape, dt, views))

    @property
    def total_size(self) -> int:
        return self.state_plan.total_size

    def init_buffer(self, caches: Any = None):
        """A fresh state buffer: zeroed (``caches=None`` — the models'
        ``init_cache`` contract is all-zero state, so the engine never
        materializes a cache pytree on the residency path), or packed
        from concrete initial caches.

        Concrete packing goes host-side through the bounds-checked numpy
        :class:`Arena` (same leaf-view spec as the device views), then
        one ``device_put`` — correct for any initial cache contents, and
        no extra jit compile on the cold-start path."""
        if caches is None:
            return self.arena.allocate()
        host = Arena(self.arena.layout)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
        if treedef != self.treedef:
            raise ValueError(
                "initial caches do not match the bound pytree structure"
            )
        for (_, leaf), (path, axis, _pss, dt, views) in zip(
            leaves, self._bindings
        ):
            arr = np.asarray(leaf)
            for view in views:
                host.store(
                    view.tensor_id, np.take(arr, view.slot, axis=axis)
                )
        # jnp.array COPIES into a device-owned buffer: device_put of the
        # host arena can zero-copy alias numpy memory on CPU, which is
        # unsafe to donate through the decode jits
        return jnp.array(host.buf)

    def unpack(self, buf) -> Any:
        """The cache pytree as views over ``buf`` — every leaf rebuilt
        from its per-slot cells at the plan's offsets."""
        out = []
        for _path, axis, per_slot_shape, dt, views in self._bindings:
            per_slot = [
                self.arena.view(buf, v.tensor_id, per_slot_shape, dt)
                for v in views
            ]
            out.append(jnp.stack(per_slot, axis=axis))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def pack(self, caches: Any, buf):
        """Write a cache pytree back into ``buf`` at the plan's offsets;
        returns the successor buffer value."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
        if treedef != self.treedef:
            raise ValueError(
                "decode returned a cache pytree with a different structure "
                "than the bound template"
            )
        for (_, leaf), (_path, axis, _pss, dt, views) in zip(
            leaves, self._bindings
        ):
            for view in views:
                buf = self.arena.store(
                    buf, view.tensor_id, jnp.take(leaf, view.slot, axis=axis)
                )
        return buf


@dataclasses.dataclass
class BlockOut:
    """Device handles from one scan-block dispatch — NOTHING here has
    been fetched. ``tokens``/``pos``/``done``/``budget``/``keys`` are the
    post-block carry (the engine chains the next block's dispatch off
    them without a host sync); ``wave_tokens``/``emitted`` are the
    per-wave outputs the engine fetches once per block when absorbing."""

    tokens: Any  # (n_slots, 1) int32 — last token per slot
    pos: Any  # (n_slots,) int32
    done: Any  # (n_slots,) bool — stopped mid-block (EOS/budget/max_len)
    budget: Any  # (n_slots,) int32 — remaining new-token budget
    keys: Any  # (n_slots, 2) uint32 — per-slot PRNG keys
    wave_tokens: Any  # (K, n_slots) int32 — token chosen at each wave
    emitted: Any  # (K, n_slots) bool — slot actually emitted at that wave


def _block_wave(model, sampler, params, caches, tokens, pos, active, done,
                budget, keys, eos):
    """One scan wave, shared by both backends (only the state threading
    differs): decode at ``active & ~done``, then the sampler's on-device
    token selection + stop bookkeeping. Inactive/frozen slots keep their
    token and position, so the cache scatter stays idempotent for them —
    the same invariant the host loop relies on."""
    step_active = active & jnp.logical_not(done)
    logits, new_caches = model.decode_step(
        params, tokens, caches, pos, active=step_active
    )
    keys, tokens, pos, done, budget = sampler.advance(
        logits, keys, tokens, pos, step_active, done, budget, eos
    )
    carry = (tokens, pos, done, budget, keys)
    return new_caches, carry, (tokens[:, 0], step_active)


# ------------------------------------------------- jitted decode functions
#
# Module-level factories for everything the backends jit. The serving
# backends, the AOT bundle compiler (runtime/aot.py) and the static
# decode lint all lower THESE functions — so "the bundled executable is
# the executable the engine would have compiled" holds by construction,
# and the differential tests only need to check numerics, not identity.


def resident_decode_impl(model, residency: StateResidency) -> Callable:
    """One decode wave over the donated flat state buffer:
    ``(params, tokens, buf, pos, active) -> (logits, buf')``."""

    def decode_step(params, tokens, buf, pos, active):
        caches = residency.unpack(buf)
        logits, new_caches = model.decode_step(
            params, tokens, caches, pos, active=active
        )
        return logits, residency.pack(new_caches, buf)

    return decode_step


def resident_reset_impl(model, residency: StateResidency) -> Callable:
    """Slot reset over the donated buffer: ``(buf, keep) -> buf'``."""

    def reset_slots(buf, keep):
        caches = residency.unpack(buf)
        return residency.pack(model.reset_slots(caches, keep), buf)

    return reset_slots


def resident_block_impl(
    model, residency: StateResidency, sampler, length: int
) -> Callable:
    """``length`` decode waves in one ``lax.scan`` over the donated
    buffer, sampling + stop detection on device (see ``_block_wave``)."""

    def decode_block(params, buf, tokens, pos, active, done, budget, keys,
                     eos):
        def body(carry, _):
            buf, tokens, pos, done, budget, keys = carry
            caches = residency.unpack(buf)
            new_caches, (tokens, pos, done, budget, keys), out = (
                _block_wave(model, sampler, params, caches, tokens,
                            pos, active, done, budget, keys, eos)
            )
            buf = residency.pack(new_caches, buf)
            return (buf, tokens, pos, done, budget, keys), out

        carry, (toks, emitted) = jax.lax.scan(
            body, (buf, tokens, pos, done, budget, keys), None,
            length=length,
        )
        return carry, toks, emitted

    return decode_block


def pytree_decode_impl(model) -> Callable:
    """Decode wave over the XLA-allocated cache pytree:
    ``(params, tokens, caches, pos, active) -> (logits, caches')``."""

    def decode_step(params, tokens, caches, pos, active):
        return model.decode_step(params, tokens, caches, pos, active=active)

    return decode_step


def pytree_reset_impl(model) -> Callable:
    def reset_slots(caches, keep):
        return model.reset_slots(caches, keep)

    return reset_slots


def pytree_block_impl(model, sampler, length: int) -> Callable:
    def decode_block(params, caches, tokens, pos, active, done, budget,
                     keys, eos):
        def body(carry, _):
            caches, tokens, pos, done, budget, keys = carry
            caches, (tokens, pos, done, budget, keys), out = (
                _block_wave(model, sampler, params, caches, tokens,
                            pos, active, done, budget, keys, eos)
            )
            return (caches, tokens, pos, done, budget, keys), out

        carry, (toks, emitted) = jax.lax.scan(
            body, (caches, tokens, pos, done, budget, keys), None,
            length=length,
        )
        return carry, toks, emitted

    return decode_block


class ResidentState:
    """Serving backend: cross-step state donate-threaded as ONE buffer.

    ``decode``/``reset`` donate the flat state buffer to their jits and
    keep its successor, so XLA writes the new state into the same
    physical allocation every wave — the planned layout IS the live
    layout, and ``live_bytes == StatePlan.total_size`` for the engine's
    whole lifetime."""

    residency = True

    def __init__(
        self,
        model,
        residency: StateResidency,
        init_caches: Any = None,
        *,
        executables: "dict[str, Any] | None" = None,
    ):
        self.model = model
        self._residency = residency
        self.buf = residency.init_buffer(init_caches)
        # load-or-compile: a deserialized AOT executable from the bundle
        # when present (zero XLA compiles), else a counted lazy jit of
        # the SAME impl function the AOT compiler lowered
        self._execs = executables or {}
        self._decode = self._execs.get("resident_decode") or _LazyJit(
            resident_decode_impl(model, residency),
            donate_argnums=DECODE_DONATE,
        )
        self._reset = self._execs.get("resident_reset") or _LazyJit(
            resident_reset_impl(model, residency),
            donate_argnums=RESET_DONATE,
        )
        self._block_jits: dict[int, Any] = {}  # scan length -> callable

    def decode(self, params, tokens, pos, active):
        logits, self.buf = self._decode(params, tokens, self.buf, pos, active)
        # synchronize before the engine mutates its host-side buffers —
        # see the _step_tokens race note in runtime/engine.py
        jax.block_until_ready(self.buf)
        return logits

    def reset(self, keep):
        self.buf = self._reset(self.buf, jnp.array(keep))
        jax.block_until_ready(self.buf)

    def decode_block(self, params, tokens, pos, active, done, budget, keys,
                     eos, *, length, sampler) -> BlockOut:
        """``length`` decode waves in ONE dispatch: ``lax.scan`` over the
        DONATED state buffer with on-device sampling and stop detection.
        Returns device handles only — no host sync here; the engine
        fetches the per-wave outputs when it absorbs the block, and may
        chain the next block's dispatch off the returned carry first.

        An AOT executable covers the configured full-size block only
        (tail blocks have engine-chosen shorter lengths and lazy-compile
        — the bundle's serve fingerprint pins block size and sampling, so
        a pack entry that matches is safe to run)."""
        jitted = self._block_jits.get(length)
        if jitted is None:
            jitted = self._execs.get(block_entry_name("resident", length))
            if jitted is None:
                jitted = _LazyJit(
                    resident_block_impl(
                        self.model, self._residency, sampler, length
                    ),
                    donate_argnums=BLOCK_DONATE,
                )
            self._block_jits[length] = jitted
        carry, toks, emitted = jitted(
            params, self.buf, tokens, pos, active, done, budget, keys, eos
        )
        self.buf, tokens, pos, done, budget, keys = carry
        return BlockOut(tokens=tokens, pos=pos, done=done, budget=budget,
                        keys=keys, wave_tokens=toks, emitted=emitted)

    @property
    def caches(self) -> Any:
        """The cache pytree as live views over the state buffer (for
        inspection/tracing; decode never materializes this on the host)."""
        return self._residency.unpack(self.buf)

    @property
    def live_bytes(self) -> int:
        return int(self.buf.nbytes)


class PytreeState:
    """The pre-residency backend (``REPRO_STATE_RESIDENCY=off``): caches
    stay an XLA-allocated pytree, reallocated by value every step. Same
    interface as :class:`ResidentState`, so the engine is oblivious."""

    residency = False

    def __init__(
        self,
        model,
        init_caches: Any,
        *,
        executables: "dict[str, Any] | None" = None,
    ):
        self.model = model
        self.caches = init_caches
        self._execs = executables or {}
        self._decode = self._execs.get("pytree_decode") or _LazyJit(
            pytree_decode_impl(model)
        )
        self._reset = self._execs.get("pytree_reset") or _LazyJit(
            pytree_reset_impl(model)
        )
        self._block_jits: dict[int, Any] = {}  # scan length -> callable

    def decode(self, params, tokens, pos, active):
        logits, self.caches = self._decode(
            params, tokens, self.caches, pos, active
        )
        # see the _step_tokens race note in runtime/engine.py
        jax.block_until_ready(self.caches)
        return logits

    def reset(self, keep):
        self.caches = self._reset(self.caches, jnp.array(keep))

    def decode_block(self, params, tokens, pos, active, done, budget, keys,
                     eos, *, length, sampler) -> BlockOut:
        """Scan-block decode over the XLA-allocated cache pytree — the
        same contract as :meth:`ResidentState.decode_block` (the block
        path works with residency off; the buffer just isn't donated)."""
        jitted = self._block_jits.get(length)
        if jitted is None:
            jitted = self._execs.get(block_entry_name("pytree", length))
            if jitted is None:
                jitted = _LazyJit(
                    pytree_block_impl(self.model, sampler, length)
                )
            self._block_jits[length] = jitted
        carry, toks, emitted = jitted(
            params, self.caches, tokens, pos, active, done, budget, keys, eos
        )
        self.caches, tokens, pos, done, budget, keys = carry
        return BlockOut(tokens=tokens, pos=pos, done=done, budget=budget,
                        keys=keys, wave_tokens=toks, emitted=emitted)

    @property
    def live_bytes(self) -> int:
        return int(
            sum(
                int(np.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(self.caches)
            )
        )
