"""Inference engine: plan-once memory management + batched serving.

This is where the paper's contribution becomes a first-class framework
feature. At engine construction we:

1. obtain the activation ``MemoryPlan`` for the decode step — either
   served from a precompiled :class:`~repro.core.artifact.PlanBundle`
   (``plan_bundle=``: the ahead-of-time path — no jaxpr trace, no planner
   call; the bundle's config-level fingerprint is verified against this
   engine's bucket and mismatches fall back to planning with a one-line
   warning in the report), or by tracing the decode step to a jaxpr
   (``trace/jaxpr_liveness``) and planning it (paper §5, Greedy-by-Size
   offsets with auto fallback) — reported in ``engine.memory_report`` and
   validated against XLA's own temp allocation;
2. materialize the activation arena straight from the plan's offsets
   (``engine.activation_arena`` — allocate once, serve forever);
3. plan the CROSS-STEP state (per-slot KV caches + decode buffers) as a
   Shared-Objects instance where ``op index == decode wave`` — slots are
   the shared objects, requests are the tensors (paper §4 applied above
   the XLA level, where XLA cannot help);
4. run continuous batching: fixed ``n_slots``, admit from queue on free,
   step all active slots each wave, retire on EOS/max_len.

The decode step itself is jit-compiled once; the engine never reallocates
its buffers (donate-style cache threading).
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.artifact import PlanBundle, decode_fingerprint, resolve_bundle
from repro.core.graph import Graph
from repro.core.planner import MemoryPlan, plan_graph
from repro.models import transformer
from repro.models.api import Model
from repro.runtime.arena import Arena, ArenaLayout
from repro.trace.jaxpr_liveness import trace_graph


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived_wave: int = 0
    admitted_wave: int = -1  # wave at which the request took a slot
    tokens: list[int] = dataclasses.field(default_factory=list)
    finished_wave: int = -1


@dataclasses.dataclass
class MemoryReport:
    activation_plan: MemoryPlan
    xla_temp_bytes: int | None
    cache_bytes_per_slot: int
    n_slots: int
    # the activation plan came from the content-addressed plan cache
    # (repeat engine construction over an unchanged decode graph)
    plan_cache_hit: bool = False
    # where the plan came from: "bundle" (precompiled artifact, zero
    # trace/plan work), "cache" (plan cache hit), or "planned"
    plan_source: str = "planned"
    # one-line reason when a requested bundle could not be used and the
    # engine fell back to plan-at-construction
    bundle_warning: str | None = None

    def summary(self) -> str:
        lines = [self.activation_plan.summary()]
        if self.bundle_warning:
            lines.append(f"WARNING: {self.bundle_warning}")
        if self.plan_source == "bundle":
            lines.append("activation plan served from a precompiled bundle")
        elif self.plan_cache_hit:
            lines.append("activation plan served from the plan cache")
        if self.xla_temp_bytes is not None:
            lines.append(
                f"XLA temp allocation for the same step: "
                f"{self.xla_temp_bytes / 2**20:.3f} MiB"
            )
        lines.append(
            f"KV/state cache: {self.cache_bytes_per_slot / 2**20:.3f} MiB/slot "
            f"x {self.n_slots} slots"
        )
        return "\n".join(lines)


class InferenceEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        plan_strategy: str = "auto",
        greedy: bool = True,
        sample_seed: int | None = 0,
        activation_graph: Graph | None = None,
        plan_bundle: PlanBundle | str | Path | None = None,
        verify_bundle: bool = False,
    ):
        if cfg.family == "audio":
            raise NotImplementedError("engine drives decoder-only archs")
        self.cfg = cfg
        self.model = Model.for_config(cfg)
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        # ONE engine-owned generator: a per-slot default_rng(self._wave)
        # gave every slot in a wave the same seed, so slots with identical
        # logits always emitted identical tokens and reruns were trivially
        # correlated
        self._sampler = np.random.default_rng(sample_seed)

        self.caches = self.model.init_cache(n_slots, max_len)
        self._reset = jax.jit(lambda c, keep: self.model.reset_slots(c, keep))
        self._decode = jax.jit(
            lambda p, t, c, pos, act: self.model.decode_step(
                p, t, c, pos, active=act
            )
        )

        # --- the paper's planner on the decode step ---------------------
        # Ahead-of-time path first: a precompiled PlanBundle
        # (launch/compile.py) already carries the plan for this exact
        # (config, n_slots, max_len) bucket. Verifying its cheap
        # config-level fingerprint costs microseconds; on a match the
        # engine performs NO jaxpr trace, NO planner call, and skips the
        # XLA memory-analysis compile — the cold-start win the artifact
        # pipeline exists for. Any mismatch or load failure falls back to
        # today's plan-at-construction path with a one-line warning.
        bundle: PlanBundle | None = None
        bundle_warning: str | None = None
        if plan_bundle is not None:
            bundle, bundle_warning = self._load_bundle(plan_bundle)
        tok0 = jnp.zeros((n_slots, 1), jnp.int32)
        pos0 = jnp.zeros((n_slots,), jnp.int32)
        act0 = jnp.ones((n_slots,), bool)
        if bundle is not None and verify_bundle:
            # trace-backed verification: the config fingerprint cannot see
            # model-code changes (only a PIPELINE_REVISION bump can), so a
            # paranoid caller trades the zero-trace cold start for a
            # structural check of the stored graph_fingerprint
            from repro.core.artifact import graph_fingerprint

            fresh = graph_fingerprint(trace_graph(
                lambda p, t, c, pos, act: self.model.decode_step(
                    p, t, c, pos, active=act
                ),
                params, tok0, self.caches, pos0, act0,
                name=f"{cfg.name}-decode",
            ))
            if bundle.graph_fingerprint != fresh:
                bundle_warning = (
                    f"plan bundle graph fingerprint mismatch (bundle "
                    f"{str(bundle.graph_fingerprint)[:12]}, traced "
                    f"{fresh[:12]} — model code changed since compile?); "
                    f"planned at construction instead"
                )
                bundle = None
        xla_temp: int | None = None
        if bundle is not None:
            plan = bundle.plan
            plan_source = "bundle"
            xla_temp = bundle.provenance.get("xla_temp_bytes")
        else:
            # a pre-searched graph (core/order_search, core/fusion_search)
            # can be planned directly instead of tracing the default-order
            # step
            graph = activation_graph if activation_graph is not None else trace_graph(
                lambda p, t, c, pos, act: self.model.decode_step(
                    p, t, c, pos, active=act
                ),
                params, tok0, self.caches, pos0, act0, name=f"{cfg.name}-decode",
            )
            plan = plan_graph(graph, mode="offsets", strategy=plan_strategy)
            plan_source = "cache" if plan.cache_hit else "planned"
            try:
                compiled = (
                    self._decode.lower(params, tok0, self.caches, pos0, act0)
                    .compile()
                )
                ma = compiled.memory_analysis()
                xla_temp = int(getattr(ma, "temp_size_in_bytes", 0)) or None
            except Exception:
                pass
        self.plan_bundle = bundle
        # allocate-once deployment: the arena comes straight from the
        # stored offsets (no planner objects needed on the bundle path)
        self.activation_arena = Arena(ArenaLayout.from_plan(plan))
        cache_bytes = sum(
            np.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(self.caches)
        )
        self.memory_report = MemoryReport(
            activation_plan=plan,
            xla_temp_bytes=xla_temp,
            cache_bytes_per_slot=int(cache_bytes // n_slots),
            n_slots=n_slots,
            plan_cache_hit=plan.cache_hit,
            plan_source=plan_source,
            bundle_warning=bundle_warning,
        )

        # serving state — per-slot positions (continuous batching: every
        # slot advances at its own position in ONE decode call per wave)
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}  # slot -> request
        self._slot_pos = np.zeros(n_slots, np.int32)
        self._slot_tokens = np.zeros((n_slots, 1), np.int32)
        self._wave = 0
        # slot occupancy intervals for the §4-style shared-objects audit:
        # (slot, first_wave, last_wave, request_id)
        self.slot_log: list[tuple[int, int, int, int]] = []
        self._next_rid = 0

    def _load_bundle(
        self, source: PlanBundle | str | Path
    ) -> tuple[PlanBundle | None, str | None]:
        """Resolve + fingerprint-check a plan bundle. Returns
        ``(bundle, None)`` on success, ``(None, warning)`` on any failure —
        a bad artifact degrades to plan-at-construction, never crashes
        serving (hence the deliberately broad except: whatever a corrupt
        or adversarially malformed document raises, serving proceeds)."""
        try:
            bundle = resolve_bundle(
                source, self.cfg, n_slots=self.n_slots, max_len=self.max_len
            )
        except Exception as e:
            return None, (
                f"plan bundle unusable ({e}); planned at construction instead"
            )
        expect = decode_fingerprint(
            self.cfg, n_slots=self.n_slots, max_len=self.max_len
        )
        if bundle.fingerprint != expect:
            return None, (
                f"plan bundle fingerprint mismatch (bundle "
                f"{str(bundle.fingerprint)[:12]}, engine {expect[:12]}); "
                f"planned at construction instead"
            )
        return bundle, None

    # ------------------------------------------------------------ admin
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                    arrived_wave=self._wave)
        )
        return rid

    def _step_tokens(self, tokens: np.ndarray, pos: np.ndarray,
                     active: np.ndarray):
        # jnp.array COPIES (jnp.asarray is zero-copy on CPU, and the engine
        # mutates these numpy buffers while the async dispatch may still be
        # reading them — a real data race, found as a nondeterministic
        # wrong-token bug on the slowest arch)
        logits, self.caches = self._decode(
            self.params, jnp.array(tokens), self.caches,
            jnp.array(pos, jnp.int32), jnp.array(active),
        )
        # synchronize: with async dispatch left in flight we observed
        # rare nondeterministic state corruption on CPU (two stable token
        # trajectories from identical inputs; forcing completion removes
        # it). The engine is host-latency-bound at reference scale, so
        # this costs nothing; a production engine would double-buffer
        # cache pytrees instead.
        jax.block_until_ready(self.caches)
        return logits

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if s not in self._active]
        while free and self._queue:
            slot = free.pop(0)
            req = self._queue.pop(0)
            req.admitted_wave = self._wave
            self._active[slot] = req
            # per-slot prefill: feed prompt tokens through the decode step
            # at this slot's own position; other slots are NOT advanced
            # (their position/token stay put -> the scatter rewrites their
            # current cache entry with identical values: idempotent).
            self._slot_pos[slot] = 0
            only_this = np.zeros(self.n_slots, bool)
            only_this[slot] = True
            # wipe the recycled slot's state (stale SSM state would leak);
            # jnp.array (copying) — see _step_tokens race note
            self.caches = self._reset(self.caches, jnp.array(~only_this))
            for t in req.prompt[:-1]:
                self._slot_tokens[slot, 0] = t
                self._step_tokens(self._slot_tokens, self._slot_pos, only_this)
                self._slot_pos[slot] += 1
            self._slot_tokens[slot, 0] = req.prompt[-1]

    def _sample_token(self, row: np.ndarray) -> int:
        """Greedy argmax, or a draw from the engine-owned generator (so
        consecutive draws — e.g. two slots in one wave — are independent,
        while a fixed ``sample_seed`` keeps whole runs reproducible)."""
        if self.greedy:
            return int(row.argmax())
        return int(self._sampler.choice(len(row), p=_softmax(row)))

    # ------------------------------------------------------------ serve
    def step(self) -> list[Request]:
        """One decode wave over all active slots; returns finished reqs."""
        self._admit()
        if not self._active:
            return []
        active = np.zeros(self.n_slots, bool)
        for s in self._active:
            active[s] = True
        logits = self._step_tokens(self._slot_tokens, self._slot_pos, active)
        finished: list[Request] = []
        for slot, req in list(self._active.items()):
            row = np.asarray(logits[slot])
            nxt = self._sample_token(row)
            req.tokens.append(nxt)
            self._slot_tokens[slot, 0] = nxt
            self._slot_pos[slot] += 1
            if (
                len(req.tokens) >= req.max_new_tokens
                or self._slot_pos[slot] >= self.max_len - 1
            ):
                req.finished_wave = self._wave
                self.slot_log.append(
                    (slot, req.admitted_wave, self._wave, req.request_id)
                )
                finished.append(req)
                del self._active[slot]
        self._wave += 1
        return finished

    def run_until_done(self, max_waves: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_waves):
            done.extend(self.step())
            if not self._active and not self._queue:
                break
        return done


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()
