"""Inference engine: plan-once memory management + batched serving.

This is where the paper's contribution becomes a first-class framework
feature. At engine construction we:

1. obtain the :class:`~repro.core.unified.UnifiedPlan` for the serving
   bucket from the engine's :class:`~repro.core.unified.PlanSession` —
   ``PlanSession.from_manifest(dir)`` serves a precompiled v2
   :class:`~repro.core.artifact.PlanBundle` covering BOTH halves
   (activation offsets + cross-step state layout) with no jaxpr trace, no
   planner call, and no state-layout work; bucket auto-selection picks
   the nearest compiled ``max_len >= requested``. ``from_spec`` plans a
   :class:`~repro.core.unified.PlanSpec` on demand (pre-searched graphs,
   pinned strategies). Without a session — or when a bundle's fingerprint
   does not match — the engine traces the decode step
   (``trace/jaxpr_liveness``) and plans it (paper §5), recording a
   one-line warning in the report;
2. materialize the activation arena straight from the plan's offsets
   (``engine.activation_arena`` — allocate once, serve forever) and
   MATERIALIZE the cross-step state from the plan too: with state
   residency on (default; ``REPRO_STATE_RESIDENCY=off`` to disable) the
   per-slot KV caches and decode buffers live as views over ONE flat
   device buffer of exactly ``StatePlan.total_size`` bytes
   (``runtime/residency.py``), donate-threaded through the decode jit so
   XLA reuses the same allocation every wave — the planned layout is the
   live layout, not an accounting overlay;
3. lay out the CROSS-STEP state (per-slot KV caches + decode buffers) as
   a Shared-Objects instance where ``op index == decode wave`` — slots
   are the shared objects, requests are the tensors (paper §4 applied
   above the XLA level, where XLA cannot help); the engine's slot log is
   the runtime audit (``shared_objects.from_slot_log``);
4. run continuous batching: fixed ``n_slots``, admit from queue on free,
   step all active slots each wave, retire on EOS/max_len.

The decode step itself is jit-compiled once; the engine never reallocates
its buffers (the state buffer is a donated jit argument, so the decode
writes each wave's new state into the same physical allocation).

Two serving loops share that state:

* the single-wave HOST loop (``block_size=1``, the default): one decode
  dispatch + one host sync per wave, numpy sampling on the host. This is
  the correctness oracle;
* the SCAN-BLOCK loop (``block_size=K``): K decode waves per dispatch via
  ``lax.scan`` over the donated state buffer, with sampling (greedy
  argmax or temperature/top-k with per-slot ``jax.random`` keys) and
  stop detection (EOS / token budget / max_len, a per-slot ``done`` mask
  freezing finished slots mid-block) folded into the jit — ONE host sync
  per block (``HOST_SYNCS`` counts them, same discipline as the
  zero-trace/zero-plan counters). ``run_until_done`` additionally
  pipelines blocks: when nothing is queued, the next block is dispatched
  — chained on the in-flight block's device carry — BEFORE the previous
  block's results are fetched, so host admit/retire bookkeeping overlaps
  device compute. Greedy block decode is byte-identical to the host loop
  (the block-length policy lands predictable finishes on block ends, so
  admission waves match too); sampled block decode is reproducible under
  a fixed seed and invariant to the block size (keys advance per
  emission, not per wave).
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.artifact import (
    PlanBundle,
    decode_fingerprint,
    serve_fingerprint,
)
from repro.core.graph import Graph
from repro.core.planner import MemoryPlan, plan_graph
from repro.core.unified import (
    PagedStatePlan,
    PlanSession,
    PlanSpec,
    StatePlan,
    UnifiedPlan,
    detect_state_axes,
    plan_paged_state,
    plan_state,
    state_records_from_pytree,
)
from repro.models.api import Model
from repro.runtime.arena import Arena
from repro.runtime.paging import (
    PagedOutOfPagesError,
    PagedResidentState,
    PagedStateResidency,
)
from repro.runtime.residency import (
    BlockOut,
    PytreeState,
    ResidentState,
    StateResidency,
    count_compile,
    residency_enabled,
)
from repro.runtime.sampling import SamplingParams, TokenSampler, host_probs
from repro.trace.jaxpr_liveness import trace_graph

# Decode-phase host synchronization points, module-wide (the same
# counter discipline as tracer.TRACE_CALLS / planner.PLAN_CALLS /
# unified.STATE_PLAN_CALLS): +1 per host-loop wave, +1 per scan block —
# CI pins host syncs per scan block to exactly 1. Prefill dispatches are
# not counted (they are per-prompt-token by construction).
HOST_SYNCS = 0


class WavesExhaustedError(RuntimeError):
    """``run_until_done`` ran out of its wave budget with requests still
    active or queued; ``unfinished`` carries them."""

    def __init__(self, msg: str, unfinished: "list[Request]"):
        super().__init__(msg)
        self.unfinished = unfinished


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived_wave: int = 0
    admitted_wave: int = -1  # wave at which the request took a slot
    tokens: list[int] = dataclasses.field(default_factory=list)
    finished_wave: int = -1


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-not-absorbed scan block: the device handles, the
    wave span it covers, the slot->request snapshot at dispatch time, and
    the PREDICTED per-slot waves remaining after it (budget/max_len only —
    EOS can shorten a slot's run but never extend it), which is what the
    chained pre-dispatch sizes the next block from without a host sync."""

    out: BlockOut
    base_wave: int
    length: int
    active_dev: Any  # device bool mask this block was dispatched with
    slots: dict[int, "Request"]
    rem_after: dict[int, int]


@dataclasses.dataclass
class MemoryReport:
    activation_plan: MemoryPlan
    xla_temp_bytes: int | None
    # exact per-slot state bytes — the StatePlan's slot region size
    # (``cache_bytes // n_slots`` used to truncate remainder bytes away)
    cache_bytes_per_slot: int
    n_slots: int
    # the activation plan came from the content-addressed plan cache
    # (repeat engine construction over an unchanged decode graph)
    plan_cache_hit: bool = False
    # where the plan came from: "bundle" (precompiled artifact, zero
    # trace/plan work for both halves), "cache" (plan cache hit), or
    # "planned"
    plan_source: str = "planned"
    # one-line reason when a requested bundle could not be used and the
    # engine fell back to plan-at-construction
    bundle_warning: str | None = None
    # cross-step slot/KV layout (the other half of the unified plan)
    state_plan: StatePlan | None = None
    # planned-vs-live device accounting: with residency on the engine's
    # whole cross-step state is ONE buffer of exactly the planned size
    # (live == planned); off, it is an XLA-allocated pytree whose summed
    # leaf bytes are reported here instead
    state_residency: bool = False
    state_live_bytes: int | None = None
    # v3 zero-compile serving: the AOT executable entries deserialized
    # from the bundle (empty = lazy compile), and the one-line reason
    # when a shipped pack was refused (platform/jax-version/integrity)
    aot_executables: list[str] = dataclasses.field(default_factory=list)
    aot_warning: str | None = None
    # paged state accounting (None on the symmetric backend): pool size,
    # pages currently held by ACTIVE slots, and the page size — under
    # paging ``cache_bytes_per_slot`` above is the HONEST live-page
    # bytes per active slot (pages_live * page_size / n_active), not
    # the symmetric region size (``engine.memory_report`` refreshes the
    # live fields on access)
    state_pages_total: int | None = None
    state_pages_live: int | None = None
    state_page_size: int | None = None

    @property
    def state_planned_bytes(self) -> int | None:
        return (
            self.state_plan.total_size if self.state_plan is not None else None
        )

    @property
    def unified_total_bytes(self) -> int:
        return self.activation_plan.total_size + (
            self.state_plan.total_size if self.state_plan is not None else 0
        )

    def summary(self) -> str:
        lines = [self.activation_plan.summary()]
        if self.bundle_warning:
            lines.append(f"WARNING: {self.bundle_warning}")
        if self.plan_source == "bundle":
            lines.append(
                "activation + state plans served from a precompiled bundle"
            )
        elif self.plan_cache_hit:
            lines.append("activation plan served from the plan cache")
        if self.xla_temp_bytes is not None:
            lines.append(
                f"XLA temp allocation for the same step: "
                f"{self.xla_temp_bytes / 2**20:.3f} MiB"
            )
        if self.aot_executables:
            lines.append(
                f"AOT decode executables: {len(self.aot_executables)} "
                f"loaded from the bundle (zero-compile serving)"
            )
        elif self.aot_warning:
            lines.append(f"WARNING: {self.aot_warning}")
        if self.state_plan is not None:
            lines.append(self.state_plan.summary())
            lines.append(
                f"unified footprint (activation + state): "
                f"{self.unified_total_bytes / 2**20:.3f} MiB"
            )
        if self.state_pages_total is not None:
            live = self.state_pages_live or 0
            page = self.state_page_size or 0
            lines.append(
                f"paged state: {live}/{self.state_pages_total} pool pages "
                f"live ({live * page / 2**20:.3f} MiB of "
                f"{(self.state_planned_bytes or 0) / 2**20:.3f} MiB "
                f"logical)"
            )
        if self.state_live_bytes is not None:
            if self.state_residency and self.state_pages_total is not None:
                lines.append(
                    f"state residency: ON (paged) — live device state "
                    f"{self.state_live_bytes / 2**20:.3f} MiB across "
                    f"page-table-mapped pool pages"
                )
            elif self.state_residency:
                lines.append(
                    f"state residency: ON — live device state "
                    f"{self.state_live_bytes / 2**20:.3f} MiB in one "
                    f"plan-backed allocation"
                )
            else:
                lines.append(
                    f"state residency: off — live device state "
                    f"{self.state_live_bytes / 2**20:.3f} MiB as an "
                    f"XLA-allocated cache pytree"
                )
        lines.append(
            f"KV/state cache: {self.cache_bytes_per_slot / 2**20:.3f} MiB/slot "
            f"x {self.n_slots} slots"
        )
        return "\n".join(lines)


def _session_from_legacy_kwargs(
    session: PlanSession | None,
    *,
    plan_strategy: str | None,
    activation_graph: Graph | None,
    plan_bundle: PlanBundle | str | Path | None,
    verify_bundle: bool | None,
) -> PlanSession | None:
    """Deprecated-kwarg shim: the pre-unified plan-source kwargs map onto
    a PlanSession. ``plan_bundle`` keeps its historical exact-bucket
    semantics (``nearest=False``); new callers get auto-selection through
    ``PlanSession.from_manifest``."""
    # explicitly-passed OLD DEFAULTS are semantic no-ops, not deprecated
    # usage — callers migrating incrementally must be able to combine
    # them with session= (the downstream spec/verify defaults reproduce
    # them exactly)
    if plan_strategy == "auto":
        plan_strategy = None
    if verify_bundle is False:
        verify_bundle = None
    legacy = {
        "plan_strategy": plan_strategy,
        "activation_graph": activation_graph,
        "plan_bundle": plan_bundle,
        "verify_bundle": verify_bundle,
    }
    used = [k for k, v in legacy.items() if v is not None]
    if not used:
        return session
    if session is not None:
        raise ValueError(
            f"pass either session= or the deprecated {used} kwargs, not both"
        )
    warnings.warn(
        f"InferenceEngine({', '.join(used)}=...) is deprecated; pass "
        f"session=PlanSession.from_manifest(dir) / .from_bundle(b) / "
        f".from_spec(PlanSpec(...)) instead",
        DeprecationWarning,
        stacklevel=3,
    )
    verify = bool(verify_bundle)
    if plan_bundle is not None:
        if not isinstance(plan_bundle, PlanBundle) and Path(plan_bundle).is_dir():
            return PlanSession.from_manifest(
                plan_bundle, nearest=False, verify_graph=verify
            )
        return PlanSession.from_bundle(plan_bundle, verify_graph=verify)
    return PlanSession.from_spec(
        PlanSpec(graph=activation_graph, strategy=plan_strategy or "auto")
    )


class InferenceEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        session: PlanSession | None = None,
        greedy: bool = True,
        sample_seed: int | None = 0,
        temperature: float = 1.0,
        top_k: int = 0,
        # retire a slot when it emits this token (None = length-only)
        eos_id: int | None = None,
        # decode waves per host sync: 1 = the single-wave host loop
        # (numpy sampling, the oracle); K > 1 = lax.scan block decode
        # with on-device sampling + stop detection
        block_size: int = 1,
        # paged state (None = symmetric max_len slot regions): fixed
        # page size in bytes and pool size in pages (None = enough to
        # map every slot fully); joins the serve fingerprint so paged
        # and symmetric bundles for the same bucket never cross-match
        page_size: int | None = None,
        page_pool: int | None = None,
        # None -> the REPRO_STATE_RESIDENCY env knob (default: on)
        state_residency: bool | None = None,
        # certify the resolved unified plan at startup with the static
        # analyzer (repro.analysis.soundness); None -> the
        # REPRO_STARTUP_LINT env knob (default: off — bundles are gated
        # at publish time, and the tracer/planner are differentially
        # tested, so the per-process re-proof is opt-in paranoia)
        startup_lint: bool | None = None,
        # deprecated plan-source kwargs — use session=PlanSession...
        plan_strategy: str | None = None,
        activation_graph: Graph | None = None,
        plan_bundle: PlanBundle | str | Path | None = None,
        verify_bundle: bool | None = None,
    ):
        if cfg.family == "audio":
            raise NotImplementedError("engine drives decoder-only archs")
        session = _session_from_legacy_kwargs(
            session,
            plan_strategy=plan_strategy,
            activation_graph=activation_graph,
            plan_bundle=plan_bundle,
            verify_bundle=verify_bundle,
        )
        self.cfg = cfg
        self.model = Model.for_config(cfg)
        self.params = params
        self.greedy = greedy
        self.session = session
        self.eos_id = None if eos_id is None else int(eos_id)
        self.block_size = int(block_size)
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.sampling = SamplingParams(
            greedy=greedy, temperature=float(temperature), top_k=int(top_k)
        )
        self.temperature = self.sampling.temperature
        self.top_k = self.sampling.top_k
        # the part of the serve config that shapes the compiled graph —
        # joins the decode fingerprint so bundles self-invalidate across
        # serving configurations (None = default greedy host loop)
        self.page_size = None if not page_size else int(page_size)
        self.page_pool = None if page_pool is None else int(page_pool)
        if self.page_size is not None and self.page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self._serve_params = serve_fingerprint(
            block_size=self.block_size, greedy=greedy,
            temperature=self.temperature, top_k=self.top_k,
            page_size=self.page_size, page_pool=self.page_pool,
        )
        # ONE engine-owned generator: a per-slot default_rng(self._wave)
        # gave every slot in a wave the same seed, so slots with identical
        # logits always emitted identical tokens and reruns were trivially
        # correlated
        self._sampler = np.random.default_rng(sample_seed)
        self._sample_seed = sample_seed

        # --- the unified plan for this serving bucket -------------------
        # The session is the single plan source: a precompiled v2 bundle
        # carries BOTH halves (activation offsets + cross-step state
        # layout) behind one fingerprint check — no jaxpr trace, no
        # planner call, no state-layout work, no XLA memory-analysis
        # compile. Nearest-bucket selection may hand back a larger
        # compiled max_len than requested; the engine serves that bucket.
        # Any mismatch or load failure falls back to plan-at-construction
        # with a one-line warning. Auto-selection may also hand back a
        # wider slot pool (n_slots >= requested — a bigger §4 shared-object
        # pool is admissible, just wasteful); the engine serves that pool.
        resolution = (
            session.resolve(
                cfg, n_slots=n_slots, max_len=max_len,
                serve_params=self._serve_params,
            )
            if session is not None
            else None
        )
        self.max_len = resolution.max_len if resolution is not None else max_len
        if resolution is not None and resolution.n_slots:
            n_slots = resolution.n_slots
        self.n_slots = n_slots

        # Shape-level cache template: structure + shapes + dtypes for
        # tracing, state planning, and the residency binding. No state
        # buffer is materialized until the backend is chosen below — the
        # residency path must never allocate the pytree AND the arena.
        cache_template = jax.eval_shape(
            lambda: self.model.init_cache(n_slots, self.max_len)
        )

        def _decode_fn(p, t, c, pos, act):
            return self.model.decode_step(p, t, c, pos, active=act)

        bundle = resolution.bundle if resolution is not None else None
        unified = resolution.unified if resolution is not None else None
        bundle_warning = resolution.warning if resolution is not None else None
        spec = resolution.spec if resolution is not None else None
        tok0 = jnp.zeros((n_slots, 1), jnp.int32)
        pos0 = jnp.zeros((n_slots,), jnp.int32)
        act0 = jnp.ones((n_slots,), bool)
        if bundle is not None and session is not None and session.verify_graph:
            # trace-backed verification: the config fingerprint cannot see
            # model-code changes (only a PIPELINE_REVISION bump can), so a
            # paranoid caller trades the zero-trace cold start for a
            # structural check of the stored graph_fingerprint
            from repro.core.artifact import graph_fingerprint

            fresh = graph_fingerprint(trace_graph(
                _decode_fn,
                params, tok0, cache_template, pos0, act0,
                name=f"{cfg.name}-decode",
            ))
            if bundle.graph_fingerprint != fresh:
                bundle_warning = (
                    f"plan bundle graph fingerprint mismatch (bundle "
                    f"{str(bundle.graph_fingerprint)[:12]}, traced "
                    f"{fresh[:12]} — model code changed since compile?); "
                    f"planned at construction instead"
                )
                bundle = None
                unified = None

        xla_temp: int | None = None
        if unified is not None and unified.activation is not None:
            plan = unified.activation
            if bundle is not None:
                plan_source = "bundle"
                xla_temp = bundle.provenance.get("xla_temp_bytes")
            else:
                plan_source = "cache" if plan.cache_hit else "planned"
        else:
            # fallback half: a pre-searched graph (core/order_search,
            # core/fusion_search) from the spec can be planned directly
            # instead of tracing the default-order step
            graph = (
                spec.graph
                if spec is not None and spec.graph is not None
                else trace_graph(
                    _decode_fn,
                    params, tok0, cache_template, pos0, act0,
                    name=f"{cfg.name}-decode",
                )
            )
            strategy = spec.strategy if spec is not None else "auto"
            plan = plan_graph(graph, mode="offsets", strategy=strategy)
            plan_source = "cache" if plan.cache_hit else "planned"
        if bundle is None and xla_temp is None:
            # planned-vs-XLA validation line: only a bundle carries the
            # measurement precomputed; every other plan source (trace,
            # spec-planned searched graph) measures it here. Measured on
            # the plain cache-pytree decode (comparable across residency
            # modes and to compile.py's offline measurement).
            try:
                compiled = (
                    jax.jit(_decode_fn)
                    .lower(params, tok0, cache_template, pos0, act0)
                    .compile()
                )
                count_compile()
                ma = compiled.memory_analysis()
                xla_temp = int(getattr(ma, "temp_size_in_bytes", 0)) or None
            except Exception:
                pass

        # cross-step half: a v2 bundle ships the slot/KV layout; anything
        # else lays it out from the engine's own cache pytree (cheap, but
        # counted — unified.STATE_PLAN_CALLS — so tests can pin the
        # bundle path to zero work here too)
        if unified is not None and unified.state is not None:
            state_plan = unified.state
        elif self.page_size:
            state_plan = plan_paged_state(
                state_records_from_pytree(cache_template, n_slots=n_slots),
                n_slots=n_slots,
                max_len=self.max_len,
                page_size=self.page_size,
                page_pool=self.page_pool,
                axes=detect_state_axes(
                    self.model.init_cache,
                    n_slots=n_slots,
                    max_len=self.max_len,
                ),
            )
        else:
            state_plan = plan_state(
                state_records_from_pytree(cache_template, n_slots=n_slots),
                n_slots=n_slots,
                max_len=self.max_len,
            )
        self.unified_plan = UnifiedPlan(
            activation=plan,
            state=state_plan,
            fingerprint=(
                unified.fingerprint
                if unified is not None
                else decode_fingerprint(
                    cfg, n_slots=n_slots, max_len=self.max_len,
                    serve_params=self._serve_params,
                )
            ),
        )
        if (
            startup_lint
            if startup_lint is not None
            else os.environ.get("REPRO_STARTUP_LINT", "").lower()
            in ("1", "on", "true")
        ):
            from repro.analysis import LintGateError, soundness
            from repro.analysis.findings import Report

            report = Report().extend(
                soundness.certify_unified(
                    self.unified_plan, label=f"{cfg.name}-startup"
                ),
                checked=f"{cfg.name}-startup",
            )
            if not report.ok():
                raise LintGateError(
                    report, context="startup lint refused the unified plan"
                )

        self.plan_bundle = bundle
        # v3 zero-compile path: deserialize the bundle's AOT executables
        # (when shipped) for the state backend below — decode/reset/scan
        # block then dispatch without a single XLA compile. A refused
        # pack (wrong platform, different jax version, integrity failure)
        # warns ONE line and serves through the counted lazy jits — the
        # same degradation a v2 bundle gets.
        aot_execs: dict[str, Any] = {}
        aot_warning: str | None = None
        if bundle is not None and bundle.executables is not None:
            from repro.runtime.aot import load_executables

            aot_execs, aot_warning = load_executables(bundle)
            if aot_warning:
                warnings.warn(aot_warning, RuntimeWarning, stacklevel=2)
        # allocate-once deployment: BOTH layouts come from the one unified
        # plan; the activation arena is materialized (the decode step's
        # scratch bytes) and — with residency on — so is the cross-step
        # state: ONE flat device buffer of exactly StatePlan.total_size
        # bytes, donate-threaded through the decode jit. With residency
        # off the state layout degrades to the PR 4 accounting overlay
        # over an XLA-allocated cache pytree.
        act_layout, self.state_layout = self.unified_plan.arena_layouts()
        self.activation_arena = Arena(act_layout)
        self.residency: StateResidency | None = None
        paged_plan = isinstance(state_plan, PagedStatePlan)
        if residency_enabled(state_residency):
            try:
                if paged_plan:
                    # page-table addressing over the physical pool
                    # buffer; page allocation bookkeeping lives in the
                    # backend, driven by _admit / retirement below
                    self.residency = PagedStateResidency(
                        state_plan, cache_template, n_slots=n_slots,
                        layout=self.state_layout,
                    )
                    self.state = PagedResidentState(
                        self.model, self.residency, executables=aot_execs
                    )
                else:
                    self.residency = StateResidency(
                        state_plan, cache_template, n_slots=n_slots,
                        layout=self.state_layout,
                    )
                    # zero-init straight into the flat buffer
                    # (init_cache's contract is all-zero state): on this
                    # path the engine NEVER materializes a cache pytree,
                    # so cold start holds exactly one state allocation,
                    # not pytree + arena
                    self.state = ResidentState(
                        self.model, self.residency, executables=aot_execs
                    )
            except Exception as e:
                # a state plan that cannot back this cache pytree must
                # degrade to the XLA-allocated path, not kill serving
                warnings.warn(
                    f"state residency disabled: {e}", RuntimeWarning,
                    stacklevel=2,
                )
                self.residency = None
        if self.residency is None:
            if paged_plan:
                # the pytree backend has no page indirection: tokens are
                # identical (it is the differential oracle), but state
                # stays symmetric and page accounting is unavailable
                warnings.warn(
                    "paged state requires state residency; serving the "
                    "symmetric XLA-allocated pytree backend instead",
                    RuntimeWarning,
                    stacklevel=2,
                )
            self.state = PytreeState(
                self.model,
                self.model.init_cache(n_slots, self.max_len),
                executables=aot_execs,
            )
        paged_backend = bool(getattr(self.state, "paged", False))
        self._memory_report = MemoryReport(
            activation_plan=plan,
            xla_temp_bytes=xla_temp,
            cache_bytes_per_slot=(
                0 if paged_backend else state_plan.bytes_per_slot
            ),
            n_slots=n_slots,
            plan_cache_hit=plan.cache_hit,
            plan_source=plan_source,
            bundle_warning=bundle_warning,
            state_plan=state_plan,
            state_residency=self.state.residency,
            state_live_bytes=self.state.live_bytes,
            aot_executables=sorted(aot_execs),
            aot_warning=aot_warning,
            state_pages_total=(
                self.state.pages_total if paged_backend else None
            ),
            state_pages_live=0 if paged_backend else None,
            state_page_size=(
                state_plan.page_size if paged_backend else None
            ),
        )

        # serving state — per-slot positions (continuous batching: every
        # slot advances at its own position in ONE decode call per wave)
        self._queue: list[Request] = []
        self._active: dict[int, Request] = {}  # slot -> request
        self._slot_pos = np.zeros(n_slots, np.int32)
        self._slot_tokens = np.zeros((n_slots, 1), np.int32)
        self._wave = 0
        # slot occupancy intervals for the §4-style shared-objects audit:
        # (slot, first_wave, last_wave, request_id)
        self.slot_log: list[tuple[int, int, int, int]] = []
        self._next_rid = 0
        # scan-block serving state: the on-device sampler (closed over by
        # the block jit), per-slot PRNG keys (lazy — only the block path
        # or on-device sampling needs them), and the block counter the
        # throughput bench pairs with HOST_SYNCS
        self._token_sampler = TokenSampler(self.sampling, max_len=self.max_len)
        self._keys = None
        self.n_blocks = 0

    # ------------------------------------------------------------ admin
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                    arrived_wave=self._wave)
        )
        return rid

    @property
    def caches(self):
        """The live cache pytree — concrete XLA buffers with residency
        off, views over the one state buffer with it on (inspection
        only; the serving path never materializes this)."""
        return self.state.caches

    @property
    def memory_report(self) -> MemoryReport:
        """The planned-vs-live report. Under paging the live fields are
        refreshed on access — ``cache_bytes_per_slot`` is the HONEST
        live-page bytes per active slot and ``state_pages_live`` /
        ``state_live_bytes`` track the pool — so the report tells the
        truth mid-serve, not just at construction."""
        rep = self._memory_report
        if not getattr(self.state, "paged", False):
            return rep
        return dataclasses.replace(
            rep,
            cache_bytes_per_slot=(
                self.state.live_bytes // max(len(self._active), 1)
            ),
            state_pages_live=self.state.pages_live,
            state_live_bytes=self.state.live_bytes,
        )

    @property
    def page_log(self) -> list[tuple[int, int, int, int]]:
        """Page occupancy intervals ``(page, admitted_wave,
        finished_wave, request_id)`` — the page-granular twin of
        ``slot_log`` (empty on non-paged backends), audited by
        ``shared_objects.from_page_log``."""
        return list(getattr(self.state, "page_log", []))

    def _step_tokens(self, tokens: np.ndarray, pos: np.ndarray,
                     active: np.ndarray):
        # jnp.array COPIES (jnp.asarray is zero-copy on CPU, and the engine
        # mutates these numpy buffers while the async dispatch may still be
        # reading them — a real data race, found as a nondeterministic
        # wrong-token bug on the slowest arch).
        #
        # The state backend synchronizes on its new state before returning:
        # with async dispatch left in flight we observed rare
        # nondeterministic state corruption on CPU (two stable token
        # trajectories from identical inputs; forcing completion removes
        # it). The engine is host-latency-bound at reference scale, so
        # this costs nothing; a production engine would double-buffer.
        return self.state.decode(
            self.params, jnp.array(tokens),
            jnp.array(pos, jnp.int32), jnp.array(active),
        )

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if s not in self._active]
        paged = getattr(self.state, "paged", False)
        while free and self._queue:
            if paged:
                # allocate-before-admit: map the pages the head request
                # needs (its cache never grows past prompt + budget,
                # capped by the bucket length) BEFORE touching any slot
                # state. A refused allocation mutates nothing: with
                # active slots we stop admitting and retry after the
                # next retirement returns pages (FIFO head-of-line, so
                # the admission schedule stays deterministic); with NO
                # active slots the whole pool is free, so the request
                # can never fit this bucket and the error propagates.
                req = self._queue[0]
                needed = min(
                    len(req.prompt) + req.max_new_tokens, self.max_len
                )
                try:
                    self.state.allocate_slot(
                        free[0], needed, rid=req.request_id,
                        wave=self._wave,
                    )
                except PagedOutOfPagesError:
                    if self._active:
                        break
                    raise
            slot = free.pop(0)
            req = self._queue.pop(0)
            req.admitted_wave = self._wave
            self._active[slot] = req
            # per-slot prefill: feed prompt tokens through the decode step
            # at this slot's own position; other slots are NOT advanced
            # (their position/token stay put -> the scatter rewrites their
            # current cache entry with identical values: idempotent).
            self._slot_pos[slot] = 0
            only_this = np.zeros(self.n_slots, bool)
            only_this[slot] = True
            # wipe the recycled slot's state (stale SSM state would leak);
            # the backend copies the keep mask — see _step_tokens race note
            self.state.reset(~only_this)
            for t in req.prompt[:-1]:
                self._slot_tokens[slot, 0] = t
                self._step_tokens(self._slot_tokens, self._slot_pos, only_this)
                self._slot_pos[slot] += 1
            self._slot_tokens[slot, 0] = req.prompt[-1]

    def _sample_token(self, row: np.ndarray) -> int:
        """Greedy argmax, or a draw from the engine-owned generator (so
        consecutive draws — e.g. two slots in one wave — are independent,
        while a fixed ``sample_seed`` keeps whole runs reproducible).
        Probabilities come from the float64 ``sampling.host_probs`` —
        the float32 softmax tripped ``Generator.choice``'s sum-to-1
        check on rounding."""
        if self.greedy:
            return int(row.argmax())
        p = host_probs(row, temperature=self.temperature, top_k=self.top_k)
        return int(self._sampler.choice(len(p), p=p))

    def _finished(self, req: Request, slot: int, nxt: int) -> bool:
        """The retirement oracle, shared by the host loop and the block
        absorber (the on-device stop detection mirrors exactly this):
        EOS, exhausted new-token budget, or the context limit."""
        return (
            (self.eos_id is not None and nxt == self.eos_id)
            or len(req.tokens) >= req.max_new_tokens
            or int(self._slot_pos[slot]) >= self.max_len - 1
        )

    # ------------------------------------------------------------ serve
    def step(self) -> list[Request]:
        """One decode wave over all active slots; returns finished reqs."""
        global HOST_SYNCS
        self._admit()
        if not self._active:
            return []
        active = np.zeros(self.n_slots, bool)
        for s in self._active:
            active[s] = True
        logits = self._step_tokens(self._slot_tokens, self._slot_pos, active)
        HOST_SYNCS += 1
        finished: list[Request] = []
        for slot, req in list(self._active.items()):
            row = np.asarray(logits[slot])
            nxt = self._sample_token(row)
            req.tokens.append(nxt)
            self._slot_tokens[slot, 0] = nxt
            self._slot_pos[slot] += 1
            if self._finished(req, slot, nxt):
                req.finished_wave = self._wave
                self.slot_log.append(
                    (slot, req.admitted_wave, self._wave, req.request_id)
                )
                finished.append(req)
                del self._active[slot]
                if getattr(self.state, "paged", False):
                    self.state.free_slot(slot, self._wave)
        self._wave += 1
        return finished

    # ----------------------------------------------------- block serve
    def _ensure_keys(self):
        if self._keys is None:
            seed = (
                self._sample_seed
                if self._sample_seed is not None
                else int(np.random.default_rng().integers(2**31 - 1))
            )
            self._keys = self._token_sampler.init_keys(seed, self.n_slots)
        return self._keys

    def _remaining_waves(self) -> dict[int, int]:
        """Per-active-slot PREDICTABLE waves left (new-token budget and
        max_len; EOS can only shorten a run, never extend it)."""
        rem = {}
        for slot, req in self._active.items():
            budget = req.max_new_tokens - len(req.tokens)
            len_cap = max((self.max_len - 1) - int(self._slot_pos[slot]), 1)
            rem[slot] = max(min(budget, len_cap), 1)
        return rem

    def _plan_block(self, waves_left: int | None = None) -> int:
        """This block's scan length K: capped by the LONGEST predictable
        remaining run (no all-frozen tail waves) and — when requests are
        queued — by the SHORTEST one, so predictable finishes land on the
        block's last wave and admission happens at exactly the same wave
        as the single-wave host loop (the differential-test schedule
        contract). A mid-block EOS still freezes its slot until the block
        ends; with a non-empty queue that defers the slot's re-admission
        by < block_size waves (the one scheduling deviation from the
        host loop — tokens are unaffected)."""
        rem = self._remaining_waves()
        k = min(self.block_size, max(rem.values()))
        if self._queue:
            k = min(k, min(rem.values()))
        if waves_left is not None:
            k = min(k, waves_left)
        return max(k, 1)

    def _dispatch_block(self, k: int) -> _Inflight:
        """Launch K scan waves WITHOUT a host sync. Every input is copied
        to a fresh device array before dispatch — the host keeps mutating
        its numpy mirrors while the block is in flight (the _step_tokens
        race note, applied to the async path)."""
        active = np.zeros(self.n_slots, bool)
        budget = np.zeros(self.n_slots, np.int32)
        rem = self._remaining_waves()
        for slot, req in self._active.items():
            active[slot] = True
            budget[slot] = req.max_new_tokens - len(req.tokens)
        active_dev = jnp.array(active)
        out = self.state.decode_block(
            self.params,
            jnp.array(self._slot_tokens),
            jnp.array(self._slot_pos, jnp.int32),
            active_dev,
            jnp.zeros(self.n_slots, bool),
            jnp.array(budget),
            self._ensure_keys(),
            jnp.int32(-1 if self.eos_id is None else self.eos_id),
            length=k,
            sampler=self._token_sampler,
        )
        self._keys = out.keys
        return _Inflight(
            out=out, base_wave=self._wave, length=k, active_dev=active_dev,
            slots=dict(self._active),
            rem_after={s: max(r - k, 0) for s, r in rem.items()},
        )

    def _dispatch_chained(self, prev: _Inflight, k: int) -> _Inflight:
        """Launch the NEXT block off the in-flight block's device carry —
        no host sync between the two dispatches. Only valid when nothing
        is queued (the carry's ``done`` mask already freezes every slot
        that finished mid-stream, and no admission can be pending)."""
        out = self.state.decode_block(
            self.params, prev.out.tokens, prev.out.pos, prev.active_dev,
            prev.out.done, prev.out.budget, self._keys,
            jnp.int32(-1 if self.eos_id is None else self.eos_id),
            length=k, sampler=self._token_sampler,
        )
        self._keys = out.keys
        return _Inflight(
            out=out, base_wave=prev.base_wave + prev.length, length=k,
            active_dev=prev.active_dev, slots=prev.slots,
            rem_after={s: max(r - k, 0) for s, r in prev.rem_after.items()},
        )

    def _absorb_block(self, inflight: _Inflight) -> list[Request]:
        """Fetch one block's per-wave outputs (THE one host sync per
        block) and replay them through the host bookkeeping — the same
        retirement oracle as the host loop, wave by wave, so slot_log
        intervals and finish waves mean the same thing in both modes."""
        global HOST_SYNCS
        HOST_SYNCS += 1
        self.n_blocks += 1
        toks = np.asarray(inflight.out.wave_tokens)
        emitted = np.asarray(inflight.out.emitted)
        finished: list[Request] = []
        for k in range(inflight.length):
            wave = inflight.base_wave + k
            for slot, req in inflight.slots.items():
                if self._active.get(slot) is not req or not emitted[k, slot]:
                    continue
                nxt = int(toks[k, slot])
                req.tokens.append(nxt)
                self._slot_tokens[slot, 0] = nxt
                self._slot_pos[slot] += 1
                if self._finished(req, slot, nxt):
                    req.finished_wave = wave
                    self.slot_log.append(
                        (slot, req.admitted_wave, wave, req.request_id)
                    )
                    finished.append(req)
                    del self._active[slot]
                    if getattr(self.state, "paged", False):
                        self.state.free_slot(slot, wave)
        self._wave = inflight.base_wave + inflight.length
        return finished

    def step_block(self) -> list[Request]:
        """One synchronous scan block: admit, dispatch K waves, absorb.
        (``run_until_done`` pipelines these — it chains the next block's
        dispatch before fetching the previous block's results whenever
        the queue is empty.)"""
        self._admit()
        if not self._active:
            return []
        return self._absorb_block(self._dispatch_block(self._plan_block()))

    def _run_blocks(self, max_waves: int) -> list[Request]:
        done: list[Request] = []
        waves_left = max_waves
        inflight: _Inflight | None = None
        while True:
            if inflight is None:
                self._admit()
                if not self._active or waves_left <= 0:
                    break
                k = self._plan_block(waves_left)
                inflight = self._dispatch_block(k)
                waves_left -= k
            # async admission/retirement: with nothing queued, no host
            # decision can change the next block's inputs — chain its
            # dispatch off the in-flight carry BEFORE fetching, so the
            # absorb below overlaps device compute
            nxt: _Inflight | None = None
            if not self._queue and waves_left > 0:
                rem = [r for r in inflight.rem_after.values() if r > 0]
                if rem:
                    k2 = min(self.block_size, max(rem), waves_left)
                    nxt = self._dispatch_chained(inflight, k2)
                    waves_left -= k2
            done.extend(self._absorb_block(inflight))
            inflight = nxt
            if inflight is None and not self._active and not self._queue:
                break
        return done

    def unfinished_requests(self) -> list[Request]:
        """Requests still holding a slot or waiting in the queue —
        surfaced when ``run_until_done`` exhausts its wave budget."""
        return list(self._active.values()) + list(self._queue)

    def run_until_done(
        self, max_waves: int = 10_000, *, raise_on_exhausted: bool = False
    ) -> list[Request]:
        """Serve until queue and slots drain (or ``max_waves`` decode
        waves run). Exhausting the wave budget with work remaining warns
        — or raises :class:`WavesExhaustedError` with the unfinished
        requests attached under ``raise_on_exhausted=True`` — instead of
        silently returning partial results."""
        done: list[Request] = []
        if self.block_size <= 1:
            for _ in range(max_waves):
                done.extend(self.step())
                if not self._active and not self._queue:
                    break
        else:
            done.extend(self._run_blocks(max_waves))
        if self._active or self._queue:
            msg = (
                f"run_until_done exhausted max_waves={max_waves} with "
                f"{len(self._active)} active and {len(self._queue)} queued "
                f"request(s) unfinished"
            )
            if raise_on_exhausted:
                raise WavesExhaustedError(msg, self.unfinished_requests())
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return done


def _softmax(x: np.ndarray) -> np.ndarray:
    """Backwards-compatible alias of :func:`repro.runtime.sampling.softmax`
    (float64 + explicit renormalization — see the bugfix note there)."""
    from repro.runtime.sampling import softmax

    return softmax(x)
