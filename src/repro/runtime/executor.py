"""Arena-backed jaxpr interpreter — runs a model with the planned reuse.

This is the Offset Calculation deployment path (paper §5) executed for
real: every intermediate result is stored into ONE flat arena at its
planned offset; tensors whose usage intervals have ended are silently
overwritten by later tensors sharing their bytes. If the plan were wrong,
results would be garbage — so ``assert_allclose`` against plain execution
is an end-to-end proof of plan validity (stronger than the static checker).

Also records the naive co-residency total vs the arena size so tests can
assert the real memory win.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.extend.core import Literal

from repro.core import plan_io
from repro.core.graph import Graph
from repro.core.planner import MemoryPlan, plan_graph
from repro.core.unified import UnifiedPlan
from repro.runtime.arena import Arena, ArenaLayout
from repro.trace.jaxpr_liveness import _INLINE, _sub_closed_jaxpr, graph_from_jaxpr


@dataclasses.dataclass
class ExecutionStats:
    arena_bytes: int
    naive_peak_bytes: int  # sum of all intermediate tensors (paper's Naive)
    n_ops: int

    @property
    def reduction(self) -> float:
        return self.naive_peak_bytes / max(self.arena_bytes, 1)


class ArenaExecutor:
    """plan once → allocate once → run many (the paper's deployment mode)."""

    def __init__(
        self,
        fn: Callable,
        *example_args,
        strategy: str = "auto",
        alignment: int = 64,
        plan: "MemoryPlan | UnifiedPlan | None" = None,
    ):
        self.closed = jax.make_jaxpr(fn)(*example_args)
        self.graph: Graph = graph_from_jaxpr(
            self.closed, name=getattr(fn, "__name__", "fn"),
            inline_nested=True, expand_scan=False,
        )
        self.state_arena: Arena | None = None
        if isinstance(plan, UnifiedPlan):
            if plan.state is not None:
                # materialize the cross-step half too (host twin of the
                # engine's device residency — same leaf_view_spec
                # addressing), so an executor-driven decode can store
                # per-slot cache leaves at their planned offsets
                self.state_arena = Arena(
                    ArenaLayout.from_state_plan(plan.state)
                )
            plan = plan.activation  # execution runs the activation half
        if plan is not None:
            # a precompiled plan (e.g. out of a PlanBundle) skips the
            # planner — but only if it covers exactly this graph's records;
            # a stale artifact here would mean silent memory corruption
            canon = plan_io.canonical_records
            if canon(plan.records) != canon(
                self.graph.usage_records(alignment)
            ):
                raise ValueError(
                    "precomputed plan does not match this graph's usage "
                    "records; re-run launch/compile.py"
                )
            self.plan = plan
        else:
            self.plan = plan_graph(
                self.graph, mode="offsets", strategy=strategy,
                alignment=alignment,
            )
        self.arena = Arena(ArenaLayout.from_plan(self.plan))
        self.stats = ExecutionStats(
            arena_bytes=self.plan.total_size,
            naive_peak_bytes=self.plan.naive_size,
            n_ops=len(self.graph.ops),
        )
        self._out_tree = jax.tree_util.tree_structure(
            jax.eval_shape(fn, *example_args)
        )

    def __call__(self, *args):
        flat = _eval_with_arena(self.closed, self.graph, self.arena, args)
        return jax.tree_util.tree_unflatten(self._out_tree, flat)


def _eval_with_arena(closed, graph: Graph, arena: Arena, args: Sequence[Any]):
    """Interpret the jaxpr; intermediates live in the arena."""
    jaxpr = closed.jaxpr
    var_tid: dict[Any, int] = graph.var_tid  # type: ignore[attr-defined]
    boundary = graph.boundary_ids
    env: dict[int, Any] = {}  # tensor id -> concrete value

    for cv, val in zip(jaxpr.constvars, closed.consts):
        env[var_tid[cv]] = val
    flat_args = jax.tree_util.tree_leaves(args)
    if len(flat_args) != len(jaxpr.invars):
        raise ValueError(
            f"expected {len(jaxpr.invars)} flat args, got {len(flat_args)}"
        )
    for iv, val in zip(jaxpr.invars, flat_args):
        env[var_tid[iv]] = val

    def read(v):
        return v.val if isinstance(v, Literal) else env[var_tid[v]]

    visited: set[int] = set()

    def walk(jxp, consts):
        for cv, val in zip(jxp.constvars, consts):
            env[var_tid[cv]] = val
        for eqn in jxp.eqns:
            sub = _sub_closed_jaxpr(eqn)
            if (
                eqn.primitive.name in _INLINE
                and sub is not None
                and id(sub.jaxpr) not in visited
            ):
                # The tracer inlined the FIRST occurrence of each body (in
                # the same walk order); mirror that decision exactly.
                visited.add(id(sub.jaxpr))
                inner = sub.jaxpr
                for iv, ov in zip(inner.invars, eqn.invars):
                    env[var_tid[iv]] = read(ov)
                walk(inner, sub.consts)
                for inner_ov, outer_ov in zip(inner.outvars, eqn.outvars):
                    if type(outer_ov).__name__ == "DropVar":
                        continue
                    env[var_tid[outer_ov]] = (
                        inner_ov.val
                        if isinstance(inner_ov, Literal)
                        else env[var_tid[inner_ov]]
                    )
                continue
            # opaque equation: bind the primitive directly
            invals = [read(v) for v in eqn.invars]
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            outvals = eqn.primitive.bind(*subfuns, *invals, **bind_params)
            if not eqn.primitive.multiple_results:
                outvals = [outvals]
            for v, val in zip(eqn.outvars, outvals):
                if type(v).__name__ == "DropVar":
                    continue
                tid = var_tid[v]
                if tid in boundary:
                    env[tid] = val
                else:
                    env[tid] = arena.store(tid, np.asarray(val))

    walk(jaxpr, [])  # top-level consts were bound above
    outs = []
    for v in jaxpr.outvars:
        outs.append(v.val if isinstance(v, Literal) else env[var_tid[v]])
    return outs
