"""Paged state backend: the residency buffer addressed through per-slot
page tables (ROADMAP open item 2 — §4 shared objects at page granularity).

The symmetric :class:`~repro.runtime.residency.ResidentState` gives every
slot a full ``max_len`` region, so a 64-token request in a 4096-token
bucket strands ~98% of its planned state bytes. This module keeps the
*logical* layout identical — the same
:class:`~repro.core.unified.StatePlan` leaves, offsets and strides the
whole codebase reasons about — but backs it with a pool of fixed-size
physical pages (:class:`~repro.core.unified.PagedStatePlan`):

* :class:`PagedStateResidency` re-binds the cache pytree to the plan
  through a page-table indirection: ``unpack`` gathers each slot's
  logical region from its table row (``jnp.take`` over the page-reshaped
  buffer), ``pack`` scatters it back — one gather + one scatter per
  decode wave, all shapes static, so the decode jit stays a fixed
  program and the table is plain int32 *data* (no retrace, no
  recompile when the mapping changes);
* physical page 0 is the reserved all-zero **null page**: unmapped
  logical pages read as zeros through it, and every scatter row aimed
  at it provably carries zeros (unmapped bytes are zeros on the way in
  and the decode masks its cache updates by ``active``), so duplicate
  scatter indices are benign;
* :class:`PagedResidentState` adds the serving-time bookkeeping:
  allocate-on-admit (:meth:`~PagedResidentState.allocate_slot` maps the
  pages a request's ``needed_len`` intersects, refusing with
  :class:`PagedOutOfPagesError` when the pool cannot cover it) and
  free-on-retire (:meth:`~PagedResidentState.free_slot`), with a page
  log mirroring the engine's slot log for the §4-style audit
  (``shared_objects.from_page_log``).

**Byte-identity discipline.** Retirement frees a slot's pages but does
NOT clear its table row (*lazy invalidation*): the symmetric baseline
never zeroes a retired slot (reset happens at the next admit), so the
retired slot's stale bytes must stay readable for the cache-leaf
differential to hold. Re-admission prefers (1) the slot's own stale
pages, then (2) never-mapped free pages, and only then (3) steals
another retired slot's stale page — and at the default pool size
(``n_slots * pages_per_slot``) case (3) provably never happens, so
paged decode is unconditionally byte-identical to the symmetric
baseline there. Reset-at-admit zeroes every page the slot still maps
(stale ones included), exactly matching the baseline's full-region
wipe.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import block_entry_name
from repro.core.unified import PagedStatePlan
from repro.runtime.residency import (
    BlockOut,
    StateResidency,
    _block_wave,
    _LazyJit,
)

# Donated argument positions for the paged jits (the page table rides
# LAST and is never donated — it is a tiny int32 input the host mutates
# between dispatches).
PAGED_DECODE_DONATE = (2,)  # (params, tokens, BUF, pos, active, pages)
PAGED_RESET_DONATE = (0,)  # (BUF, keep, pages)
PAGED_BLOCK_DONATE = (1,)  # (params, BUF, tokens, pos, active, ..., pages)


class PagedOutOfPagesError(RuntimeError):
    """Admission would exceed the page pool. Carries the numbers a
    caller needs to decide (wait for retirements vs reject): pages the
    request needs, pages currently free, pages live across active slots,
    and the bucket's total pool size."""

    def __init__(
        self,
        *,
        pages_needed: int,
        pages_free: int,
        pages_live: int,
        pages_total: int,
    ):
        self.pages_needed = pages_needed
        self.pages_free = pages_free
        self.pages_live = pages_live
        self.pages_total = pages_total
        super().__init__(
            f"paged admission refused: request needs {pages_needed} "
            f"page(s) but only {pages_free} of the bucket's {pages_total} "
            f"pool pages are free ({pages_live} live across active slots)"
        )


class PagedStateResidency(StateResidency):
    """The :class:`~repro.runtime.residency.StateResidency` binding with
    page-table addressing: the flat buffer is ``n_pages_total`` physical
    pages (null page at physical index 0), and every (slot, leaf) cell
    is reached by gathering the slot's table row instead of a static
    ``slot * slot_stride`` base.

    Binding validation is inherited wholesale — the logical layout IS
    the symmetric plan's, so path/dtype/per-slot-byte checks are
    unchanged."""

    def __init__(
        self,
        state_plan: PagedStatePlan,
        template: Any,
        *,
        n_slots: int,
        layout: Any | None = None,
    ):
        if not isinstance(state_plan, PagedStatePlan):
            raise TypeError(
                f"PagedStateResidency needs a PagedStatePlan, got "
                f"{type(state_plan).__name__}"
            )
        super().__init__(state_plan, template, n_slots=n_slots, layout=layout)
        self.paged_plan = state_plan
        if state_plan.slot_stride > (
            state_plan.pages_per_slot * state_plan.page_size
        ):
            raise ValueError(
                "page table does not cover the slot region: "
                f"{state_plan.pages_per_slot} x {state_plan.page_size} B "
                f"< stride {state_plan.slot_stride} B"
            )
        # page_offsets are distinct page-aligned offsets inside the
        # physical buffer (validated at plan time), i.e. a permutation
        # of physical indices 1..n_pages_pool — the table stores these
        # physical indices directly
        phys = sorted(o // state_plan.page_size for o in state_plan.page_offsets)
        if phys != list(range(1, state_plan.n_pages_pool + 1)):
            raise ValueError(
                "paged plan's page offsets do not tile the physical pool"
            )

    @property
    def phys_total_size(self) -> int:
        return self.paged_plan.phys_total_size

    def init_buffer(self, caches: Any = None):
        """A fresh physical buffer: the null page + the whole pool,
        zeroed (the models' ``init_cache`` contract is all-zero state —
        and with an all-zero table every logical read resolves to the
        null page anyway). Must be a device-OWNED buffer (``jnp.zeros``,
        like the symmetric arena) — ``device_put`` of a host array can
        zero-copy alias numpy-owned memory on CPU, which is unsafe to
        donate through the decode jits."""
        if caches is not None:
            raise ValueError(
                "paged residency initializes zero state only (allocate "
                "pages, then pack through the table)"
            )
        return jnp.zeros(self.paged_plan.phys_total_size, jnp.uint8)

    def unpack(self, buf, pages) -> Any:
        """The cache pytree gathered through the page tables: ONE
        ``jnp.take`` rebuilds every slot's logical region, then each
        leaf is a static column slice + bitcast of it."""
        plan = self.paged_plan
        page, pps = plan.page_size, plan.pages_per_slot
        buf_pages = buf.reshape(plan.n_pages_total, page)
        region = jnp.take(buf_pages, pages.reshape(-1), axis=0).reshape(
            self.n_slots, pps * page
        )
        out = []
        for _path, axis, per_slot_shape, dt, views in self._bindings:
            off = views[0].offset  # slot 0's view offset == leaf offset
            nb = views[0].used_nbytes
            raw = region[:, off : off + nb]
            if dt.itemsize > 1:
                raw = raw.reshape(self.n_slots, nb // dt.itemsize, dt.itemsize)
            leaf = jax.lax.bitcast_convert_type(raw, dt)
            leaf = leaf.reshape((self.n_slots,) + per_slot_shape)
            out.append(jnp.moveaxis(leaf, 0, axis))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def pack(self, caches: Any, buf, pages):
        """Scatter a cache pytree back through the page tables; returns
        the successor buffer value. Rows of unmapped logical pages all
        target the null page and provably carry zeros (see module
        docstring), so the duplicate scatter indices there are benign —
        and the null page stays all-zero by the same argument."""
        plan = self.paged_plan
        page, pps = plan.page_size, plan.pages_per_slot
        leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
        if treedef != self.treedef:
            raise ValueError(
                "decode returned a cache pytree with a different structure "
                "than the bound template"
            )
        region = jnp.zeros((self.n_slots, pps * page), jnp.uint8)
        for (_, leaf), (_path, axis, _pss, dt, views) in zip(
            leaves, self._bindings
        ):
            off = views[0].offset
            nb = views[0].used_nbytes
            flat = jnp.moveaxis(leaf, axis, 0).reshape(self.n_slots, -1)
            raw = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(
                self.n_slots, nb
            )
            region = region.at[:, off : off + nb].set(raw)
        buf_pages = buf.reshape(plan.n_pages_total, page)
        buf_pages = buf_pages.at[pages.reshape(-1)].set(
            region.reshape(self.n_slots * pps, page)
        )
        return buf_pages.reshape(-1)


# ------------------------------------------------- jitted decode functions
#
# Module-level factories, same discipline as runtime/residency.py: the
# serving backend, the AOT compiler (runtime/aot.py) and the static
# decode lint all lower THESE functions. The page table is the LAST
# positional argument of every one.


def paged_decode_impl(model, residency: PagedStateResidency) -> Callable:
    """One decode wave through the page tables:
    ``(params, tokens, buf, pos, active, pages) -> (logits, buf')``."""

    def decode_step(params, tokens, buf, pos, active, pages):
        caches = residency.unpack(buf, pages)
        logits, new_caches = model.decode_step(
            params, tokens, caches, pos, active=active
        )
        return logits, residency.pack(new_caches, buf, pages)

    return decode_step


def paged_reset_impl(model, residency: PagedStateResidency) -> Callable:
    """Slot reset through the page tables:
    ``(buf, keep, pages) -> buf'`` — zeroes every page the dropped
    slots still map (stale mappings included: the symmetric baseline
    wipes the whole slot region at admit, and so does this)."""

    def reset_slots(buf, keep, pages):
        caches = residency.unpack(buf, pages)
        return residency.pack(model.reset_slots(caches, keep), buf, pages)

    return reset_slots


def paged_block_impl(
    model, residency: PagedStateResidency, sampler, length: int
) -> Callable:
    """``length`` decode waves in one ``lax.scan``: gather the cache
    pytree through the tables ONCE, scan the waves over the pytree
    carry, scatter back ONCE. ``pack``/``unpack`` are exact inverses on
    values, so this is wave-for-wave identical to the symmetric block's
    per-wave pack/unpack — with a 1/length page-indirection cost."""

    def decode_block(params, buf, tokens, pos, active, done, budget, keys,
                     eos, pages):
        caches0 = residency.unpack(buf, pages)

        def body(carry, _):
            caches, tokens, pos, done, budget, keys = carry
            caches, (tokens, pos, done, budget, keys), out = (
                _block_wave(model, sampler, params, caches, tokens,
                            pos, active, done, budget, keys, eos)
            )
            return (caches, tokens, pos, done, budget, keys), out

        carry, (toks, emitted) = jax.lax.scan(
            body, (caches0, tokens, pos, done, budget, keys), None,
            length=length,
        )
        caches, tokens, pos, done, budget, keys = carry
        buf = residency.pack(caches, buf, pages)
        return (buf, tokens, pos, done, budget, keys), toks, emitted

    return decode_block


class PagedResidentState:
    """Serving backend: the donated flat buffer addressed through
    per-slot page tables, with allocate-on-admit / free-on-retire page
    bookkeeping.

    Same decode/reset/decode_block interface as
    :class:`~repro.runtime.residency.ResidentState` (the engine is
    oblivious to the indirection), plus the page lifecycle the engine's
    admission path drives: :meth:`allocate_slot` before a slot is
    reset/prefilled, :meth:`free_slot` when it retires."""

    residency = True
    paged = True

    def __init__(
        self,
        model,
        residency: PagedStateResidency,
        *,
        executables: "dict[str, Any] | None" = None,
    ):
        self.model = model
        self._residency = residency
        plan = residency.paged_plan
        self.plan = plan
        self.buf = residency.init_buffer()
        # host-authoritative page table, mirrored to device only when a
        # mapping actually changes (admission); 0 = null page
        self._table = np.zeros(
            (residency.n_slots, plan.pages_per_slot), np.int32
        )
        self._table_dev = jnp.array(self._table)
        # free pool as physical page indices (ascending — deterministic
        # assignment order), page -> (slot, logical_idx) for EVERY
        # mapped page (live or stale), and the live set: pages held by
        # currently-active slots
        self._free: list[int] = sorted(
            o // plan.page_size for o in plan.page_offsets
        )
        self._owner: dict[int, tuple[int, int]] = {}
        self._live: set[int] = set()
        self._page_admit: dict[int, int] = {}  # page -> admitted wave
        self._slot_rid: dict[int, int] = {}
        # page occupancy intervals, the page-granular twin of the
        # engine's slot_log: (page, admitted_wave, finished_wave, rid)
        self.page_log: list[tuple[int, int, int, int]] = []
        self.pages_live_peak = 0
        self._execs = executables or {}
        self._decode = self._execs.get("paged_decode") or _LazyJit(
            paged_decode_impl(model, residency),
            donate_argnums=PAGED_DECODE_DONATE,
        )
        self._reset = self._execs.get("paged_reset") or _LazyJit(
            paged_reset_impl(model, residency),
            donate_argnums=PAGED_RESET_DONATE,
        )
        self._block_jits: dict[int, Any] = {}  # scan length -> callable

    # ------------------------------------------------- page lifecycle
    @property
    def pages_total(self) -> int:
        return self.plan.n_pages_pool

    @property
    def pages_live(self) -> int:
        return len(self._live)

    def slot_pages(self, slot: int) -> list[int]:
        """The physical pages ``slot`` holds LIVE (mapped and counted
        against the pool; stale mappings of a retired slot excluded)."""
        return sorted(
            int(p) for p in self._table[slot] if p and int(p) in self._live
        )

    def allocate_slot(
        self, slot: int, needed_len: int, *, rid: int, wave: int
    ) -> int:
        """Map the pages ``slot`` needs to serve a request whose cache
        never grows past ``needed_len`` rows. Returns the number of
        pages now live for the slot; raises :class:`PagedOutOfPagesError`
        (mutating NOTHING) when the free pool cannot cover the need.

        Assignment order is the byte-identity ladder from the module
        docstring: the slot's own stale pages first, never-mapped free
        pages next, stolen stale pages of other retired slots last —
        each group in ascending physical order, so runs are
        deterministic."""
        need = self.plan.pages_needed(needed_len)
        free_set = set(self._free)
        assigned: dict[int, int] = {}
        for j in need:
            p = int(self._table[slot, j])
            if p and p in free_set:  # (1) stale-self: still mapped here
                assigned[j] = p
                free_set.discard(p)
        remaining = [j for j in need if j not in assigned]
        avail = sorted(free_set)
        pool = [p for p in avail if p not in self._owner] + [
            p for p in avail if p in self._owner
        ]
        if len(remaining) > len(pool):
            raise PagedOutOfPagesError(
                pages_needed=len(need),
                pages_free=len(self._free),
                pages_live=len(self._live),
                pages_total=self.plan.n_pages_pool,
            )
        dirty = False
        for j, p in zip(remaining, pool):
            old = self._owner.get(p)
            if old is not None:  # (3) steal: clear the stale owner's map
                self._table[old[0], old[1]] = 0
            self._table[slot, j] = p
            self._owner[p] = (slot, j)
            assigned[j] = p
            dirty = True
        taken = set(assigned.values())
        self._free = sorted(set(self._free) - taken)
        for p in taken:
            self._live.add(p)
            self._page_admit[p] = wave
        self._slot_rid[slot] = rid
        self.pages_live_peak = max(self.pages_live_peak, len(self._live))
        if dirty:
            self._table_dev = jnp.array(self._table)
        return len(taken)

    def free_slot(self, slot: int, wave: int) -> list[int]:
        """Return a retired slot's live pages to the free pool and log
        their occupancy intervals. The table row is NOT cleared (lazy
        invalidation — see module docstring), so the device table needs
        no refresh and the retired slot's stale bytes stay readable,
        exactly like the symmetric baseline's."""
        released = self.slot_pages(slot)
        rid = self._slot_rid.get(slot, -1)
        for p in released:
            self._live.discard(p)
            self.page_log.append((p, self._page_admit.pop(p), wave, rid))
        self._free = sorted(set(self._free) | set(released))
        return released

    # ------------------------------------------------------- serving
    def decode(self, params, tokens, pos, active):
        logits, self.buf = self._decode(
            params, tokens, self.buf, pos, active, self._table_dev
        )
        # see the _step_tokens race note in runtime/engine.py
        jax.block_until_ready(self.buf)
        return logits

    def reset(self, keep):
        self.buf = self._reset(self.buf, jnp.array(keep), self._table_dev)
        jax.block_until_ready(self.buf)

    def decode_block(self, params, tokens, pos, active, done, budget, keys,
                     eos, *, length, sampler) -> BlockOut:
        """Scan-block decode through the page tables — the contract of
        :meth:`~repro.runtime.residency.ResidentState.decode_block`.
        Table mutations happen only at admission and the engine chains
        blocks only when nothing is queued, so an in-flight block always
        holds the current table."""
        jitted = self._block_jits.get(length)
        if jitted is None:
            jitted = self._execs.get(block_entry_name("paged", length))
            if jitted is None:
                jitted = _LazyJit(
                    paged_block_impl(
                        self.model, self._residency, sampler, length
                    ),
                    donate_argnums=PAGED_BLOCK_DONATE,
                )
            self._block_jits[length] = jitted
        carry, toks, emitted = jitted(
            params, self.buf, tokens, pos, active, done, budget, keys, eos,
            self._table_dev,
        )
        self.buf, tokens, pos, done, budget, keys = carry
        return BlockOut(tokens=tokens, pos=pos, done=done, budget=budget,
                        keys=keys, wave_tokens=toks, emitted=emitted)

    @property
    def caches(self) -> Any:
        """The cache pytree gathered through the live page tables
        (inspection only; the serving path never materializes this)."""
        return self._residency.unpack(self.buf, self._table_dev)

    @property
    def live_bytes(self) -> int:
        """Pool bytes holding live state — the paged win the report and
        benches track: ``pages_live * page_size``, vs the symmetric
        backend's constant ``StatePlan.total_size``."""
        return len(self._live) * self.plan.page_size

    @property
    def allocated_bytes(self) -> int:
        """The physical buffer allocation (null page + whole pool)."""
        return int(self.buf.nbytes)
