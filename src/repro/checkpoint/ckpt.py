"""Flat-npz checkpointing for param/optimizer pytrees.

Pure numpy (no orbax offline): pytrees are flattened with stable
path-derived keys; restore round-trips dtypes and tree structure. Suited
to single-host save/restore and the tests; the launcher saves params +
optimizer state + step + data-pipeline cursor (exact resume).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _keys(tree: Any) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    flat, treedef = jax.tree_util.tree_flatten(tree)
    keys = _keys(tree)
    arrays = {f"arr_{i}": np.asarray(x) for i, x in enumerate(flat)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path + ".npz" if not path.endswith(".npz") else path)
    real = path if path.endswith(".npz") else path + ".npz"
    with open(real + ".meta.json", "w") as f:
        json.dump({"keys": keys, "meta": meta or {}}, f)


def restore(path: str, like: Any) -> tuple[Any, dict]:
    real = path if path.endswith(".npz") else path + ".npz"
    data = np.load(real)
    with open(real + ".meta.json") as f:
        info = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    keys = _keys(like)
    if keys != info["keys"]:
        raise ValueError("checkpoint tree structure mismatch")
    flat = [data[f"arr_{i}"].astype(np.asarray(x).dtype) for i, x in enumerate(flat_like)]
    return jax.tree_util.tree_unflatten(treedef, flat), info["meta"]
