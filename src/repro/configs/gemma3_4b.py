"""gemma3-4b — dense, 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ArchConfig, LayerSpec

_LOCAL = LayerSpec(mixer="attn", ffn="mlp", window=1024)
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp", window=None)

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense", source="hf:google/gemma-3-1b-pt",
    d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240, vocab=262144,
    head_dim=2560 // 8, qk_norm=True, act="gelu", rope_theta=1_000_000.0,
    # 34 layers = 5 x (5 local + 1 global) + 4 local remainder
    period=(_LOCAL,) * 5 + (_GLOBAL,), n_periods=5,
    remainder=(_LOCAL,) * 4,
    supports_long_context=True,
)
REDUCED = CONFIG.reduced(period=(_LOCAL, _GLOBAL), remainder=())
