"""Architecture config schema + registry for the 10 assigned architectures.

Each assigned architecture gets one module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact assigned spec) and ``REDUCED`` (≤2 layers,
d_model ≤ 512, ≤4 experts — used by CPU smoke tests). ``--arch <id>`` on
every launcher resolves through :func:`get_config`.

Layer stacks are expressed as a repeated *period* of ``LayerSpec``s plus a
remainder, so the model compiles as ``lax.scan`` over periods (HLO size
independent of depth).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal, Sequence

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
Mixer = Literal["attn", "mamba", "none"]
Ffn = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer = token mixer + channel mixer (ffn)."""

    mixer: Mixer = "attn"
    ffn: Ffn = "mlp"
    window: int | None = None  # sliding-window size; None = global attention
    shared_attn: bool = False  # Zamba2-style shared full block before mixer


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    source: str  # citation from the assignment pool
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    period: tuple[LayerSpec, ...]
    n_periods: int
    remainder: tuple[LayerSpec, ...] = ()
    head_dim: int | None = None  # default d_model // n_heads
    qk_norm: bool = False
    act: Literal["silu", "gelu", "sq_relu"] = "silu"
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    # Zamba2-style shared attention block (params shared across insertions)
    shared_attn_heads: int = 0
    # modality frontend stubs
    n_prefix_tokens: int = 0  # VLM: image patch embeddings prepended
    encoder_layers: int = 0  # audio enc-dec: encoder depth
    enc_len_ratio: int = 1  # encoder frames = seq_len // ratio
    # long_500k applicability (sub-quadratic decode path)
    supports_long_context: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_periods + len(self.remainder)

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        changes: dict = dict(
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512) or 256,
            vocab=min(self.vocab, 512),
            n_periods=1,
            period=self.period[: min(len(self.period), 2)],
            remainder=(),
            head_dim=None,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            shared_attn_heads=min(self.shared_attn_heads, 4),
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            encoder_layers=min(self.encoder_layers, 2),
            dtype="float32",
        )
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


ARCH_IDS: tuple[str, ...] = (
    "qwen3-0.6b",
    "gemma3-27b",
    "internvl2-1b",
    "zamba2-7b",
    "gemma3-4b",
    "llama4-maverick-400b-a17b",
    "nemotron-4-340b",
    "seamless-m4t-medium",
    "granite-moe-3b-a800m",
    "mamba2-2.7b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.REDUCED


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
