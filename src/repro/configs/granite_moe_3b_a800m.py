"""granite-moe-3b-a800m — MoE 40 experts top-8, per-expert d_ff=512
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
    head_dim=64, act="silu", rope_theta=10_000.0,
    period=(LayerSpec(mixer="attn", ffn="moe"),), n_periods=32,
    n_experts=40, top_k=8,
)
REDUCED = CONFIG.reduced()
