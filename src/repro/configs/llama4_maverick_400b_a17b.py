"""llama4-maverick-400b-a17b — MoE 128 experts top-1 + shared expert,
dense/MoE alternating, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E
family]. 48 layers = 24 x (dense, moe)."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    head_dim=128, act="silu", rope_theta=500_000.0,
    period=(LayerSpec(mixer="attn", ffn="mlp"),
            LayerSpec(mixer="attn", ffn="moe")),
    n_periods=24,
    n_experts=128, top_k=1, shared_expert=True,
)
REDUCED = CONFIG.reduced()
