"""qwen3-0.6b — dense, GQA, qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense", source="hf:Qwen/Qwen3-8B",
    d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072, vocab=151936,
    head_dim=64, qk_norm=True, act="silu", rope_theta=1_000_000.0,
    period=(LayerSpec(mixer="attn", ffn="mlp"),), n_periods=28,
)
REDUCED = CONFIG.reduced()
