"""nemotron-4-340b — dense, GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense", source="arXiv:2402.16819",
    d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728, vocab=256000,
    head_dim=192, act="sq_relu", rope_theta=10_000.0,
    period=(LayerSpec(mixer="attn", ffn="mlp"),), n_periods=96,
)
REDUCED = CONFIG.reduced()
