"""internvl2-1b — VLM: InternViT frontend (STUB) + Qwen2-0.5B-style LM
backbone [arXiv:2404.16821]. input_specs supplies 256 patch embeddings."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm", source="arXiv:2404.16821",
    d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151655,
    head_dim=64, act="silu", rope_theta=1_000_000.0,
    period=(LayerSpec(mixer="attn", ffn="mlp"),), n_periods=24,
    n_prefix_tokens=256,
)
REDUCED = CONFIG.reduced()
