"""mamba2-2.7b — attention-free SSM via SSD (state-space duality)
[arXiv:2405.21060]. 64 Mamba2 layers, d_state=128, headdim=64."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", source="arXiv:2405.21060",
    d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280,
    act="silu",
    period=(LayerSpec(mixer="mamba", ffn="none"),), n_periods=64,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    supports_long_context=True,
)
REDUCED = CONFIG.reduced()
