"""gemma3-27b — dense, 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ArchConfig, LayerSpec

_LOCAL = LayerSpec(mixer="attn", ffn="mlp", window=1024)
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp", window=None)

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense", source="hf:google/gemma-3-1b-pt",
    d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144,
    head_dim=5376 // 32, qk_norm=True, act="gelu", rope_theta=1_000_000.0,
    # 62 layers = 10 x (5 local + 1 global) + 2 local remainder
    period=(_LOCAL,) * 5 + (_GLOBAL,), n_periods=10,
    remainder=(_LOCAL, _LOCAL),
    supports_long_context=True,  # local layers cache only `window`
)
REDUCED = CONFIG.reduced(period=(_LOCAL, _GLOBAL), remainder=())
