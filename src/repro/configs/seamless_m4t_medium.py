"""seamless-m4t-medium — encoder-decoder, multimodal audio->text
[arXiv:2308.11596]. Audio frontend (mel + conv) is a STUB; input_specs
supplies frame embeddings at seq_len//4. 12 encoder + 12 decoder layers."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", source="arXiv:2308.11596",
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    act="gelu",
    period=(LayerSpec(mixer="attn", ffn="mlp"),), n_periods=12,
    encoder_layers=12, enc_len_ratio=4,
)
REDUCED = CONFIG.reduced()
