"""zamba2-7b — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 81 layers = 13 x (5 mamba + 1 mamba-with-shared-attn)
+ 3 mamba remainder; the shared block params are reused at every
insertion (concat(h, emb0) at 2*d_model)."""
from repro.configs.base import ArchConfig, LayerSpec

_M = LayerSpec(mixer="mamba", ffn="none")
_MS = LayerSpec(mixer="mamba", ffn="none", shared_attn=True)

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    act="silu",
    period=(_M,) * 5 + (_MS,), n_periods=13, remainder=(_M, _M, _M),
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=2,
    shared_attn_heads=32,
    supports_long_context=True,  # SSM state is O(1) in sequence length
)
REDUCED = CONFIG.reduced(period=(_M, _MS), remainder=(), ssm_groups=1)
