"""Training driver: loss, train_step, and a runnable CPU loop.

``make_train_step(model, mesh_ctx)`` builds the pjit-able step used by
both the end-to-end example (examples/train_lm.py) and the multi-pod
dry-run (train_4k shape).
"""

from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, get_config, get_reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.api import Model
from repro.optim import adamw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def make_loss_fn(model: Model, constrain=None, remat: bool = True):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, constrain=constrain, remat=remat)
        labels = batch["labels"]
        # VLM prefix positions have no labels: forward prepends
        # n_prefix_tokens embeddings, so logits is longer than tokens.
        S = labels.shape[1]
        loss = cross_entropy(logits[:, -S:], labels)
        return loss + 0.01 * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    constrain=None,
    remat: bool = True,
):
    loss_fn = make_loss_fn(model, constrain, remat)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def run_training(
    arch: str,
    steps: int = 20,
    reduced: bool = True,
    seq_len: int = 128,
    batch: int = 4,
    log_every: int = 5,
    ckpt_path: str | None = None,
    save_every: int = 0,
) -> list[dict[str, float]]:
    cfg = get_reduced(arch) if reduced else get_config(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=steps)
    opt_state = adamw.init_state(params)
    start_step = 0
    if ckpt_path:
        import os

        from repro.checkpoint import ckpt

        if os.path.exists(ckpt_path + ".npz"):
            (params, opt_state), meta = ckpt.restore(
                ckpt_path, (params, opt_state)
            )
            start_step = int(meta["step"])
            print(f"resumed from {ckpt_path} at step {start_step}")
    step_fn = jax.jit(make_train_step(model, opt_cfg, remat=False))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=batch))
    history = []
    for step in range(start_step, steps):
        raw = pipe.batch_at(step)
        b: dict[str, Any] = {
            "tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"]),
        }
        if cfg.n_prefix_tokens:
            b["prefix_embeds"] = jnp.zeros(
                (batch, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "audio":
            b["frames"] = jnp.zeros(
                (batch, max(seq_len // cfg.enc_len_ratio, 1), cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, b)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["wall_s"] = time.perf_counter() - t0
        history.append(metrics)
        if step % log_every == 0:
            print(f"step {step}: loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['grad_norm']:.3f} {metrics['wall_s']:.2f}s")
        if ckpt_path and save_every and (step + 1) % save_every == 0:
            from repro.checkpoint import ckpt

            # exact resume: the pipeline is seekable by step, so saving
            # (params, opt_state, step) is the complete training state
            ckpt.save(ckpt_path, (params, opt_state), meta={"step": step + 1})
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true", help="use the full config")
    args = ap.parse_args()
    hist = run_training(
        args.arch, steps=args.steps, reduced=not args.full,
        seq_len=args.seq_len, batch=args.batch,
    )
    print(f"final loss: {hist[-1]['loss']:.4f} (first {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
