"""HLO-text cost analyzer with while-loop trip-count awareness.

Why: XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
ignoring the trip count (verified by microbenchmark: a 10-iteration scan
of a 512³ matmul reports the flops of one iteration). Our layer stacks are
``lax.scan`` loops, so flops/bytes/collective-bytes would be understated
by ~n_layers. This module parses ``compiled.as_text()`` into a call graph
and rolls costs up with multipliers:

* ``while``    -> body + cond costs × trip count (extracted from the
  ``constant(N)`` in the condition computation — the form jax scans emit;
  unknown conditions fall back to ×1 and are reported).
* ``fusion``   -> called computation's flops (its internal bytes are not
  HBM traffic; the fusion instruction's operands/results are).
* ``call``/``conditional`` -> callee × 1 (conditionals: max over branches).

Costs:
* flops: 2·M·N·K for ``dot`` (from operand shapes + contracting/batch
  dims), result-elements for other arithmetic ops.
* bytes: operands + results of top-level instructions, skipping
  no-cost ops (parameter/constant/tuple/get-tuple-element/bitcast).
* collective bytes: operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (``-start`` variants
  counted, ``-done`` skipped).

Validated against ``cost_analysis()`` on loop-free programs in
tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%[\w.\-]+")
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _elements(type_str: str) -> int:
    n = 1
    for d in _shape_dims(type_str):
        n *= d
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""

    @property
    def attrs_literal(self) -> str | None:
        """For ``constant(N)`` instructions: the literal text."""
        if self.opcode == "constant":
            return self.raw_operands.strip()
        return None


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    by_name: dict[str, Instruction]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: dict | None = None
    collective_counts: dict | None = None
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k,
            self.bytes * k,
            self.collective_bytes * k,
            {n: b * k for n, b in (self.collective_bytes_by_kind or {}).items()},
            {n: c * k for n, c in (self.collective_counts or {}).items()},
            self.unknown_trip_loops,
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        for n, b in (other.collective_bytes_by_kind or {}).items():
            d = self.collective_bytes_by_kind
            d[n] = d.get(n, 0) + b
        for n, c in (other.collective_counts or {}).items():
            d = self.collective_counts
            d[n] = d.get(n, 0) + c
        self.unknown_trip_loops += other.unknown_trip_loops


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({computation name -> Computation}, entry name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        header = re.match(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.+\{$", s)
        if header and not line.startswith(" "):
            name = header.group(2)
            cur = Computation(name, [], {})
            comps[name] = cur
            if header.group(1):
                entry = name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = re.match(r"^(ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$", s)
        if not m:
            continue
        rest = m.group(3)
        # result type = everything up to the opcode token; opcode is the
        # first bare word followed by '('
        om = re.search(r"\s([\w\-]+)\(", rest)
        if not om:
            continue
        result_type = rest[: om.start()].strip()
        opcode = om.group(1)
        # operand region: balanced parens from om.end()-1
        depth = 1
        j = om.end()
        while j < len(rest) and depth:
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
            j += 1
        operand_str = rest[om.end() : j - 1]
        attrs = rest[j:]
        inst = Instruction(
            name=m.group(2),
            result_type=result_type,
            opcode=opcode,
            operands=_NAME_RE.findall(operand_str),
            attrs=attrs,
            raw_operands=operand_str,
        )
        cur.instructions.append(inst)
        cur.by_name[inst.name] = inst
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _operand_type(comp: Computation, name: str) -> str:
    inst = comp.by_name.get(name)
    return inst.result_type if inst else ""


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    lhs_t = _operand_type(comp, inst.operands[0]) if inst.operands else ""
    rhs_t = _operand_type(comp, inst.operands[1]) if len(inst.operands) > 1 else ""
    lhs, rhs = _shape_dims(lhs_t), _shape_dims(rhs_t)
    if not lhs or not rhs:
        return 0.0

    def dims_of(attr):
        m = re.search(attr + r"=\{([0-9,]*)\}", inst.attrs)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]

    lc = dims_of("lhs_contracting_dims")
    lb = dims_of("lhs_batch_dims")
    batch = 1
    for d in lb:
        batch *= lhs[d]
    contract = 1
    for d in lc:
        contract *= lhs[d]
    m_ = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m_ *= d
    rc = dims_of("rhs_contracting_dims")
    rb = dims_of("rhs_batch_dims")
    n_ = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n_ *= d
    return 2.0 * batch * m_ * n_ * contract


def _trip_from_literals(cond: Computation, comps: dict[str, Computation]) -> int | None:
    """jax-emitted scan conditions compare the induction variable against a
    ``constant(N)``; take the largest integer constant in the condition
    (descending into its fusions)."""
    best = None
    for inst in cond.instructions:
        lit = inst.attrs_literal
        if lit is not None:
            try:
                v = int(lit)
            except ValueError:
                continue
            best = v if best is None else max(best, v)
        if inst.opcode == "fusion":
            callee = _called(inst)
            if callee and callee in comps:
                sub = _trip_from_literals(comps[callee], comps)
                if sub is not None:
                    best = sub if best is None else max(best, sub)
    return best


def _called(inst: Instruction) -> str | None:
    m = re.search(r"calls=(%[\w.\-]+)", inst.attrs)
    if m:
        return m.group(1)
    m = re.search(r"to_apply=(%[\w.\-]+)", inst.attrs)
    if m:
        return m.group(1)
    return None


_LAYOUT_ONLY = {
    "parameter", "convert", "bitcast", "copy", "transpose", "reshape",
    "broadcast", "constant", "tuple", "get-tuple-element",
}


def _fusion_kind(comps: dict[str, Computation], callee: str) -> str:
    """Classify a fusion body for byte accounting:
    * "layout"  — converts/transposes only. The CPU backend emulates bf16
      dots by materializing f32 converts of ENTIRE operands (a KV cache!)
      which does not happen on TPU's native-bf16 MXU -> count result once.
    * "scatter" — contains scatter/DUS; in-place on TPU -> count the
      update region twice (read+write).
    * "compute" — everything else -> operands + result.
    """
    comp = comps.get(callee)
    if comp is None:
        return "compute"
    ops = {i.opcode for i in comp.instructions}
    if ops & {"scatter", "dynamic-update-slice"}:
        return "scatter"
    if ops <= _LAYOUT_ONLY:
        return "layout"
    # bf16->f32 upcast feeding a dot: the CPU backend materializes the f32
    # copy; TPU reads bf16 natively. Detect: f32 root with a same-element-
    # count bf16 parameter -> count the bf16 source once ("upcast").
    root = comp.instructions[-1] if comp.instructions else None
    if root is not None and root.result_type.startswith("f32"):
        n_root = _elements(root.result_type)
        for i in comp.instructions:
            if i.opcode == "parameter" and i.result_type.startswith("bf16") \
                    and _elements(i.result_type) == n_root:
                return "upcast"
    return "compute"


def _fusion_scatter_update_bytes(comps, callee: str) -> int:
    comp = comps.get(callee)
    if comp is None:
        return 0
    total = 0
    for i in comp.instructions:
        if i.opcode == "scatter" and len(i.operands) > 2:
            total += _type_bytes(_operand_type(comp, i.operands[2]))
        elif i.opcode == "dynamic-update-slice" and len(i.operands) > 1:
            total += _type_bytes(_operand_type(comp, i.operands[1]))
    return total


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    memo: dict[str, HloCost] = {}

    def comp_cost(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = HloCost(collective_bytes_by_kind={}, collective_counts={})
        if comp is None:
            memo[name] = out
            return out
        memo[name] = out  # break cycles defensively
        for inst in comp.instructions:
            op = inst.opcode
            if op in _ZERO_COST:
                continue
            if op == "while":
                body = re.search(r"body=(%[\w.\-]+)", inst.attrs)
                cond = re.search(r"condition=(%[\w.\-]+)", inst.attrs)
                trips = None
                if cond:
                    trips = _trip_from_literals(comps[cond.group(1)], comps) \
                        if cond.group(1) in comps else None
                if trips is None:
                    trips = 1
                    out.unknown_trip_loops += 1
                if body and body.group(1) in comps:
                    out.add(comp_cost(body.group(1)).scaled(trips))
                if cond and cond.group(1) in comps:
                    out.add(comp_cost(cond.group(1)).scaled(trips))
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", inst.attrs)
                names = _NAME_RE.findall(branches[0]) if branches else []
                m2 = re.findall(r"(?:true|false)_computation=(%[\w.\-]+)", inst.attrs)
                names += m2
                sub = [comp_cost(n) for n in names if n in comps]
                if sub:
                    mx = max(sub, key=lambda c: c.flops + c.bytes)
                    out.add(mx)
                continue
            if op in ("call", "async-start"):
                callee = _called(inst)
                if callee and callee in comps:
                    out.add(comp_cost(callee))
            fusion_kind = None
            if op == "fusion":
                callee = _called(inst)
                if callee and callee in comps:
                    fusion_kind = _fusion_kind(comps, callee)
                    sub = comp_cost(callee)
                    # fusion internals are registers/VMEM, not HBM traffic:
                    # take its flops/collectives, drop its bytes
                    out.flops += sub.flops
                    out.collective_bytes += sub.collective_bytes
                    for n, b in (sub.collective_bytes_by_kind or {}).items():
                        out.collective_bytes_by_kind[n] = (
                            out.collective_bytes_by_kind.get(n, 0) + b
                        )
                    for n, c in (sub.collective_counts or {}).items():
                        out.collective_counts[n] = (
                            out.collective_counts.get(n, 0) + c
                        )
                    out.unknown_trip_loops += sub.unknown_trip_loops
            # --- local instruction costs
            base = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base is not None and not op.endswith("-done"):
                obytes = sum(
                    _type_bytes(_operand_type(comp, o)) for o in inst.operands
                )
                out.collective_bytes += obytes
                out.collective_bytes_by_kind[base] = (
                    out.collective_bytes_by_kind.get(base, 0) + obytes
                )
                out.collective_counts[base] = (
                    out.collective_counts.get(base, 0) + 1
                )
            # bytes: operands + result (skip pure control ops handled above).
            # Slice-family ops move only the sliced window; update-in-place
            # ops (DUS / scatter) touch only the update region — XLA
            # performs them in place (donated/aliased buffers at the jit
            # boundary, ordinary liveness inside a program), so counting
            # the full buffer would overstate HBM traffic by the
            # cache-size/update-size ratio.
            if fusion_kind == "layout":
                out.bytes += _type_bytes(inst.result_type)
            elif fusion_kind == "upcast":
                # one native-bf16 read on TPU (half the f32 result size)
                out.bytes += _type_bytes(inst.result_type) // 2
            elif fusion_kind == "scatter":
                out.bytes += 2 * _fusion_scatter_update_bytes(
                    comps, _called(inst)
                )
            elif op in ("slice", "dynamic-slice", "gather"):
                out.bytes += 2 * _type_bytes(inst.result_type)
            elif op == "dynamic-update-slice":
                upd = (
                    _type_bytes(_operand_type(comp, inst.operands[1]))
                    if len(inst.operands) > 1 else 0
                )
                out.bytes += 2 * upd
            elif op == "scatter":
                upd = (
                    _type_bytes(_operand_type(comp, inst.operands[2]))
                    if len(inst.operands) > 2 else 0
                )
                out.bytes += 2 * upd
            elif op not in ("while", "conditional", "call"):
                obytes = sum(
                    _type_bytes(_operand_type(comp, o)) for o in inst.operands
                )
                out.bytes += obytes + _type_bytes(inst.result_type)
            # flops
            if op == "dot":
                out.flops += _dot_flops(comp, inst)
            elif op == "convolution":
                # rough: 2 * output elements * kernel elements (unused by
                # our models; kept for completeness)
                out.flops += 2.0 * _elements(inst.result_type)
            elif op not in ("fusion", "while", "conditional", "call",
                            "copy", "broadcast", "transpose", "slice",
                            "dynamic-slice", "dynamic-update-slice",
                            "concatenate", "pad", "reverse", "gather",
                            "scatter", "select", "compare", "convert") \
                    and base is None:
                out.flops += float(_elements(inst.result_type))
        return out

    return comp_cost(entry)


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions: older
    releases return a one-element list of dicts (per device), newer ones a
    plain dict; some backends return None or raise. Always returns a dict
    (empty when unavailable)."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        # degrade, but not silently: a zeroed xla_* column with no signal
        # would corrupt roofline comparisons undetected
        import warnings

        warnings.warn(f"cost_analysis unavailable: {type(e).__name__}: {e}")
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
