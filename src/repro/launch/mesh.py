"""Production mesh + sharding rules (TP on `model`, FSDP on `data`).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod = v5e-256 as (data=16, model=16); multi-pod
adds a leading ``pod`` axis: (pod=2, data=16, model=16) = 512 chips.

Sharding policy (resolved per-architecture for divisibility):
* params: Megatron tensor-parallel on the `model` axis (FFN hidden,
  attention heads/head_dim, experts, vocab) + FSDP on the `data` axis for
  the complementary dimension. Non-divisible dims fall back to replication
  (never GSPMD padding, so the roofline numbers stay clean).
* activations: batch on (pod, data) when divisible, `model`-axis features
  via with_sharding_constraint tags emitted inside the models
  (the ``constrain(x, tag)`` hooks).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Mesh + per-arch resolved activation/parameter rules.

    ``seq_parallel`` (Megatron-LM sequence parallelism, §Perf iteration):
    shard the residual stream's sequence dim over `model` so norms,
    residual adds and the scan-carried remat activations are 1/TP-degree
    per device; XLA inserts the all-gather at matmul entry /
    reduce-scatter at exit.
    """

    mesh: Mesh
    cfg: ArchConfig
    seq_parallel: bool = False

    # ---- axis sizes
    @property
    def model_size(self) -> int:
        return self.mesh.shape["model"]

    @property
    def data_size(self) -> int:
        d = self.mesh.shape["data"]
        return d * self.mesh.shape.get("pod", 1)

    @property
    def batch_axes(self):
        return ("pod", "data") if "pod" in self.mesh.shape else ("data",)

    # ---- helpers
    def _axis_if(self, dim: int, axis, size: int):
        return axis if dim % size == 0 and dim >= size else None

    def batch_axis_for(self, b: int):
        """Shard batch over (pod, data) when divisible, else just data,
        else replicate (long_500k's batch=1)."""
        full = int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))
        if b % full == 0:
            return self.batch_axes
        if b % self.mesh.shape["data"] == 0:
            return ("data",)
        return None

    # ---- activation constraint hook (models call constrain(x, tag))
    def constrain(self, x: jax.Array, tag: str) -> jax.Array:
        spec = self.activation_spec(x, tag)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def activation_spec(self, x, tag: str):
        cfg, ms = self.cfg, self.model_size
        B = x.shape[0]
        batch = self.batch_axis_for(B)
        if tag == "hidden":  # (B, S, D)
            if (
                self.seq_parallel
                and x.ndim == 3
                and x.shape[1] % ms == 0
                and x.shape[1] >= ms
            ):
                return P(batch, "model", None)
            return P(batch, None, None)
        if tag == "ffn":  # (B, S, F)
            return P(batch, None, self._axis_if(x.shape[-1], "model", ms))
        if tag == "heads":  # (B, S, H, hd)
            # NEVER shard head_dim: the score einsum contracts it, turning
            # every score tensor into a partial sum that must be
            # all-reduced (measured 2×8.2 TB/step on llama4 prefill —
            # §Perf). Non-divisible head counts replicate; K/V pick up the
            # sequence dim instead (context-parallel attention).
            h_ax = self._axis_if(x.shape[-2], "model", ms)
            return P(batch, None, h_ax, None)
        if tag == "kv_heads":  # (B, T, KV, hd)
            kv_ax = self._axis_if(x.shape[-2], "model", ms)
            if kv_ax is None:
                # context parallelism: shard the cache/sequence dim; the
                # softmax over the sharded axis costs only a tiny
                # max/sum all-reduce, and the PV contraction all-reduces
                # one (B,C,H·hd) tile instead of (B,H,C,T) scores.
                t_ax = self._axis_if(x.shape[1], "model", ms)
                return P(batch, t_ax, None, None)
            return P(batch, None, kv_ax, None)
        if tag == "ssm_heads":  # (B, S, H, P)
            h_ax = self._axis_if(x.shape[-2], "model", ms)
            return P(batch, None, h_ax, None)
        if tag == "experts":  # (B, G, E, C, D)
            e_ax = self._axis_if(x.shape[2], "model", ms)
            return P(batch, None, e_ax, None, None)
        if tag == "experts_ff":  # (B, G, E, C, F)
            e_ax = self._axis_if(x.shape[2], "model", ms)
            f_ax = self._axis_if(x.shape[-1], "model", ms) if e_ax is None else None
            return P(batch, None, e_ax, None, f_ax)
        if tag == "logits":  # (B, S, V) or (B, V)
            # vocab dims are huge and rarely divisible (seamless 256206,
            # internvl 151655): GSPMD's padded uneven sharding is far
            # cheaper than replicating a (B,S,V) fp32 tensor — measured
            # 145 GB/chip on seamless train without this.
            v_ax = "model" if x.shape[-1] >= ms else None
            if x.ndim == 3:
                return P(batch, None, v_ax)
            return P(batch, v_ax)
        return None

    # ---- parameter shardings
    def param_spec(self, path: str, x) -> P:
        """Rule-based param partitioning from the pytree path + shape."""
        ms, cfg = self.model_size, self.cfg
        fsdp = "data"  # FSDP axis for the complementary dim
        shape = x.shape
        nd = x.ndim
        # strip the stacked scan axis (period params have leading n_periods)
        lead = 1 if "period" in path and nd >= 1 else 0
        dims = shape[lead:]

        def fit(d, axis_size):
            return d % axis_size == 0 and d >= axis_size

        name = path.rsplit("/", 1)[-1] if "/" in path else path
        spec: list = [None] * nd

        def put(rel_idx, axis, size):
            d = dims[rel_idx]
            if fit(d, size) and axis not in spec:
                spec[lead + rel_idx] = axis

        if name == "embed":
            # jit ARGUMENT shardings must divide evenly, so non-divisible
            # vocabs (seamless 256206) keep the vocab dim replicated here;
            # the logits activation constraint (uneven sharding is legal
            # inside the program) still distributes the big (B,S,V) tensor.
            put(0, "model", ms)  # vocab
            put(1, fsdp, self.mesh.shape["data"])
            return P(*spec)
        if len(dims) == 0:
            return P(*spec)
        if name in ("wq", "wk", "wv", "w_in", "w_gate", "in_proj") and len(dims) == 2:
            put(1, "model", ms)  # output features (heads*hd / d_ff / inner)
            put(0, fsdp, self.mesh.shape["data"])
            return P(*spec)
        if name in ("wo", "w_out", "out_proj", "out") and len(dims) == 2:
            put(0, "model", ms)  # input features
            put(1, fsdp, self.mesh.shape["data"])
            return P(*spec)
        if len(dims) == 3:  # MoE expert stacks (E, d_in, d_out)
            if fit(dims[0], ms):
                put(0, "model", ms)
                put(1, fsdp, self.mesh.shape["data"])
            else:
                # experts not divisible (granite's 40): shard the ff dim.
                # (§Perf note: dropping the FSDP dim here was tried to kill
                # the per-layer grad all-reduces and REFUTED — the
                # collectives are the stacked-scan grad sync, which XLA
                # keeps inside the backward loop regardless; see
                # EXPERIMENTS.md §Perf pair-4 investigation.)
                ff_rel = 2 if name in ("w_in", "w_gate") else 1
                put(ff_rel, "model", ms)
                put(2 if ff_rel == 1 else 1, fsdp, self.mesh.shape["data"])
            return P(*spec)
        if name == "router" and len(dims) == 2:
            return P(*spec)
        if name == "conv_w" and len(dims) == 2:
            put(1, "model", ms)  # conv channels follow the inner dim
            return P(*spec)
        if len(dims) == 1:
            # biases / norms / per-head scalars: replicate (cheap)
            return P(*spec)
        if len(dims) == 2:
            put(1, "model", ms)
            put(0, fsdp, self.mesh.shape["data"])
            return P(*spec)
        return P(*spec)

    def param_shardings(self, params: Any):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)

        def path_str(p):
            return "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in p
            )

        specs = [
            NamedSharding(self.mesh, self.param_spec(path_str(p), x))
            for p, x in flat
        ]
        return jax.tree_util.tree_unflatten(treedef, specs)

    def cache_shardings(self, caches: Any):
        """KV/state caches: batch over (pod, data) + a trailing heads or
        feature dim over `model` when divisible.

        Batch axis location is structural: decoder caches are
        ``{"period": (leading n_periods axis ⇒ batch = axis 1),
        "remainder": (batch = axis 0)}``; enc-dec caches are
        ``{"self"/"cross": (L, B, …) ⇒ batch = axis 1}``.
        """
        ms = self.model_size

        def spec_for(batch_axis):
            def f(x):
                spec: list = [None] * x.ndim
                b_ax = self.batch_axis_for(x.shape[batch_axis])
                spec[batch_axis] = b_ax
                if b_ax is None and x.ndim > batch_axis + 2:
                    # batch=1 (long_500k): the data axis would idle — shard
                    # the cache sequence dim over it instead (ring-style
                    # decode; the scatter picks the owning shard).
                    t = batch_axis + 1
                    ds = self.mesh.shape["data"]
                    if x.shape[t] % ds == 0 and x.shape[t] >= 16 * ds:
                        spec[t] = "data"
                # shard a trailing heads/features dim on model
                for i in (x.ndim - 2, x.ndim - 1, x.ndim - 3):
                    if (
                        i > batch_axis
                        and spec[i] is None
                        and x.shape[i] % ms == 0
                        and x.shape[i] >= ms
                    ):
                        spec[i] = "model"
                        break
                return NamedSharding(self.mesh, P(*spec))

            return f

        if isinstance(caches, dict) and "period" in caches:
            return {
                "period": jax.tree_util.tree_map(spec_for(1), caches["period"]),
                "remainder": jax.tree_util.tree_map(
                    spec_for(0), caches["remainder"]
                ),
            }
        if isinstance(caches, dict) and "self" in caches:
            return jax.tree_util.tree_map(spec_for(1), caches)
        return jax.tree_util.tree_map(spec_for(0), caches)

    def batch_shardings(self, batch: Any):
        def spec_for(x):
            b_ax = self.batch_axis_for(x.shape[0])
            return NamedSharding(self.mesh, P(b_ax, *([None] * (x.ndim - 1))))

        return jax.tree_util.tree_map(spec_for, batch)

    def replicated(self, tree: Any):
        return jax.tree_util.tree_map(
            lambda x: NamedSharding(self.mesh, P()), tree
        )
