import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The two lines above MUST run before any jax import anywhere — jax locks
the device count at first init. 512 placeholder CPU devices let
``jax.make_mesh`` build the production meshes:

    single pod : (data=16, model=16)        = 256 chips (v5e-256)
    multi-pod  : (pod=2, data=16, model=16) = 512 chips

For each combination we ``jit(step).lower(specs).compile()`` with the
arch's sharding rules, print ``memory_analysis()`` (proves per-device fit)
and ``cost_analysis()`` + HLO collective bytes (feeds §Roofline).

``--activation-plan`` additionally traces each step's jaxpr (shape-level;
params are never materialized) through the paper's planner and reports the
planned activation-arena size next to XLA's temp allocation. Plans are
served from the content-addressed plan cache (core/plan_io), so sweeping
``--all`` re-plans each unique graph once; set ``REPRO_PLAN_CACHE_DIR``
to persist plans across runs (and ``REPRO_PLAN_CACHE_MAX_BYTES`` to cap
the disk tier). ``--search`` additionally runs the memory-aware
order/fusion search (core/order_search, core/fusion_search) over each
traced graph and reports the searched footprint + plan-cache hit rate.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--activation-plan] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.hlo_analysis import analyze as analyze_hlo, xla_cost_analysis
from repro.launch.mesh import ShardingCtx, make_production_mesh
from repro.launch.roofline import Roofline, model_flops
from repro.launch.train import make_train_step
from repro.models.api import INPUT_SHAPES, Model
from repro.optim import adamw


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def build_step(arch: str, shape_name: str, mesh, *, seq_parallel: bool = False):
    """Returns (jitted_fn, arg ShapeDtypeStructs) or (None, reason)."""
    cfg = get_config(arch)
    model = Model.for_config(cfg)
    shape = INPUT_SHAPES[shape_name]
    ok, why = model.supports_shape(shape)
    if not ok:
        return None, why
    ctx = ShardingCtx(mesh, cfg, seq_parallel=seq_parallel)
    constrain = ctx.constrain

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: model.init(key))
    p_shard = ctx.param_shardings(params_shape)
    batch_specs = model.input_specs(shape)
    b_shard = ctx.batch_shardings(batch_specs)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_shape = jax.eval_shape(lambda: adamw.init_state(params_shape))
        o_shard = {
            "step": ctx.replicated(opt_shape["step"]),
            "m": ctx.param_shardings(opt_shape["m"]),
            "v": ctx.param_shardings(opt_shape["v"]),
        }
        step = make_train_step(model, opt_cfg, constrain=constrain, remat=True)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
        )
        return (jitted, (params_shape, opt_shape, batch_specs)), None

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches = model.prefill(params, batch, constrain=constrain)
            return logits, caches

        out_caches = jax.eval_shape(prefill_step, params_shape, batch_specs)[1]
        jitted = jax.jit(
            prefill_step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, ctx.cache_shardings(out_caches)),
        )
        return (jitted, (params_shape, batch_specs)), None

    # decode: ONE token against a seq_len cache
    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len)
    )
    c_shard = ctx.cache_shardings(cache_shape)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    act = jax.ShapeDtypeStruct((B,), jnp.bool_)

    def serve_step(params, token, caches, pos, active):
        return model.decode_step(
            params, token, caches, pos, constrain=constrain, active=active
        )

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_shard, None, c_shard, None, None),
        out_shardings=(None, c_shard),
        donate_argnums=(2,),  # caches update in place (aliased buffers)
    )
    return (jitted, (params_shape, tok, cache_shape, pos, act)), None


def planner_report(jitted, specs, name: str, search: bool = False,
                   state_pytree=None, n_slots: int | None = None,
                   max_len: int = 0) -> dict:
    """Trace the step's jaxpr and run the paper's planner on it.

    ``trace_graph`` on the jitted callable works on ShapeDtypeStructs (no
    parameter materialization) and inlines the pjit body; the plan itself
    comes from/through the content-addressed plan cache. ``search=True``
    additionally runs the memory-aware order/fusion searches over the
    traced graph (each candidate plan served from the same cache) and
    reports the best searched footprint next to the default-order plan.
    For decode steps (``state_pytree`` given) the cross-step slot/KV
    state is laid out too, so the report carries the unified footprint —
    the same two halves a compiled v2 bundle ships.
    """
    from repro.core.planner import plan_graph
    from repro.trace.jaxpr_liveness import trace_graph

    graph = trace_graph(jitted, *specs, name=name)
    plan = plan_graph(graph, mode="offsets", strategy="auto")
    out = {
        "planner_total_gb": plan.total_size / 1e9,
        "planner_lb_gb": plan.lower_bound / 1e9,
        "planner_naive_gb": plan.naive_size / 1e9,
        "planner_strategy": plan.strategy,
        "planner_records": len(plan.records),
        "plan_cache_hit": plan.cache_hit,
        "plan_wall_s": plan.plan_wall_s,
    }
    if state_pytree is not None and n_slots:
        from repro.core.unified import plan_state, state_records_from_pytree

        records = state_records_from_pytree(state_pytree, n_slots=n_slots)
        state = plan_state(records, n_slots=n_slots, max_len=max_len)
        # planned-vs-live: what the decode step's XLA-allocated cache
        # pytree occupies on device (the donated argument bytes) next to
        # the StatePlan's one-arena total — the residency engine's live
        # bytes equal the latter exactly (runtime/residency.py)
        live = sum(r.nbytes for r in records)
        out.update({
            "state_total_gb": state.total_size / 1e9,
            "state_live_gb": live / 1e9,
            "state_plan_overhead": round(state.total_size / max(live, 1), 6),
            "state_leaves": len(state.leaves),
            "unified_total_gb": (plan.total_size + state.total_size) / 1e9,
        })
    if search:
        from repro.core.fusion_search import fusion_search
        from repro.core.order_search import search_order
        from repro.core.plan_io import PlanCache

        cache = PlanCache()
        order_res = search_order(graph, iters=300, seed=0, cache=cache)
        fusion_res = fusion_search(graph, max_rounds=40, cache=cache)
        best = min(order_res.plan.total_size, fusion_res.plan.total_size)
        hits = order_res.cache_hits + fusion_res.cache_hits
        evals = hits + order_res.cache_misses + fusion_res.cache_misses
        out.update({
            "searched_total_gb": best / 1e9,
            "search_delta_gb": (plan.total_size - best) / 1e9,
            "search_fused_groups": fusion_res.n_fused_groups,
            "search_plan_calls": evals,
            "search_cache_hit_rate": round(hits / max(evals, 1), 4),
            "search_wall_s": round(order_res.wall_s + fusion_res.wall_s, 3),
        })
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            seq_parallel: bool = False, activation_plan: bool = False,
            search: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.perf_counter()
    built, why = build_step(arch, shape_name, mesh, seq_parallel=seq_parallel)
    if built is None:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": why,
        }
    jitted, specs = built
    with mesh:
        lowered = jitted.lower(*specs)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # NOTE: XLA's cost_analysis() counts while bodies once (ignores trip
    # count) — see launch/hlo_analysis.py; we use our trip-aware analyzer
    # and keep XLA's numbers for reference.
    hc = analyze_hlo(hlo)
    n_dev = mesh.size
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rl = Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        flops=hc.flops,
        bytes_accessed=hc.bytes,
        collective_bytes=hc.collective_bytes,
        collectives=hc,
        model_flops=model_flops(cfg, shape) / n_dev,
        peak_memory_bytes=float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        ),
    )
    out = {
        "status": "ok",
        **rl.row(),
        "wall_s": time.perf_counter() - t0,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "arg_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "out_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
        "collective_counts": hc.collective_counts,
        "collective_bytes_by_kind": hc.collective_bytes_by_kind,
        "unknown_trip_loops": hc.unknown_trip_loops,
        "xla_flops_per_dev": float(cost.get("flops", 0.0)),
        "xla_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
    }
    if not cost:
        # xla_cost_analysis degraded to {}: flag it in the artifact so the
        # zeroed xla_* reference columns are not mistaken for real values
        out["xla_cost_unavailable"] = True
    if activation_plan or search:
        try:
            decode = shape.kind == "decode"
            out.update(planner_report(
                jitted, specs, f"{arch}-{shape_name}", search=search,
                state_pytree=specs[2] if decode else None,
                n_slots=shape.global_batch if decode else None,
                max_len=shape.seq_len,
            ))
        except Exception as e:  # planner failure must not sink the dry-run
            out["planner_error"] = f"{type(e).__name__}: {e}"
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--activation-plan", action="store_true",
                    help="run the paper's planner on each step's jaxpr")
    ap.add_argument("--search", action="store_true",
                    help="also run the memory-aware order/fusion search "
                         "over each traced graph (implies --activation-plan)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s, False))
                combos.append((a, s, True))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        combos = [(args.arch, args.shape, args.multi_pod)]

    results = []
    for arch, shape, mp in combos:
        try:
            res = run_one(arch, shape, mp, seq_parallel=args.seq_parallel,
                          activation_plan=args.activation_plan,
                          search=args.search)
        except Exception as e:  # a dry-run failure is a bug in our system
            res = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        results.append(res)
        print(json.dumps(res, default=str))
        sys.stdout.flush()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_bad = sum(r["status"] == "error" for r in results)
    print(f"# {len(results)} combos, {n_bad} errors", file=sys.stderr)
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
