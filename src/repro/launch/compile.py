"""AOT plan compiler: decode graph -> unified memory plan -> bundle.

The offline half of the compile→artifact→serve pipeline. For one
``(arch, n_slots, max_len, dtype)`` serving bucket this entrypoint:

1. traces the decode step to its liveness graph **at the shape level**
   (``jax.eval_shape`` parameter/cache pytrees — no weights are ever
   materialized, so compiling a plan for a 400B-parameter config costs
   megabytes, not terabytes) and derives the cross-step state records
   from the same shape-level cache pytree;
2. submits ONE :class:`~repro.core.unified.PlanSpec` to the unified
   facade (``repro.core.plan``): the activation half runs the paper's
   Offset Calculation portfolio — with ``--search`` also the memory-aware
   topological-order annealing and the MAFAT-style fusion search
   (``core/order_search`` / ``core/fusion_search``) against the cached
   planner — and the cross-step half gets the slot/KV shared-objects
   layout with concrete offsets;
3. gates the result through the static analyzer (default on; ``--no-lint``
   to skip): the O(n log n) soundness certifier
   (``repro.analysis.soundness``) re-derives liveness and proves the
   activation arena and state layout collision-free, and the bundle
   self-lint (``repro.analysis.bundle_lint``) checks fingerprint/shape
   coherence — error findings refuse the publish
   (:class:`repro.analysis.LintGateError`);
4. AOT-compiles the bucket's decode executables (decode step, slot
   reset, scan block — the exact functions the state backends jit,
   ``runtime/aot.py``) and serializes them into the bundle, so a served
   node performs **zero XLA compiles** on top of the zero traces / zero
   planner calls. Runs *behind* the lint gate (an unsound plan is
   refused before the expensive compiles), and the resulting executables
   are themselves audited (donation aliasing preserved through
   serialization, ``analysis/decode_lint.lint_executables``) before
   publish. ``--no-aot`` skips this step (smaller bundles, lazy-compile
   serving);
5. publishes a versioned, fingerprinted v4
   :class:`~repro.core.artifact.PlanBundle` carrying all of the above —
   plus, under ``--prefill-len``, the planned full-sequence *prefill*
   activation arena (the long-lifetime regime; the prefill shape joins
   the fingerprint and the bucket key) —
   into a content-addressed manifest directory that
   ``InferenceEngine(session=PlanSession.from_manifest(dir))`` /
   ``launch/serve.py --plan-bundle`` serve from without tracing,
   planning, laying anything out, or compiling anything.

``--all`` sweeps a whole fleet's bucket grid — every selected arch ×
``--slots-list`` × ``--max-lens`` (× ``--dtypes``) — into one manifest,
so ``serve.py`` bucket auto-selection (nearest compiled
``max_len >= requested``) can answer any admissible request with zero
traces and zero planner calls.

Usage:
    PYTHONPATH=src python -m repro.launch.compile --arch qwen3-0.6b \
        --search [--full] [--slots 4] [--max-len 128] [--out plan_artifacts]
    PYTHONPATH=src python -m repro.launch.compile --all \
        --slots-list 2 4 --max-lens 64 128 256 --out plan_artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import shlex
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, ArchConfig, get_config, get_reduced
from repro.core.artifact import (
    BundleManifest,
    PlanBundle,
    bucket_key,
    graph_fingerprint,
    serve_fingerprint,
)
from repro.core.fusion_search import FusionSearchResult
from repro.core.graph import Graph
from repro.core.order_search import OrderSearchResult
from repro.core.plan_io import PlanCache
from repro.core.planner import MemoryPlan
from repro.core.unified import (
    PlanSpec,
    UnifiedPlan,
    detect_state_axes,
    plan as plan_unified,
    state_records_from_pytree,
)
from repro.models.api import Model, ShapeSpec
from repro.trace.jaxpr_liveness import trace_graph

DEFAULT_BUNDLE_DIR = "plan_artifacts"


@dataclasses.dataclass
class CompileResult:
    bundle: PlanBundle
    graph: Graph
    unified: UnifiedPlan
    greedy_plan: MemoryPlan
    order_result: OrderSearchResult | None
    fusion_result: FusionSearchResult | None
    wall_s: float

    @property
    def searched_total(self) -> int:
        return self.bundle.plan.total_size

    def summary(self) -> str:
        lines = [self.bundle.summary()]
        if self.order_result is not None and self.fusion_result is not None:
            evals = (
                self.order_result.evaluations + self.fusion_result.evaluations
            )
            hits = (
                self.order_result.cache_hits + self.fusion_result.cache_hits
            )
            lines.append(
                f"search: {evals} plan calls "
                f"({hits / max(evals, 1):.0%} cache hits), "
                f"order {self.order_result.plan.total_size / 2**20:.3f} MiB, "
                f"fused {self.fusion_result.plan.total_size / 2**20:.3f} MiB "
                f"({self.fusion_result.n_fused_groups} groups)"
            )
        lines.append(f"compile wall: {self.wall_s:.2f}s")
        return "\n".join(lines)


def _decode_specs(cfg: ArchConfig, *, n_slots: int, max_len: int):
    """(decode_fn, shape-level args) for the decode step — no weights are
    ever materialized, only avals."""
    if cfg.family == "audio":
        raise NotImplementedError("compile targets decoder-only archs")
    model = Model.for_config(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: model.init(key))
    caches = jax.eval_shape(lambda: model.init_cache(n_slots, max_len))
    tok0 = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
    pos0 = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    act0 = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)

    def decode(p, t, c, pos, act):
        return model.decode_step(p, t, c, pos, active=act)

    return decode, (params, tok0, caches, pos0, act0)


def trace_decode_graph(
    cfg: ArchConfig, *, n_slots: int, max_len: int
) -> Graph:
    """Shape-level trace of the decode step — identical jaxpr (hence
    identical graph and plan) to what the engine would trace with real
    weights, since ``make_jaxpr`` only consumes avals."""
    decode, specs = _decode_specs(cfg, n_slots=n_slots, max_len=max_len)
    return trace_graph(decode, *specs, name=f"{cfg.name}-decode")


def _prefill_specs(cfg: ArchConfig, *, prefill_len: int):
    """(prefill_fn, shape-level args) for the full-sequence prefill of ONE
    request (batch 1 — the engine fills slots one request at a time).
    Works through ``Model.input_specs(kind="prefill")``, so modality
    frontends (prefix embeds, audio frames) are covered uniformly."""
    if prefill_len < 1:
        raise ValueError(f"prefill_len must be >= 1, got {prefill_len}")
    model = Model.for_config(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: model.init(key))
    batch = model.input_specs(
        ShapeSpec(f"prefill_{prefill_len}", prefill_len, 1, "prefill")
    )

    def prefill(p, b):
        return model.prefill(p, b)

    return prefill, (params, batch)


def trace_prefill_graph(cfg: ArchConfig, *, prefill_len: int) -> Graph:
    """Shape-level trace of the full-sequence prefill at ``prefill_len``
    tokens — the long-activation-lifetime regime the paper's strategies
    are strongest in. Same aval-only contract as the decode trace."""
    prefill, specs = _prefill_specs(cfg, prefill_len=prefill_len)
    return trace_graph(
        prefill, *specs, name=f"{cfg.name}-prefill{prefill_len}"
    )


def _measure_xla_temp(
    cfg: ArchConfig, *, n_slots: int, max_len: int
) -> int | None:
    """AOT-compile the decode step (shape-level) and read XLA's temp
    allocation, so bundle-served engines keep the planned-vs-XLA
    validation line without compiling anything at serving time."""
    decode, specs = _decode_specs(cfg, n_slots=n_slots, max_len=max_len)
    try:
        compiled = jax.jit(decode).lower(*specs).compile()
        ma = compiled.memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0)) or None
    except Exception:
        return None


def compile_decode_plan(
    cfg: ArchConfig,
    *,
    n_slots: int,
    max_len: int,
    strategy: str = "auto",
    search: bool = False,
    search_iters: int = 300,
    fusion_rounds: int = 40,
    cache: PlanCache | None = None,
    measure_xla: bool = True,
    block_size: int = 1,
    greedy: bool = True,
    temperature: float = 1.0,
    top_k: int = 0,
    page_size: int | None = None,
    page_pool: int | None = None,
    prefill_len: int | None = None,
    lint: bool = True,
    aot: bool = True,
) -> CompileResult:
    """Trace → unified plan (both halves) → lint gate → AOT executables
    → bundle, in memory.

    ``block_size``/``greedy``/``temperature``/``top_k`` are the serving
    bucket's serve-loop configuration: they join the bundle fingerprint
    (``artifact.serve_fingerprint``), so a bundle compiled for the
    scan-block path self-invalidates against a default host-loop engine
    and vice versa. The planned layouts themselves do not change — the
    decode body traced for planning is the same graph the scan body
    iterates.

    ``prefill_len`` additionally traces and plans the full-sequence
    prefill activation arena at that many tokens; the bundle then carries
    both transient plans (the prefill arena aliases the decode arena —
    the phases never overlap in time) and ``prefill_len`` joins the
    fingerprint and the bucket key (``|pf{S}``)."""
    wall0 = time.perf_counter()
    serve_params = serve_fingerprint(
        block_size=block_size, greedy=greedy,
        temperature=temperature, top_k=top_k,
        page_size=page_size, page_pool=page_pool,
    )
    decode, specs = _decode_specs(cfg, n_slots=n_slots, max_len=max_len)
    graph = trace_graph(decode, *specs, name=f"{cfg.name}-decode")
    # the shape-level cache pytree (specs[2]) feeds the cross-step half
    state_records = state_records_from_pytree(specs[2], n_slots=n_slots)
    prefill_graph = (
        trace_prefill_graph(cfg, prefill_len=prefill_len)
        if prefill_len else None
    )

    unified = plan_unified(PlanSpec(
        graph=graph,
        state_records=state_records,
        cfg=cfg,
        n_slots=n_slots,
        max_len=max_len,
        serve_params=serve_params,
        strategy=strategy,
        search=search,
        search_iters=search_iters,
        fusion_rounds=fusion_rounds,
        cache=cache,
        page_size=page_size,
        page_pool=page_pool,
        prefill_graph=prefill_graph,
        prefill_len=prefill_len,
        state_token_axes=(
            detect_state_axes(
                Model.for_config(cfg).init_cache,
                n_slots=n_slots, max_len=max_len,
            )
            if page_size else None
        ),
    ))
    best_plan = unified.activation

    provenance = {
        "tool": "repro.launch.compile",
        **unified.provenance,
        # with AOT on, the measurement comes free from the pytree-decode
        # executable compile below (no separate throwaway compile)
        "xla_temp_bytes": (
            _measure_xla_temp(cfg, n_slots=n_slots, max_len=max_len)
            if measure_xla and not aot else None
        ),
    }
    if serve_params:
        provenance["serve_params"] = serve_params
    bundle = PlanBundle(
        fingerprint=unified.fingerprint,
        graph_fingerprint=graph_fingerprint(graph),
        arch=cfg.name,
        n_slots=n_slots,
        max_len=max_len,
        dtype=cfg.dtype,
        plan=best_plan,
        state_plan=unified.state,
        n_layers=cfg.n_layers,
        d_model=cfg.d_model,
        order=unified.order,
        fusion_groups=unified.fusion_groups,
        provenance=provenance,
        prefill_plan=unified.prefill,
        prefill_len=prefill_len or 0,
    )
    if lint:
        # the pre-publish gate: soundness certification (sweep-line,
        # independent of every planner) + bundle self-coherence. The O(n²)
        # oracle twin stays in core/validate for tests; this path must
        # scale to full-size graphs.
        from repro.analysis import LintGateError, bundle_lint, soundness
        from repro.analysis.findings import Report

        report = Report()
        report.extend(
            soundness.certify_bundle(bundle), checked="soundness"
        )
        report.extend(
            bundle_lint.lint_bundle(bundle, serve_params=serve_params),
            checked="bundle_lint",
        )
        if not report.ok():
            raise LintGateError(
                report,
                context=f"refusing to publish "
                f"{bucket_key(cfg, n_slots=n_slots, max_len=max_len, page_size=page_size, prefill_len=prefill_len)}",
            )
    if aot:
        # behind the lint gate on purpose: an unsound plan is refused
        # before the expensive XLA compiles. Each executable is the
        # residency impl the serving backend would jit, serialized for
        # zero-compile cold start (runtime/aot.py).
        from repro.runtime.aot import build_decode_executables

        pack, aot_xla_temp = build_decode_executables(
            cfg, unified.state,
            n_slots=n_slots, max_len=max_len,
            block_size=block_size, greedy=greedy,
            temperature=temperature, top_k=top_k,
        )
        if measure_xla and aot_xla_temp is not None:
            provenance = {**provenance, "xla_temp_bytes": aot_xla_temp}
        bundle = dataclasses.replace(
            bundle, executables=pack, provenance=provenance
        )
        if lint:
            # post-serialization audit: the executables must still carry
            # the donation aliasing (and stay free of host transfers) —
            # a serialization path that drops either is refused here
            from repro.analysis import LintGateError, decode_lint
            from repro.analysis.findings import Report

            report = Report().extend(
                decode_lint.lint_executables(bundle),
                checked="decode_lint:executables",
            )
            if not report.ok():
                raise LintGateError(
                    report,
                    context=f"refusing to publish AOT executables for "
                    f"{bucket_key(cfg, n_slots=n_slots, max_len=max_len, page_size=page_size, prefill_len=prefill_len)}",
                )
    outcome = unified.search
    return CompileResult(
        bundle=bundle,
        graph=graph,
        unified=unified,
        greedy_plan=outcome.greedy_plan if outcome is not None else best_plan,
        order_result=outcome.order if outcome is not None else None,
        fusion_result=outcome.fusion if outcome is not None else None,
        wall_s=time.perf_counter() - wall0,
    )


def compile_and_publish(
    cfg: ArchConfig,
    out_dir: str,
    *,
    n_slots: int,
    max_len: int,
    command: str | None = None,
    **kwargs,
) -> CompileResult:
    res = compile_decode_plan(cfg, n_slots=n_slots, max_len=max_len, **kwargs)
    BundleManifest(out_dir).publish(
        bucket_key(cfg, n_slots=n_slots, max_len=max_len,
                   page_size=kwargs.get("page_size"),
                   prefill_len=kwargs.get("prefill_len")),
        res.bundle,
        command=command,
    )
    return res


def sweep_buckets(
    archs: list[str],
    out_dir: str,
    *,
    full: bool = False,
    slots_list: list[int],
    max_lens: list[int],
    dtypes: list[str] | None = None,
    command: str | None = None,
    emit=print,
    explicit_archs: bool = False,
    dropped: list | None = None,
    **kwargs,
) -> list[CompileResult]:
    """The fleet sweep behind ``--all``: every (arch × slots × max_len ×
    dtype) bucket into ONE manifest. Plans are shared through one
    PlanCache across the sweep, so buckets differing only in max_len
    reuse each other's strategy runs when their record sets coincide.

    No silent caps: every arch or bucket the sweep drops is logged with
    its reason (and collected into ``dropped`` when the caller passes a
    list — ``(what, reason)`` pairs), and the sweep ends with a one-line
    drop summary. Audio (encoder-decoder) archs are skipped by default —
    the decode compile path targets decoder-only serving — but an
    explicit ``--archs`` listing (``explicit_archs=True``) opts them in:
    the sweep then *attempts* the compile so the drop reason is the real
    failure, not a guess, and audio archs start sweeping the moment the
    decode path learns to plan them."""
    cache = kwargs.pop("cache", None) or PlanCache()
    results: list[CompileResult] = []
    drops: list[tuple[str, str]] = dropped if dropped is not None else []
    for arch in archs:
        base = get_config(arch) if full else get_reduced(arch)
        if base.family == "audio" and not explicit_archs:
            reason = (
                "audio (encoder-decoder) arch — decode compile path is "
                "decoder-only; pass it via --archs to attempt anyway"
            )
            drops.append((arch, reason))
            emit(f"skip {arch}: {reason}")
            continue
        for dtype in dtypes or [base.dtype]:
            cfg = (
                base if dtype == base.dtype
                else dataclasses.replace(base, dtype=dtype)
            )
            for n_slots in slots_list:
                for max_len in max_lens:
                    key = bucket_key(
                        cfg, n_slots=n_slots, max_len=max_len,
                        page_size=kwargs.get("page_size"),
                        prefill_len=kwargs.get("prefill_len"),
                    )
                    try:
                        res = compile_and_publish(
                            cfg, out_dir, n_slots=n_slots, max_len=max_len,
                            command=command, cache=cache, **kwargs,
                        )
                    except NotImplementedError as e:
                        # un-plannable arch (today: audio opted in via an
                        # explicit --archs) — drop THE BUCKET, loudly
                        drops.append((key, str(e)))
                        emit(f"skip {key}: {e}")
                        continue
                    emit(
                        f"{key}"
                        f": {res.bundle.total_size / 2**20:.3f} MiB unified "
                        f"({res.wall_s:.2f}s)"
                    )
                    results.append(res)
    if drops:
        emit(
            f"dropped {len(drops)} arch(es)/bucket(s): "
            + ", ".join(what for what, _ in drops)
        )
    return results


def main() -> None:
    ap = argparse.ArgumentParser(
        description="compile decode-graph memory plans into serving bundles"
    )
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="one arch (or use --all)")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x slots x max-len) bucket into "
                         "one manifest; restrict archs with --archs")
    ap.add_argument("--archs", nargs="*", choices=ARCH_IDS, default=None,
                    help="arch subset for --all (default: every non-audio "
                         "arch)")
    ap.add_argument("--full", action="store_true",
                    help="compile the full config (default: reduced)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--slots-list", type=int, nargs="*", default=None,
                    help="slot counts for --all (default: --slots)")
    ap.add_argument("--max-lens", type=int, nargs="*", default=None,
                    help="max_len grid for --all (default: --max-len)")
    ap.add_argument("--dtypes", nargs="*", default=None,
                    help="dtype overrides for --all (default: each "
                         "config's own dtype)")
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--search", action="store_true",
                    help="run the order/fusion search on the decode graph")
    ap.add_argument("--iters", type=int, default=300,
                    help="order-search annealing iterations")
    ap.add_argument("--fusion-rounds", type=int, default=40)
    ap.add_argument("--block-size", type=int, default=1,
                    help="serve-loop block size the bundle is compiled "
                         "for (joins the fingerprint; 1 = host loop)")
    ap.add_argument("--sample", action="store_true",
                    help="compile for temperature/top-k sampling instead "
                         "of greedy (joins the fingerprint)")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=None,
                    help="compile a PAGED bucket: carve slot state into "
                         "fixed pages of this many bytes (joins the "
                         "fingerprint and the bucket key)")
    ap.add_argument("--page-pool", type=int, default=None,
                    help="physical pool page count for --page-size "
                         "(default: n_slots x pages-per-slot)")
    ap.add_argument("--prefill-len", type=int, default=None,
                    help="ALSO trace + plan the full-sequence prefill "
                         "activation arena at this many tokens (joins the "
                         "fingerprint and the bucket key as |pf{S}); "
                         "default: decode-only bundle")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the pre-publish static-analysis gate "
                         "(soundness certifier + bundle self-lint)")
    ap.add_argument("--no-aot", action="store_true",
                    help="skip AOT-compiling + serializing the decode "
                         "executables (smaller bundles; served engines "
                         "lazy-compile at the first wave)")
    ap.add_argument("--out", default=DEFAULT_BUNDLE_DIR,
                    help="bundle manifest directory")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable summary line")
    args = ap.parse_args()
    if bool(args.arch) == bool(args.all):
        ap.error("pass exactly one of --arch or --all")

    command = shlex.join(sys.argv)
    if args.all:
        results = sweep_buckets(
            list(args.archs or ARCH_IDS), args.out,
            full=args.full,
            slots_list=args.slots_list or [args.slots],
            max_lens=args.max_lens or [args.max_len],
            dtypes=args.dtypes,
            strategy=args.strategy, search=args.search,
            search_iters=args.iters, fusion_rounds=args.fusion_rounds,
            block_size=args.block_size, greedy=not args.sample,
            temperature=args.temperature, top_k=args.top_k,
            page_size=args.page_size, page_pool=args.page_pool,
            prefill_len=args.prefill_len,
            lint=not args.no_lint, aot=not args.no_aot,
            command=command,
            explicit_archs=args.archs is not None,
            dropped=(dropped := []),
        )
        print(f"published {len(results)} bucket(s) to {args.out}/")
        if args.json:
            print(json.dumps({
                "buckets": len(results),
                "unified_total_bytes": [r.bundle.total_size for r in results],
                "dropped": dropped,
                "wall_s": round(sum(r.wall_s for r in results), 3),
            }))
        return

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    res = compile_and_publish(
        cfg, args.out,
        n_slots=args.slots, max_len=args.max_len,
        strategy=args.strategy, search=args.search,
        search_iters=args.iters, fusion_rounds=args.fusion_rounds,
        block_size=args.block_size, greedy=not args.sample,
        temperature=args.temperature, top_k=args.top_k,
        page_size=args.page_size, page_pool=args.page_pool,
        prefill_len=args.prefill_len,
        lint=not args.no_lint, aot=not args.no_aot,
        command=command,
    )
    print(res.summary())
    print(f"published to {args.out}/ "
          f"(bucket {bucket_key(cfg, n_slots=args.slots, max_len=args.max_len, page_size=args.page_size, prefill_len=args.prefill_len)})")
    if args.json:
        print(json.dumps({
            "arch": args.arch,
            "full": args.full,
            "n_slots": args.slots,
            "max_len": args.max_len,
            "page_size": args.page_size,
            "prefill_len": args.prefill_len,
            "prefill_total_bytes": (
                res.bundle.prefill_plan.total_size
                if res.bundle.prefill_plan else None
            ),
            "greedy_total_bytes": res.greedy_plan.total_size,
            "bundle_total_bytes": res.bundle.plan.total_size,
            "state_total_bytes": (
                res.bundle.state_plan.total_size
                if res.bundle.state_plan else None
            ),
            "unified_total_bytes": res.bundle.total_size,
            "searched": args.search,
            "wall_s": round(res.wall_s, 3),
        }))


if __name__ == "__main__":
    main()
