"""AOT plan compiler: decode graph -> searched memory plan -> bundle.

The offline half of the compile→artifact→serve pipeline. For one
``(arch, n_slots, max_len)`` serving bucket this entrypoint:

1. traces the decode step to its liveness graph **at the shape level**
   (``jax.eval_shape`` parameter/cache pytrees — no weights are ever
   materialized, so compiling a plan for a 400B-parameter config costs
   megabytes, not terabytes);
2. plans it with the paper's Offset Calculation portfolio, and with
   ``--search`` also runs the memory-aware topological-order annealing and
   the MAFAT-style fusion search (``core/order_search`` /
   ``core/fusion_search``) against the cached planner — this is the
   ROADMAP item "retarget search at transformer decode graphs": the outer
   search finally points at graphs with residual-stream slack instead of
   the paper's breadth-pinned convnets;
3. validates the winning plan with the independent first-principles
   checker (``core/validate.check_offsets``);
4. publishes a versioned, fingerprinted :class:`~repro.core.artifact.PlanBundle`
   into a content-addressed manifest directory that
   ``InferenceEngine(plan_bundle=...)`` / ``launch/serve.py --plan-bundle``
   serve from without tracing or planning anything.

Usage:
    PYTHONPATH=src python -m repro.launch.compile --arch qwen3-0.6b \
        --search [--full] [--slots 4] [--max-len 128] [--out plan_artifacts]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import shlex
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, ArchConfig, get_config, get_reduced
from repro.core.artifact import (
    BundleManifest,
    PlanBundle,
    bucket_key,
    decode_fingerprint,
    graph_fingerprint,
)
from repro.core.fusion_search import FusionSearchResult, fusion_search
from repro.core.graph import Graph
from repro.core.order_search import OrderSearchResult, search_order
from repro.core.plan_io import PlanCache
from repro.core.planner import MemoryPlan, plan_graph
from repro.core.validate import check_offsets
from repro.models.api import Model
from repro.trace.jaxpr_liveness import trace_graph

DEFAULT_BUNDLE_DIR = "plan_artifacts"


@dataclasses.dataclass
class CompileResult:
    bundle: PlanBundle
    graph: Graph
    greedy_plan: MemoryPlan
    order_result: OrderSearchResult | None
    fusion_result: FusionSearchResult | None
    wall_s: float

    @property
    def searched_total(self) -> int:
        return self.bundle.plan.total_size

    def summary(self) -> str:
        lines = [self.bundle.summary()]
        if self.order_result is not None and self.fusion_result is not None:
            evals = (
                self.order_result.evaluations + self.fusion_result.evaluations
            )
            hits = (
                self.order_result.cache_hits + self.fusion_result.cache_hits
            )
            lines.append(
                f"search: {evals} plan calls "
                f"({hits / max(evals, 1):.0%} cache hits), "
                f"order {self.order_result.plan.total_size / 2**20:.3f} MiB, "
                f"fused {self.fusion_result.plan.total_size / 2**20:.3f} MiB "
                f"({self.fusion_result.n_fused_groups} groups)"
            )
        lines.append(f"compile wall: {self.wall_s:.2f}s")
        return "\n".join(lines)


def _decode_specs(cfg: ArchConfig, *, n_slots: int, max_len: int):
    """(decode_fn, shape-level args) for the decode step — no weights are
    ever materialized, only avals."""
    if cfg.family == "audio":
        raise NotImplementedError("compile targets decoder-only archs")
    model = Model.for_config(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: model.init(key))
    caches = jax.eval_shape(lambda: model.init_cache(n_slots, max_len))
    tok0 = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
    pos0 = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    act0 = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)

    def decode(p, t, c, pos, act):
        return model.decode_step(p, t, c, pos, active=act)

    return decode, (params, tok0, caches, pos0, act0)


def trace_decode_graph(
    cfg: ArchConfig, *, n_slots: int, max_len: int
) -> Graph:
    """Shape-level trace of the decode step — identical jaxpr (hence
    identical graph and plan) to what the engine would trace with real
    weights, since ``make_jaxpr`` only consumes avals."""
    decode, specs = _decode_specs(cfg, n_slots=n_slots, max_len=max_len)
    return trace_graph(decode, *specs, name=f"{cfg.name}-decode")


def _measure_xla_temp(
    cfg: ArchConfig, *, n_slots: int, max_len: int
) -> int | None:
    """AOT-compile the decode step (shape-level) and read XLA's temp
    allocation, so bundle-served engines keep the planned-vs-XLA
    validation line without compiling anything at serving time."""
    decode, specs = _decode_specs(cfg, n_slots=n_slots, max_len=max_len)
    try:
        compiled = jax.jit(decode).lower(*specs).compile()
        ma = compiled.memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0)) or None
    except Exception:
        return None


def compile_decode_plan(
    cfg: ArchConfig,
    *,
    n_slots: int,
    max_len: int,
    strategy: str = "auto",
    search: bool = False,
    search_iters: int = 300,
    fusion_rounds: int = 40,
    cache: PlanCache | None = None,
    measure_xla: bool = True,
) -> CompileResult:
    """Trace → (search) → plan → validate → bundle, all in memory."""
    wall0 = time.perf_counter()
    graph = trace_decode_graph(cfg, n_slots=n_slots, max_len=max_len)
    greedy_plan = plan_graph(graph, mode="offsets", strategy=strategy)
    check_offsets(greedy_plan.records, greedy_plan)

    best_plan = greedy_plan
    order: list[int] | None = None
    groups: list[list[int]] | None = None
    order_res: OrderSearchResult | None = None
    fusion_res: FusionSearchResult | None = None
    if search:
        search_cache = cache if cache is not None else PlanCache()
        order_res = search_order(
            graph, iters=search_iters, seed=0, strategy=strategy,
            cache=search_cache,
        )
        fusion_res = fusion_search(
            graph, strategy=strategy, max_rounds=fusion_rounds,
            cache=search_cache,
        )
        # both searches honor the never-worse contract; take the smaller
        if fusion_res.plan.total_size < best_plan.total_size and (
            fusion_res.plan.total_size <= order_res.plan.total_size
        ):
            best_plan = fusion_res.plan
            groups = [list(g) for g in fusion_res.groups]
        elif order_res.plan.total_size < best_plan.total_size:
            best_plan = order_res.plan
            order = list(order_res.order)
        if best_plan is not greedy_plan:
            check_offsets(best_plan.records, best_plan)

    provenance: dict = {
        "tool": "repro.launch.compile",
        "strategy_requested": strategy,
        "search": search,
        "graph_ops": len(graph.ops),
        "records": len(best_plan.records),
        "greedy_total_bytes": greedy_plan.total_size,
        "searched_total_bytes": (
            min(order_res.plan.total_size, fusion_res.plan.total_size)
            if search else None
        ),
        "xla_temp_bytes": (
            _measure_xla_temp(cfg, n_slots=n_slots, max_len=max_len)
            if measure_xla else None
        ),
    }
    if search:
        provenance["search_stats"] = {
            "order_total_bytes": order_res.plan.total_size,
            "fused_total_bytes": fusion_res.plan.total_size,
            "fused_groups": fusion_res.n_fused_groups,
            "internalized_bytes": fusion_res.internalized_bytes,
            "evaluations": order_res.evaluations + fusion_res.evaluations,
            "order_iters": search_iters,
            "fusion_rounds": fusion_rounds,
        }
    bundle = PlanBundle(
        fingerprint=decode_fingerprint(cfg, n_slots=n_slots, max_len=max_len),
        graph_fingerprint=graph_fingerprint(graph),
        arch=cfg.name,
        n_slots=n_slots,
        max_len=max_len,
        dtype=cfg.dtype,
        plan=best_plan,
        order=order,
        fusion_groups=groups,
        provenance=provenance,
    )
    return CompileResult(
        bundle=bundle,
        graph=graph,
        greedy_plan=greedy_plan,
        order_result=order_res,
        fusion_result=fusion_res,
        wall_s=time.perf_counter() - wall0,
    )


def compile_and_publish(
    cfg: ArchConfig,
    out_dir: str,
    *,
    n_slots: int,
    max_len: int,
    command: str | None = None,
    **kwargs,
) -> CompileResult:
    res = compile_decode_plan(cfg, n_slots=n_slots, max_len=max_len, **kwargs)
    BundleManifest(out_dir).publish(
        bucket_key(cfg, n_slots=n_slots, max_len=max_len),
        res.bundle,
        command=command,
    )
    return res


def main() -> None:
    ap = argparse.ArgumentParser(
        description="compile a decode-graph memory plan into a serving bundle"
    )
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="compile the full config (default: reduced)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--search", action="store_true",
                    help="run the order/fusion search on the decode graph")
    ap.add_argument("--iters", type=int, default=300,
                    help="order-search annealing iterations")
    ap.add_argument("--fusion-rounds", type=int, default=40)
    ap.add_argument("--out", default=DEFAULT_BUNDLE_DIR,
                    help="bundle manifest directory")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable summary line")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    res = compile_and_publish(
        cfg, args.out,
        n_slots=args.slots, max_len=args.max_len,
        strategy=args.strategy, search=args.search,
        search_iters=args.iters, fusion_rounds=args.fusion_rounds,
        command=shlex.join(sys.argv),
    )
    print(res.summary())
    print(f"published to {args.out}/ "
          f"(bucket {bucket_key(cfg, n_slots=args.slots, max_len=args.max_len)})")
    if args.json:
        print(json.dumps({
            "arch": args.arch,
            "full": args.full,
            "n_slots": args.slots,
            "max_len": args.max_len,
            "greedy_total_bytes": res.greedy_plan.total_size,
            "bundle_total_bytes": res.bundle.plan.total_size,
            "searched": args.search,
            "wall_s": round(res.wall_s, 3),
        }))


if __name__ == "__main__":
    main()
