"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``cost_analysis()`` on the SPMD-partitioned executable reports per-device
FLOPs/bytes. Collective bytes are NOT in cost_analysis — we parse the
optimized HLO text and sum OPERAND sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

# matches e.g. "bf16[8,128]{1,0}" or "f32[]" inside operand lists
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in the optimized HLO."""
    bytes_by: dict[str, int] = {}
    count_by: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE kind(OPERANDS), ..." — find " kind(" after the "="
        eq = s.find("= ")
        if eq < 0:
            continue
        rhs = s[eq + 2 :]
        m = re.search(r"\b([a-z0-9-]+)\(", rhs)
        if not m:
            continue
        kind = m.group(1)
        base = None
        for c in _COLLECTIVES:
            if kind == c or kind.startswith(c + "-"):  # all-gather-start etc.
                base = c
                break
        if base is None or kind.endswith("-done"):
            continue
        operands = rhs[m.end() :]
        depth, end = 1, 0
        for j, ch in enumerate(operands):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        operands = operands[:end]
        total = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands)
        )
        bytes_by[base] = bytes_by.get(base, 0) + total
        count_by[base] = count_by.get(base, 0) + 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float  # per device
    bytes_accessed: float  # per device
    collective_bytes: float  # per device
    collectives: CollectiveStats
    model_flops: float  # 6·N·D (or active-N) whole-step, per device share
    peak_memory_bytes: float  # per-device from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_gflops": self.flops / 1e9,
            "hbm_gb": self.bytes_accessed / 1e9,
            "coll_gb": self.collective_bytes / 1e9,
            "model_flops_ratio": self.useful_flops_ratio,
            "peak_mem_gb": self.peak_memory_bytes / 1e9,
        }


def count_params(cfg) -> int:
    """Parameter count from the config (analytic, no allocation)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim
    per_layer = 0
    n_attn = n_mamba = n_mlp = n_moe = n_shared_attn = 0
    for spec in list(cfg.period) * cfg.n_periods + list(cfg.remainder):
        if spec.mixer == "attn":
            n_attn += 1
        if spec.mixer == "mamba":
            n_mamba += 1
        if spec.ffn == "mlp":
            n_mlp += 1
        if spec.ffn == "moe":
            n_moe += 1
        if spec.shared_attn:
            n_shared_attn += 1
    attn_p = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    gate = 0 if cfg.act == "sq_relu" else 1
    mlp_p = d * f * (2 + gate)
    total = v * d + n_attn * attn_p + n_mlp * mlp_p
    if n_moe:
        moe_p = cfg.n_experts * d * f * 3 + d * cfg.n_experts
        if cfg.shared_expert:
            moe_p += mlp_p
        total += n_moe * moe_p
    if n_mamba:
        from repro.models.ssm import ssm_dims

        d_inner, nheads, conv_dim = ssm_dims(
            d, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
        )
        proj_in = d * (2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + nheads)
        total += n_mamba * (proj_in + d_inner * d + cfg.ssm_conv * conv_dim)
    if n_shared_attn and cfg.shared_attn_heads:
        d2 = 2 * d
        total += d2 * d2 * 4 + d2 * f * 3 + d2 * d  # shared once
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (attn_p + mlp_p)
        total += cfg.n_layers * attn_p  # decoder cross-attention
    return int(total)


def active_params(cfg) -> int:
    """Active params per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return count_params(cfg)
    full = count_params(cfg)
    n_moe = sum(
        1 for s in list(cfg.period) * cfg.n_periods + list(cfg.remainder)
        if s.ffn == "moe"
    )
    expert_p = cfg.n_experts * cfg.d_model * cfg.d_ff * 3
    active_expert_p = cfg.top_k * cfg.d_model * cfg.d_ff * 3
    return int(full - n_moe * (expert_p - active_expert_p))


def model_flops(cfg, shape) -> float:
    """6·N·D for train, 2·N·D for inference forward (D = tokens)."""
    n = active_params(cfg) - cfg.vocab * cfg.d_model  # non-embedding
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    # embedding/unembedding matmul
    unemb = 2.0 * tokens * cfg.d_model * cfg.vocab * (3.0 if shape.kind == "train" else 1.0)
    return mult * n * tokens + unemb
