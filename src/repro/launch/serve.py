"""Serving driver: batched-request inference with the planned engine.

End-to-end example (deliverable (b)): build a reduced model, start the
InferenceEngine — from a precompiled plan artifact when ``--plan-bundle``
points at a bundle file or manifest directory (``launch/compile.py``
output), otherwise planning at construction — submit a batch of requests,
and print cold-start time, throughput and the memory report.

A manifest directory gets **bucket auto-selection**: if the exact
``(arch, slots, max_len, dtype)`` bucket is not compiled, the engine
serves the nearest compiled ``max_len >= requested`` (exact slots/dtype)
— a fleet swept with ``compile.py --all`` answers any admissible request
with zero traces and zero planner calls. ``--exact-bucket`` turns the
selection off.

``--compile-first`` runs the AOT compiler into the bundle directory before
starting the engine (the one-command demo of compile→artifact→serve);
``--compare-cold-start`` additionally measures **time-to-first-token**
(fresh engine construction + one served token, so the baseline pays its
lazy decode-jit XLA compile and the bundle path exercises its AOT
executables) for both the bundle and the plan-at-construction engine,
printing the columns side by side along with the decode compiles each
one paid.

Serving-loop knobs: ``--block-size K`` serves K decode waves per host
sync (the lax.scan block path with on-device sampling + stop detection —
one host sync per block instead of one per wave); ``--sample`` switches
greedy argmax to temperature/top-k sampling (``--temperature``,
``--top-k``, ``--seed``); ``--eos-id`` retires a request when it emits
that token. Block size and sampling knobs join the decode fingerprint,
so ``--compile-first`` publishes a bundle that matches them.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_reduced
from repro.core.shared_objects import from_page_log, from_slot_log
from repro.core.unified import PlanSession
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine


def _time_to_first_token(cfg, params, args, session) -> tuple[float, int]:
    """Construct a fresh engine and serve one request to its first
    emitted token(s) — the process-start→first-token path, including any
    lazy decode-jit XLA compile the engine pays on its first wave.
    Returns ``(seconds, decode compiles paid)``. One full block on the
    scan path (tail blocks of length < K lazy-compile by design, which
    would misattribute a compile to the AOT column)."""
    from repro.runtime import residency

    prompt = (
        np.random.default_rng(1)
        .integers(0, cfg.vocab, size=args.prompt_len)
        .astype(np.int32)
    )
    c0 = residency.COMPILE_CALLS
    t0 = time.perf_counter()
    engine = InferenceEngine(
        cfg, params, n_slots=args.slots, max_len=args.max_len,
        session=session,
        greedy=not args.sample, sample_seed=args.seed,
        temperature=args.temperature, top_k=args.top_k,
        eos_id=args.eos_id, block_size=args.block_size,
        page_size=args.page_size, page_pool=args.page_pool,
    )
    engine.submit(prompt, max_new_tokens=max(args.block_size, 1))
    engine.run_until_done()
    return time.perf_counter() - t0, residency.COMPILE_CALLS - c0


def run(argv: list[str] | None = None) -> dict:
    """Parse args, serve, return a stats dict (tests call this directly)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--plan-bundle", default=None,
                    help="precompiled plan artifact: a bundle file or a "
                         "manifest directory from launch/compile.py")
    ap.add_argument("--exact-bucket", action="store_true",
                    help="disable nearest-bucket auto-selection (serve "
                         "only an exact (slots, max_len, dtype) match)")
    ap.add_argument("--compile-first", action="store_true",
                    help="run the AOT compiler into --plan-bundle (default "
                         "plan_artifacts/) before starting the engine")
    ap.add_argument("--compare-cold-start", action="store_true",
                    help="also time a plan-at-construction engine so the "
                         "artifact's cold-start win is printed side by side")
    ap.add_argument("--block-size", type=int, default=1,
                    help="decode waves per host sync (1 = single-wave host "
                         "loop; K > 1 = lax.scan block decode with "
                         "on-device sampling and stop detection)")
    ap.add_argument("--sample", action="store_true",
                    help="temperature/top-k sampling instead of greedy "
                         "argmax")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0,
                    help="sample seed (per-slot jax.random keys on the "
                         "block path)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a request when it emits this token")
    ap.add_argument("--page-size", type=int, default=None,
                    help="serve the PAGED state backend: per-slot page "
                         "tables over a pool of fixed pages of this many "
                         "bytes (joins the decode fingerprint)")
    ap.add_argument("--page-pool", type=int, default=None,
                    help="physical pool page count for --page-size "
                         "(default: slots x pages-per-slot)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.family == "audio":
        raise SystemExit("serve drives decoder-only archs; pick another --arch")

    bundle_dir = args.plan_bundle
    if args.compile_first:
        from repro.launch.compile import DEFAULT_BUNDLE_DIR, compile_and_publish

        bundle_dir = bundle_dir or DEFAULT_BUNDLE_DIR
        t0 = time.perf_counter()
        res = compile_and_publish(
            cfg, bundle_dir, n_slots=args.slots, max_len=args.max_len,
            command="launch/serve.py --compile-first",
            block_size=args.block_size, greedy=not args.sample,
            temperature=args.temperature, top_k=args.top_k,
            page_size=args.page_size, page_pool=args.page_pool,
        )
        print(f"compiled plan bundle in {time.perf_counter() - t0:.2f}s: "
              f"{res.bundle.summary()}")

    session = None
    if bundle_dir is not None:
        if Path(bundle_dir).is_dir():
            session = PlanSession.from_manifest(
                bundle_dir, nearest=not args.exact_bucket
            )
        else:
            session = PlanSession.from_bundle(bundle_dir)

    model = Model.for_config(cfg)
    print(f"initializing {cfg.name} ({cfg.n_layers}L d={cfg.d_model})...")
    params = model.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    engine = InferenceEngine(
        cfg, params, n_slots=args.slots, max_len=args.max_len,
        session=session,
        greedy=not args.sample, sample_seed=args.seed,
        temperature=args.temperature, top_k=args.top_k,
        eos_id=args.eos_id, block_size=args.block_size,
        page_size=args.page_size, page_pool=args.page_pool,
    )
    cold_start_s = time.perf_counter() - t0
    report = engine.memory_report
    print(f"--- engine cold start: {cold_start_s:.3f}s "
          f"(plan source: {report.plan_source}) ---")
    if engine.max_len != args.max_len:
        print(f"--- bucket auto-selection: requested max_len={args.max_len} "
              f"-> serving the compiled len={engine.max_len} bucket ---")
    if engine.n_slots != args.slots:
        print(f"--- bucket auto-selection: requested slots={args.slots} "
              f"-> serving the compiled slots={engine.n_slots} pool ---")
    cold_start_noartifact_s = None
    ttft_s = ttft_noartifact_s = None
    ttft_compile_calls = ttft_noartifact_compile_calls = None
    if args.compare_cold_start and report.plan_source == "bundle":
        t0 = time.perf_counter()
        InferenceEngine(cfg, params, n_slots=args.slots, max_len=args.max_len)
        cold_start_noartifact_s = time.perf_counter() - t0
        print(f"--- cold start without the artifact: "
              f"{cold_start_noartifact_s:.3f}s "
              f"({cold_start_noartifact_s / max(cold_start_s, 1e-9):.1f}x "
              f"slower) ---")
        ttft_s, ttft_compile_calls = _time_to_first_token(
            cfg, params, args, session
        )
        ttft_noartifact_s, ttft_noartifact_compile_calls = (
            _time_to_first_token(cfg, params, args, None)
        )
        print(f"--- time to first token: {ttft_s:.3f}s from the bundle "
              f"({ttft_compile_calls} decode compiles) vs "
              f"{ttft_noartifact_s:.3f}s plan-at-construction "
              f"({ttft_noartifact_compile_calls} compiles, "
              f"{ttft_noartifact_s / max(ttft_s, 1e-9):.1f}x slower) ---")
    print("--- memory report (the paper's planner on the decode step) ---")
    print(report.summary())
    # planned-vs-live: with residency on, the engine's whole cross-step
    # state is ONE device buffer of exactly the planned size
    print(f"--- live device state: {report.state_live_bytes} B "
          f"(planned {report.state_planned_bytes} B, unified plan "
          f"{engine.unified_plan.total_size} B, residency "
          f"{'on' if report.state_residency else 'off'}) ---")

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(
            rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        )
    from repro.runtime import engine as engine_mod

    syncs0 = engine_mod.HOST_SYNCS
    t0 = time.perf_counter()
    done = engine.run_until_done()
    wall = time.perf_counter() - t0
    host_syncs = engine_mod.HOST_SYNCS - syncs0
    toks = sum(len(r.tokens) for r in done)
    print(f"--- served {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, {engine._wave} waves, "
          f"{host_syncs} host syncs"
          + (f" over {engine.n_blocks} scan blocks"
             if args.block_size > 1 else "")
          + ") ---")
    for r in done[:3]:
        print(f"req {r.request_id}: waves [{r.admitted_wave},{r.finished_wave}] "
              f"tokens {r.tokens[:8]}...")
    # slot-reuse audit: the engine's slot log IS a §4 shared-objects
    # assignment (slots = objects, requests = tensors); from_slot_log
    # raises if any two requests overlapped on one slot
    audit = from_slot_log(engine.slot_log, state_plan=report.state_plan)
    print(f"slot log (slot, admitted, finished, rid): {engine.slot_log}")
    print(f"slot audit: {len(audit.assignment)} requests over "
          f"{engine.n_slots} slots, no interval overlap")
    final_report = engine.memory_report
    pages_total = final_report.state_pages_total
    pages_live = final_report.state_pages_live
    pages_peak = None
    if getattr(engine.state, "paged", False):
        sp = report.state_plan
        pages_peak = engine.state.pages_live_peak
        # page-reuse audit, one level below the slot audit: pool pages
        # are the shared objects; raises if the runtime allocator ever
        # double-assigned a live page
        page_audit = from_page_log(engine.page_log, state_plan=sp)
        print(f"paged state: pool {pages_total} x {sp.page_size} B pages "
              f"(+1 null), peak live {pages_peak} "
              f"({pages_peak * sp.page_size} B = "
              f"{pages_peak / max(pages_total, 1):.0%} of the pool), "
              f"live now {pages_live}")
        print(f"page audit: {len(page_audit.assignment)} (request, page) "
              f"residencies over {pages_total} pool pages, no interval "
              f"overlap")
    return {
        "requests": len(done),
        "tokens": toks,
        "tokens_per_request": {r.request_id: list(r.tokens) for r in done},
        "waves": engine._wave,
        "tokens_per_s": toks / wall if wall > 0 else None,
        "host_syncs": host_syncs,
        "blocks": engine.n_blocks,
        "block_size": args.block_size,
        "slot_log": list(engine.slot_log),
        "cold_start_s": cold_start_s,
        "cold_start_noartifact_s": cold_start_noartifact_s,
        "ttft_s": ttft_s,
        "ttft_compile_calls": ttft_compile_calls,
        "ttft_noartifact_s": ttft_noartifact_s,
        "ttft_noartifact_compile_calls": ttft_noartifact_compile_calls,
        "plan_source": report.plan_source,
        "bundle_warning": report.bundle_warning,
        "aot_executables": list(report.aot_executables),
        "aot_warning": report.aot_warning,
        "plan_total_bytes": report.activation_plan.total_size,
        "state_total_bytes": (
            report.state_plan.total_size if report.state_plan else None
        ),
        "unified_total_bytes": report.unified_total_bytes,
        "state_planned_bytes": report.state_planned_bytes,
        "state_live_bytes": final_report.state_live_bytes,
        "state_residency": report.state_residency,
        "page_size": final_report.state_page_size,
        "state_pages_total": pages_total,
        "state_pages_live": pages_live,
        "state_pages_live_peak": pages_peak,
        "page_log": list(engine.page_log),
        "requested_max_len": args.max_len,
        "effective_max_len": engine.max_len,
        "requested_slots": args.slots,
        "effective_slots": engine.n_slots,
    }


def main() -> None:
    run()


if __name__ == "__main__":
    main()
