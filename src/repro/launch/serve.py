"""Serving driver: batched-request inference with the planned engine.

End-to-end example (deliverable (b)): build a reduced model, start the
InferenceEngine (which plans its activation memory with the paper's
Offset Calculation and reports it vs XLA), submit a batch of requests,
and print throughput + the memory report.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_reduced
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.family == "audio":
        raise SystemExit("serve drives decoder-only archs; pick another --arch")
    model = Model.for_config(cfg)
    print(f"initializing {cfg.name} ({cfg.n_layers}L d={cfg.d_model})...")
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(
        cfg, params, n_slots=args.slots, max_len=args.max_len
    )
    print("--- memory report (the paper's planner on the decode step) ---")
    print(engine.memory_report.summary())

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(
            rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        )
    t0 = time.perf_counter()
    done = engine.run_until_done()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done)
    print(f"--- served {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, {engine._wave} waves) ---")
    for r in done[:3]:
        print(f"req {r.request_id}: waves [{r.admitted_wave},{r.finished_wave}] "
              f"tokens {r.tokens[:8]}...")
    # slot-reuse audit: the engine's §4-style interval log
    print(f"slot log (slot, admitted, finished, rid): {engine.slot_log}")


if __name__ == "__main__":
    main()
