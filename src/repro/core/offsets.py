"""Offset Calculation strategies (paper §5).

One flat memory arena; each intermediate tensor gets a byte offset. Tensors
with intersecting usage intervals must occupy disjoint byte ranges.
Objective: minimize ``max(offset_t + size_t)``.

* ``greedy_by_size_offsets``    — §5.2, Algorithm 3 (best-fit gap search)
* ``greedy_by_breadth_offsets`` — §5.3 (operator-breadth outer order, same
  gap logic)
* ``from_shared_objects``       — §5: any Shared Objects solution converts
  by laying the objects out contiguously.

The gap search runs on :class:`repro.core.interval_set.BestFitArena`: an
interval tree narrows each placement to the already-placed records that
actually overlap the new tensor's lifetime, instead of the seed's rescan
of every placed record (O(n²) total, preserved as the oracle in
:mod:`repro.core.reference`). Placement results are byte-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.interval_set import BestFitArena
from repro.core.records import (
    TensorUsageRecord,
    operator_breadths,
    operator_profiles,
)
from repro.core.shared_objects import SharedObjectsAssignment


@dataclasses.dataclass
class OffsetAssignment:
    strategy: str
    # tensor_id -> byte offset in the arena
    offsets: dict[int, int]
    total_size: int

    def offset_of(self, tensor_id: int) -> int:
        return self.offsets[tensor_id]


def greedy_by_size_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Paper §5.2, Algorithm 3."""
    arena = BestFitArena()
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        arena.place(rec)
    return OffsetAssignment("greedy_by_size", arena.offsets, arena.total)


def greedy_by_breadth_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Paper §5.3: operators in non-increasing breadth order; within each
    profile, unassigned tensors largest-first; same best-fit gap logic."""
    arena = BestFitArena()
    breadths = operator_breadths(records)
    profiles = operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:  # size-descending inside the profile
            if rec.tensor_id in arena.offsets:
                continue
            arena.place(rec)
    return OffsetAssignment("greedy_by_breadth", arena.offsets, arena.total)


def from_shared_objects(asn: SharedObjectsAssignment) -> OffsetAssignment:
    """Lay shared objects out contiguously (paper §5: SO ⇒ offsets; the
    converse does not hold)."""
    base: dict[int, int] = {}
    cursor = 0
    for obj in asn.objects:
        base[obj.object_id] = cursor
        cursor += obj.size
    offsets = {tid: base[oid] for tid, oid in asn.assignment.items()}
    return OffsetAssignment(f"{asn.strategy}+contiguous", offsets, cursor)


STRATEGIES: dict[str, Callable[[Sequence[TensorUsageRecord]], OffsetAssignment]] = {
    "greedy_by_size": greedy_by_size_offsets,
    "greedy_by_breadth": greedy_by_breadth_offsets,
}
