"""Offset Calculation strategies (paper §5).

One flat memory arena; each intermediate tensor gets a byte offset. Tensors
with intersecting usage intervals must occupy disjoint byte ranges.
Objective: minimize ``max(offset_t + size_t)``.

* ``greedy_by_size_offsets``    — §5.2, Algorithm 3 (best-fit gap search)
* ``greedy_by_breadth_offsets`` — §5.3 (operator-breadth outer order, same
  gap logic)
* ``from_shared_objects``       — §5: any Shared Objects solution converts
  by laying the objects out contiguously.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.records import (
    TensorUsageRecord,
    operator_breadths,
    operator_profiles,
)
from repro.core.shared_objects import SharedObjectsAssignment


@dataclasses.dataclass
class OffsetAssignment:
    strategy: str
    # tensor_id -> byte offset in the arena
    offsets: dict[int, int]
    total_size: int

    def offset_of(self, tensor_id: int) -> int:
        return self.offsets[tensor_id]


def _best_fit_offset(
    rec: TensorUsageRecord,
    allocated: list[TensorUsageRecord],
    offsets: dict[int, int],
) -> int:
    """Paper Algorithm 3 L.7–20: scan already-allocated, interval-overlapping
    tensors in increasing offset order; take the smallest gap that fits,
    else append after the rightmost overlapping tensor.

    ``allocated`` must be sorted by offset (the paper's
    ``ordered_allocated_ids``).
    """
    prev_offset = 0
    best_offset: int | None = None
    smallest_gap = None
    for x in allocated:
        if rec.overlaps(x):
            x_off = offsets[x.tensor_id]
            gap = x_off - prev_offset
            if gap >= rec.size and (smallest_gap is None or gap < smallest_gap):
                smallest_gap = gap
                best_offset = prev_offset
            prev_offset = max(prev_offset, x_off + x.size)
    if best_offset is None:
        best_offset = prev_offset
    return best_offset


def greedy_by_size_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Paper §5.2, Algorithm 3."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []  # kept sorted by offset
    total = 0
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        off = _best_fit_offset(rec, allocated, offsets)
        offsets[rec.tensor_id] = off
        total = max(total, off + rec.size)
        allocated.append(rec)
        allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("greedy_by_size", offsets, total)


def greedy_by_breadth_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Paper §5.3: operators in non-increasing breadth order; within each
    profile, unassigned tensors largest-first; same best-fit gap logic."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []
    total = 0
    breadths = operator_breadths(records)
    profiles = operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:  # size-descending inside the profile
            if rec.tensor_id in offsets:
                continue
            off = _best_fit_offset(rec, allocated, offsets)
            offsets[rec.tensor_id] = off
            total = max(total, off + rec.size)
            allocated.append(rec)
            allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("greedy_by_breadth", offsets, total)


def from_shared_objects(asn: SharedObjectsAssignment) -> OffsetAssignment:
    """Lay shared objects out contiguously (paper §5: SO ⇒ offsets; the
    converse does not hold)."""
    base: dict[int, int] = {}
    cursor = 0
    for obj in asn.objects:
        base[obj.object_id] = cursor
        cursor += obj.size
    offsets = {tid: base[oid] for tid, oid in asn.assignment.items()}
    return OffsetAssignment(f"{asn.strategy}+contiguous", offsets, cursor)


STRATEGIES: dict[str, Callable[[Sequence[TensorUsageRecord]], OffsetAssignment]] = {
    "greedy_by_size": greedy_by_size_offsets,
    "greedy_by_breadth": greedy_by_breadth_offsets,
}
