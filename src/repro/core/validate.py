"""Independent validity checkers for memory plans (used by every test).

These re-derive the constraints from first principles so a bug in a
strategy cannot hide behind a matching bug in its own bookkeeping.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.offsets import OffsetAssignment
from repro.core.records import (
    TensorUsageRecord,
    naive_consumption,
    offsets_lower_bound,
    shared_objects_lower_bound,
)
from repro.core.shared_objects import SharedObjectsAssignment


def check_shared_objects(
    records: Sequence[TensorUsageRecord], asn: SharedObjectsAssignment
) -> None:
    by_id = {r.tensor_id: r for r in records}
    assert set(asn.assignment) == set(by_id), (
        f"{asn.strategy}: assignment covers {len(asn.assignment)} of "
        f"{len(by_id)} tensors"
    )
    # no two overlapping tensors share an object
    recs = list(records)
    for i, a in enumerate(recs):
        for b in recs[i + 1 :]:
            if a.overlaps(b):
                assert asn.assignment[a.tensor_id] != asn.assignment[b.tensor_id], (
                    f"{asn.strategy}: tensors {a.tensor_id} and {b.tensor_id} "
                    f"overlap ({a} vs {b}) but share object "
                    f"{asn.assignment[a.tensor_id]}"
                )
    # object size == max assigned tensor size (no padding, no undersizing)
    sizes: dict[int, int] = {}
    for tid, oid in asn.assignment.items():
        sizes[oid] = max(sizes.get(oid, 0), by_id[tid].size)
    for obj in asn.objects:
        assert obj.size == sizes.get(obj.object_id, obj.size), (
            f"{asn.strategy}: object {obj.object_id} size {obj.size} != "
            f"max assigned {sizes.get(obj.object_id)}"
        )
        assert obj.size >= sizes.get(obj.object_id, 0)
    # bounds
    lb = shared_objects_lower_bound(records)
    naive = naive_consumption(records)
    assert lb <= asn.total_size <= naive, (
        f"{asn.strategy}: total {asn.total_size} outside [{lb}, {naive}]"
    )


def check_offsets(
    records: Sequence[TensorUsageRecord], asn: OffsetAssignment
) -> None:
    by_id = {r.tensor_id: r for r in records}
    assert set(asn.offsets) == set(by_id), (
        f"{asn.strategy}: offsets cover {len(asn.offsets)} of {len(by_id)}"
    )
    recs = list(records)
    for i, a in enumerate(recs):
        off_a = asn.offsets[a.tensor_id]
        assert off_a >= 0
        assert off_a + a.size <= asn.total_size, (
            f"{asn.strategy}: tensor {a.tensor_id} spills past total"
        )
        for b in recs[i + 1 :]:
            if a.overlaps(b):
                off_b = asn.offsets[b.tensor_id]
                disjoint = off_a + a.size <= off_b or off_b + b.size <= off_a
                assert disjoint, (
                    f"{asn.strategy}: overlapping-in-time tensors "
                    f"{a.tensor_id}@[{off_a},{off_a + a.size}) and "
                    f"{b.tensor_id}@[{off_b},{off_b + b.size}) collide in memory"
                )
    lb = offsets_lower_bound(records)
    naive = naive_consumption(records)
    assert lb <= asn.total_size <= naive, (
        f"{asn.strategy}: total {asn.total_size} outside [{lb}, {naive}]"
    )
