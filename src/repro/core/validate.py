"""Independent validity checkers for memory plans (used by every test).

These re-derive the constraints from first principles so a bug in a
strategy cannot hide behind a matching bug in its own bookkeeping. They
are deliberately naive — O(n²) pairwise sweeps — and stay that way: this
module is the SLOW ORACLE TWIN of the O(n log n) sweep-line certifier in
``repro.analysis.soundness``, which is differential-tested against it
(same verdict on every corpus graph and every seeded mutation).

Violations raise :class:`PlanValidationError`, never a bare ``assert``:
``python -O`` strips assert statements, and a checker that silently
becomes a no-op under optimization is worse than no checker at all
(``scripts/ci.sh`` runs a ``python -O`` smoke pinning exactly this).
"""

from __future__ import annotations

from typing import Sequence

from repro.core.offsets import OffsetAssignment
from repro.core.records import (
    TensorUsageRecord,
    naive_consumption,
    offsets_lower_bound,
    shared_objects_lower_bound,
)
from repro.core.shared_objects import SharedObjectsAssignment


class PlanValidationError(AssertionError):
    """A memory plan violates one of the paper's soundness constraints.

    Subclasses ``AssertionError`` for backwards compatibility (these
    checks used to be bare asserts), but is raised explicitly so the
    checkers keep working under ``python -O``.
    """


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise PlanValidationError(msg)


def check_shared_objects(
    records: Sequence[TensorUsageRecord], asn: SharedObjectsAssignment
) -> None:
    by_id = {r.tensor_id: r for r in records}
    _require(
        set(asn.assignment) == set(by_id),
        f"{asn.strategy}: assignment covers {len(asn.assignment)} of "
        f"{len(by_id)} tensors",
    )
    # no two overlapping tensors share an object
    recs = list(records)
    for i, a in enumerate(recs):
        for b in recs[i + 1 :]:
            if a.overlaps(b):
                _require(
                    asn.assignment[a.tensor_id] != asn.assignment[b.tensor_id],
                    f"{asn.strategy}: tensors {a.tensor_id} and {b.tensor_id} "
                    f"overlap ({a} vs {b}) but share object "
                    f"{asn.assignment[a.tensor_id]}",
                )
    # object size == max assigned tensor size (no padding, no undersizing)
    sizes: dict[int, int] = {}
    for tid, oid in asn.assignment.items():
        sizes[oid] = max(sizes.get(oid, 0), by_id[tid].size)
    for obj in asn.objects:
        _require(
            obj.size == sizes.get(obj.object_id, obj.size),
            f"{asn.strategy}: object {obj.object_id} size {obj.size} != "
            f"max assigned {sizes.get(obj.object_id)}",
        )
        _require(
            obj.size >= sizes.get(obj.object_id, 0),
            f"{asn.strategy}: object {obj.object_id} undersized",
        )
    # bounds
    lb = shared_objects_lower_bound(records)
    naive = naive_consumption(records)
    _require(
        lb <= asn.total_size <= naive,
        f"{asn.strategy}: total {asn.total_size} outside [{lb}, {naive}]",
    )


def check_offsets(
    records: Sequence[TensorUsageRecord], asn: OffsetAssignment
) -> None:
    by_id = {r.tensor_id: r for r in records}
    _require(
        set(asn.offsets) == set(by_id),
        f"{asn.strategy}: offsets cover {len(asn.offsets)} of {len(by_id)}",
    )
    recs = list(records)
    for i, a in enumerate(recs):
        off_a = asn.offsets[a.tensor_id]
        _require(off_a >= 0, f"{asn.strategy}: tensor {a.tensor_id} offset < 0")
        _require(
            off_a + a.size <= asn.total_size,
            f"{asn.strategy}: tensor {a.tensor_id} spills past total",
        )
        for b in recs[i + 1 :]:
            if a.overlaps(b):
                off_b = asn.offsets[b.tensor_id]
                disjoint = off_a + a.size <= off_b or off_b + b.size <= off_a
                _require(
                    disjoint,
                    f"{asn.strategy}: overlapping-in-time tensors "
                    f"{a.tensor_id}@[{off_a},{off_a + a.size}) and "
                    f"{b.tensor_id}@[{off_b},{off_b + b.size}) collide in memory",
                )
    lb = offsets_lower_bound(records)
    naive = naive_consumption(records)
    _require(
        lb <= asn.total_size <= naive,
        f"{asn.strategy}: total {asn.total_size} outside [{lb}, {naive}]",
    )
