"""Exact solvers for small instances (beyond-paper extension).

The paper reports distances to its *lower bounds* but the bounds may be
unachievable (§4.1), so the greedy strategies' true optimality gap is
unknown. These branch-and-bound solvers compute exact optima on small
graphs (≲ 10 tensors) so the test-suite and EXPERIMENTS.md §Beyond can
quantify the gap precisely.

Completeness arguments:
* Shared Objects: processing tensors in any fixed size-descending order
  and assigning each to an existing compatible object or a fresh one
  enumerates every partition into interval-compatible groups (a fresh
  object's size equals its largest = first-assigned tensor).
* Offsets: bottom-left normalization — in some optimal packing every
  tensor sits at offset 0 or flush against the end of a time-overlapping
  tensor with a strictly lower offset; adding tensors in non-decreasing
  offset order therefore only needs candidates {0} ∪ {ends of placed
  overlapping tensors} with offset >= the last placed offset.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.records import (
    TensorUsageRecord,
    offsets_lower_bound,
    shared_objects_lower_bound,
)


def optimal_shared_objects_total(
    records: Sequence[TensorUsageRecord], limit_nodes: int = 2_000_000
) -> int:
    """Exact minimum total shared-object size (branch and bound)."""
    recs = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    n = len(recs)
    if n == 0:
        return 0
    lb = shared_objects_lower_bound(recs)
    best = sum(r.size for r in recs)
    nodes = 0

    def dfs(i: int, objects: list[list[TensorUsageRecord]], total: int) -> None:
        nonlocal best, nodes
        nodes += 1
        if nodes > limit_nodes or total >= best or best == lb:
            return
        if i == n:
            best = total
            return
        rec = recs[i]
        seen: set[frozenset] = set()
        for obj in objects:
            if any(x.overlaps(rec) for x in obj):
                continue
            # true symmetry break: identical occupancy sets are equivalent
            key = frozenset((x.first_op, x.last_op, x.size) for x in obj)
            if key in seen:
                continue
            seen.add(key)
            obj.append(rec)
            dfs(i + 1, objects, total)
            obj.pop()
        objects.append([rec])
        dfs(i + 1, objects, total + rec.size)  # sizes non-increasing
        objects.pop()

    dfs(0, [], 0)
    return best


def optimal_offsets_total(
    records: Sequence[TensorUsageRecord], limit_nodes: int = 2_000_000
) -> int:
    """Exact minimum arena size (branch and bound, bottom-left order)."""
    recs = list(records)
    n = len(recs)
    if n == 0:
        return 0
    lb = offsets_lower_bound(recs)
    best = sum(r.size for r in recs)
    nodes = 0
    placed: list[tuple[TensorUsageRecord, int]] = []

    def feasible(rec: TensorUsageRecord, off: int) -> bool:
        for x, xoff in placed:
            if rec.overlaps(x) and not (
                off + rec.size <= xoff or xoff + x.size <= off
            ):
                return False
        return True

    def dfs(used: int, last_off: int, height: int) -> None:
        nonlocal best, nodes
        nodes += 1
        if nodes > limit_nodes or height >= best or best == lb:
            return
        if used == (1 << n) - 1:
            best = height
            return
        tried: set[tuple[int, int, int, int]] = set()
        for i in range(n):
            if used & (1 << i):
                continue
            rec = recs[i]
            candidates = {0}
            for x, xoff in placed:
                if rec.overlaps(x):
                    candidates.add(xoff + x.size)
            for off in sorted(candidates):
                if off < last_off:
                    continue  # non-decreasing placement order (see docstring)
                if off + rec.size >= best:
                    break
                key = (rec.first_op, rec.last_op, rec.size, off)
                if key in tried:
                    continue  # identical tensors at the same offset
                if not feasible(rec, off):
                    continue
                tried.add(key)
                placed.append((rec, off))
                dfs(used | (1 << i), off, max(height, off + rec.size))
                placed.pop()

    dfs(0, 0, 0)
    return best
