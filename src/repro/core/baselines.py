"""Prior-work baselines the paper compares against (§2, §6, Tables 1–2).

* ``naive``                — every intermediate tensor gets its own buffer.
* ``tflite_greedy_*``      — "Greedy" of Lee et al. 2019 (TFLite GPU
  delegate's GREEDY_IN_ORDER): tensors in execution (first_op) order, each
  assigned the free object with the closest size (prefer the smallest
  object >= size_t; else the largest smaller one, grown).
* ``min_cost_flow``        — Lee et al. 2019's min-cost-flow assignment for
  Shared Objects, reimplemented as a min-cost bipartite matching: each
  tensor takes its buffer either from a fresh allocation (cost size_t) or
  from a non-overlapping predecessor's object (cost = growth
  max(0, size_t - size_j)); chains of reuse form the shared objects.
* ``strip_packing_bestfit``— Sekiyama et al. 2018's profile-guided strip
  packing (best-fit decreasing): tensors in size-descending order, placed
  at the lowest feasible offset.

These are reimplementations from the cited papers' descriptions (sources
unavailable offline); the reproduction compares them against the paper's
reported numbers in EXPERIMENTS.md.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Sequence

from repro.core.interval_set import BestFitArena
from repro.core.offsets import OffsetAssignment
from repro.core.records import TensorUsageRecord
from repro.core.shared_objects import (
    SharedObject,
    SharedObjectsAssignment,
    _create_object,
    _new_assignment,
)


# ------------------------------------------------------------------ naive


def naive_shared_objects(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    asn = _new_assignment("naive")
    for rec in sorted(records, key=lambda r: r.tensor_id):
        obj = _create_object(asn, rec)
        obj.assign(rec)
        asn.assignment[rec.tensor_id] = obj.object_id
    return asn


def naive_offsets(records: Sequence[TensorUsageRecord]) -> OffsetAssignment:
    offsets: dict[int, int] = {}
    cursor = 0
    for rec in sorted(records, key=lambda r: r.tensor_id):
        offsets[rec.tensor_id] = cursor
        cursor += rec.size
    return OffsetAssignment("naive", offsets, cursor)


# ------------------------------------------- TFLite GREEDY_IN_ORDER (Lee'19)


def tflite_greedy_in_order(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Tensors in execution order; free objects pooled as their last user
    retires; closest-size object wins (prefer non-growing)."""
    asn = _new_assignment("tflite_greedy_in_order")
    order = sorted(records, key=lambda r: (r.first_op, -r.size, r.tensor_id))
    # (release_op, object_id) heap of busy objects
    busy: list[tuple[int, int]] = []
    free: set[int] = set()
    for rec in order:
        while busy and busy[0][0] < rec.first_op:
            _, oid = heapq.heappop(busy)
            free.add(oid)
        best_ge: SharedObject | None = None  # smallest object >= size
        best_lt: SharedObject | None = None  # largest object < size
        for oid in free:
            obj = asn.objects[oid]
            if obj.size >= rec.size:
                if best_ge is None or obj.size < best_ge.size:
                    best_ge = obj
            else:
                if best_lt is None or obj.size > best_lt.size:
                    best_lt = obj
        obj = best_ge or best_lt
        if obj is None:
            obj = _create_object(asn, rec)
        else:
            free.remove(obj.object_id)
        obj.assign(rec)
        asn.assignment[rec.tensor_id] = obj.object_id
        heapq.heappush(busy, (rec.last_op, obj.object_id))
    return asn


def tflite_greedy_in_order_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Lee'19 'Greedy' adapted to offsets: execution order + best-fit gap."""
    arena = BestFitArena()
    order = sorted(records, key=lambda r: (r.first_op, -r.size, r.tensor_id))
    for rec in order:
        arena.place(rec)
    return OffsetAssignment("tflite_greedy_in_order", arena.offsets, arena.total)


# ------------------------------------------------- min-cost flow (Lee'19)


class _MinCostFlow:
    """Successive-shortest-paths MCMF with SPFA (graphs here are small)."""

    def __init__(self, n: int):
        self.n = n
        self.graph: list[list[list[int]]] = [[] for _ in range(n)]
        # edge = [to, cap, cost, index_of_reverse]

    def add_edge(self, u: int, v: int, cap: int, cost: int) -> None:
        self.graph[u].append([v, cap, cost, len(self.graph[v])])
        self.graph[v].append([u, 0, -cost, len(self.graph[u]) - 1])

    def min_cost_flow(self, s: int, t: int, maxflow: int) -> int:
        total_cost = 0
        INF = 1 << 62
        while maxflow > 0:
            dist = [INF] * self.n
            in_q = [False] * self.n
            prevv = [-1] * self.n
            preve = [-1] * self.n
            dist[s] = 0
            queue = deque([s])
            in_q[s] = True
            while queue:
                u = queue.popleft()
                in_q[u] = False
                for i, e in enumerate(self.graph[u]):
                    v, cap, cost, _ = e
                    if cap > 0 and dist[u] + cost < dist[v]:
                        dist[v] = dist[u] + cost
                        prevv[v] = u
                        preve[v] = i
                        if not in_q[v]:
                            queue.append(v)
                            in_q[v] = True
            if dist[t] >= INF:
                break
            d = maxflow
            v = t
            while v != s:
                d = min(d, self.graph[prevv[v]][preve[v]][1])
                v = prevv[v]
            v = t
            while v != s:
                e = self.graph[prevv[v]][preve[v]]
                e[1] -= d
                self.graph[e[0]][e[3]][1] += d
                v = prevv[v]
            total_cost += d * dist[t]
            maxflow -= d
        return total_cost


def min_cost_flow_assignment(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Shared-objects assignment via min-cost matching (Lee'19 style).

    Node layout: source, sink, provider_i (tensor i's buffer can be handed
    off to one later tensor), consumer_i (tensor i needs one buffer).
    * source → consumer_i, cap 1, cost size_i          (fresh object)
    * provider_j → consumer_i, cap 1, cost max(0, size_i - size_j)
      iff intervals disjoint and j executes first       (reuse + growth)
    * source → provider_j cap 1 cost 0; consumer_i → sink cap 1 cost 0.
    Reuse chains are decoded into shared objects.
    """
    recs = sorted(records, key=lambda r: (r.first_op, r.tensor_id))
    n = len(recs)
    S, T = 2 * n, 2 * n + 1
    mcf = _MinCostFlow(2 * n + 2)
    for i, ri in enumerate(recs):
        mcf.add_edge(S, n + i, 1, 0)  # provider availability
        mcf.add_edge(S, i, 1, ri.size)  # fresh object for consumer i
        mcf.add_edge(i, T, 1, 0)
        for j, rj in enumerate(recs):
            if j == i:
                continue
            if rj.last_op < ri.first_op:  # j fully retires before i starts
                mcf.add_edge(n + j, i, 1, max(0, ri.size - rj.size))
    mcf.min_cost_flow(S, T, n)

    # decode: consumer i took provider j iff edge (n+j) -> i has flow
    take_from: dict[int, int] = {}
    for j in range(n):
        for e in mcf.graph[n + j]:
            v, cap, cost, _ = e
            if v < n and cap == 0:  # saturated forward edge
                take_from[v] = j
                break
    asn = _new_assignment("min_cost_flow")
    # walk chains from roots (consumers with no provider)
    chain_next: dict[int, int] = {j: i for i, j in take_from.items()}
    roots = [i for i in range(n) if i not in take_from]
    for root in roots:
        obj = _create_object(asn, recs[root])
        i = root
        while True:
            rec = recs[i]
            obj.assign(rec)
            asn.assignment[rec.tensor_id] = obj.object_id
            if i in chain_next:
                i = chain_next[i]
            else:
                break
    return asn


# ------------------------------------- strip packing best-fit (Sekiyama'18)


def strip_packing_bestfit(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Best-fit-decreasing strip packing: size-descending order, each tensor
    placed at the lowest feasible offset (first-fit over the gap list)."""
    arena = BestFitArena(first_fit=True)
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        arena.place(rec)
    return OffsetAssignment("strip_packing_bestfit", arena.offsets, arena.total)
