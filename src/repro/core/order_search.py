"""Topological-order search (the paper's §7.1 future work, implemented).

The usage intervals — and therefore every bound and every strategy result —
depend on the topological sort chosen for the DAG. The paper fixes the
order; §7.1 proposes optimizing it. We implement:

* ``memory_aware_topo_order`` — a greedy scheduler: among ready ops, pick
  the one minimizing live-set growth (frees the most bytes, then adds the
  fewest). This is the classic Bruno–Sethi-style heuristic for
  register-pressure-aware scheduling.
* ``simulated_annealing_order`` — local search over topo orders (swap
  adjacent independent ops), objective = offsets lower bound (max breadth),
  which both bounds and tracks the achievable footprint.

EXPERIMENTS.md §Beyond reports the footprint deltas on the paper's six
networks and on the transformer graphs.
"""

from __future__ import annotations

import random
from typing import Sequence

from repro.core.graph import Graph, Op
from repro.core.records import offsets_lower_bound


def _dependencies(graph: Graph) -> tuple[list[set[int]], list[set[int]]]:
    """preds[i], succs[i] as op-index sets, via tensor def/use."""
    producer: dict[int, int] = {}
    for idx, op in enumerate(graph.ops):
        for t in op.outputs:
            producer[t] = idx
    preds: list[set[int]] = [set() for _ in graph.ops]
    succs: list[set[int]] = [set() for _ in graph.ops]
    for idx, op in enumerate(graph.ops):
        for t in op.inputs:
            if t in producer and producer[t] != idx:
                preds[idx].add(producer[t])
                succs[producer[t]].add(idx)
    return preds, succs


def _reorder(graph: Graph, order: Sequence[int]) -> Graph:
    g = Graph(
        name=graph.name,
        ops=[graph.ops[i] for i in order],
        tensors=graph.tensors,
        boundary_ids=graph.boundary_ids,
    )
    g.validate()
    return g


def memory_aware_topo_order(graph: Graph) -> Graph:
    """Greedy: always schedule the ready op with the best (freed - added)
    byte delta; ties broken by smaller added bytes then original index."""
    preds, succs = _dependencies(graph)
    n = len(graph.ops)
    remaining_uses: dict[int, int] = {}
    for op in graph.ops:
        for t in op.inputs:
            remaining_uses[t] = remaining_uses.get(t, 0) + 1
    indeg = [len(p) for p in preds]
    ready = sorted(i for i in range(n) if indeg[i] == 0)
    order: list[int] = []
    uses = dict(remaining_uses)

    def delta(i: int) -> tuple[int, int, int]:
        op = graph.ops[i]
        freed = sum(
            graph.tensors[t].nbytes
            for t in set(op.inputs)
            if t not in graph.boundary_ids and uses.get(t, 0) == op.inputs.count(t)
        )
        added = sum(
            graph.tensors[t].nbytes
            for t in op.outputs
            if t not in graph.boundary_ids
        )
        return (added - freed, added, i)

    while ready:
        ready.sort(key=delta)
        i = ready.pop(0)
        order.append(i)
        for t in graph.ops[i].inputs:
            if t in uses:
                uses[t] -= 1
        for j in succs[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                ready.append(j)
    assert len(order) == n, "graph has a cycle"
    return _reorder(graph, order)


def simulated_annealing_order(
    graph: Graph,
    *,
    iters: int = 2000,
    seed: int = 0,
    t0: float = 0.15,
) -> Graph:
    """Anneal over adjacent-swap neighborhood; objective = offsets lower
    bound (max operator breadth) of the reordered graph."""
    rng = random.Random(seed)
    preds, _ = _dependencies(graph)
    n = len(graph.ops)
    order = list(range(n))

    def cost(o: Sequence[int]) -> int:
        return offsets_lower_bound(_reorder(graph, o).usage_records())

    cur = cost(order)
    best_order, best = list(order), cur
    for it in range(iters):
        if n < 2:
            break
        k = rng.randrange(n - 1)
        a, b = order[k], order[k + 1]
        if a in preds[b] or b in preds[a]:
            continue  # dependency: swap would break topo order
        order[k], order[k + 1] = b, a
        new = cost(order)
        temp = t0 * (1.0 - it / iters) + 1e-9
        if new <= cur or rng.random() < pow(2.718, -(new - cur) / (temp * max(cur, 1))):
            cur = new
            if cur < best:
                best, best_order = cur, list(order)
        else:
            order[k], order[k + 1] = a, b
    return _reorder(graph, best_order)
