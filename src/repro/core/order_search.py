"""Topological-order search driven by the cached planner (paper §7.1).

The usage intervals — and therefore every bound and every strategy result —
depend on the topological sort chosen for the DAG. The paper fixes the
order; §7.1 proposes optimizing it. PR 1 made ``plan_records`` near-free
through the content-addressed plan cache precisely so this outer loop can
call it thousands of times, so the search objective here is the REAL
planned footprint (``MemoryPlan.total_size``), not a lower bound that may
be unachievable.

* ``memory_aware_topo_order`` — a greedy scheduler: among ready ops, pick
  the one minimizing live-set growth (frees the most bytes, then adds the
  fewest). This is the classic Bruno–Sethi-style heuristic for
  register-pressure-aware scheduling.
* ``IncrementalRecords`` — maintains the usage records of a graph under a
  mutable topological order. An adjacent swap re-derives the records of
  only the tensors touched by the two swapped ops (O(affected) instead of
  rebuilding and re-validating the whole graph per candidate).
* ``search_order`` / ``simulated_annealing_order`` — local search over
  adjacent-swap neighborhoods; every candidate is costed by planning it
  for real, with repeat record-multisets served from the plan cache.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import TYPE_CHECKING, Callable, Literal, Sequence

from repro.core import plan_io
from repro.core.graph import Graph
from repro.core.records import (
    DEFAULT_ALIGNMENT,
    TensorUsageRecord,
    align,
    offsets_lower_bound,
)

if TYPE_CHECKING:  # planner imports stay late to keep this module light
    from repro.core.planner import MemoryPlan

Objective = Literal["plan", "lower_bound"]


def _dependencies(graph: Graph) -> tuple[list[set[int]], list[set[int]]]:
    """preds[i], succs[i] as op-index sets, via tensor def/use."""
    producer: dict[int, int] = {}
    for idx, op in enumerate(graph.ops):
        for t in op.outputs:
            producer[t] = idx
    preds: list[set[int]] = [set() for _ in graph.ops]
    succs: list[set[int]] = [set() for _ in graph.ops]
    for idx, op in enumerate(graph.ops):
        for t in op.inputs:
            if t in producer and producer[t] != idx:
                preds[idx].add(producer[t])
                succs[producer[t]].add(idx)
    return preds, succs


def _reorder(graph: Graph, order: Sequence[int]) -> Graph:
    """Reindex ``graph.ops`` by ``order``. The callers below only produce
    orders that are topologically valid by construction (greedy ready-list
    scheduling, dependency-checked adjacent swaps), so the input graph is
    validated ONCE up front and candidates are not re-validated — that
    per-candidate ``Graph.validate()`` made the old search loop
    O(iters × graph)."""
    return Graph(
        name=graph.name,
        ops=[graph.ops[i] for i in order],
        tensors=graph.tensors,
        boundary_ids=graph.boundary_ids,
    )


class IncrementalRecords:
    """Usage records of ``graph`` under a mutable topological order.

    ``swap(k)`` exchanges the ops at order positions ``k`` and ``k+1`` and
    updates only the records of tensors touched by those two ops — every
    other tensor's interval is untouched by an adjacent transposition.
    ``records()`` therefore always equals
    ``_reorder(graph, self.order).usage_records(alignment)`` (the property
    tests assert this equivalence on random swap sequences).
    """

    def __init__(
        self,
        graph: Graph,
        alignment: int = DEFAULT_ALIGNMENT,
        *,
        validate: bool = True,
    ):
        if validate:
            graph.validate()
        self.graph = graph
        n = len(graph.ops)
        self.order: list[int] = list(range(n))  # position -> op index
        self._pos: list[int] = list(range(n))  # op index -> position
        touch: dict[int, set[int]] = {}
        for i, op in enumerate(graph.ops):
            for t in (*op.inputs, *op.outputs):
                touch.setdefault(t, set()).add(i)
        self._touch: dict[int, tuple[int, ...]] = {
            t: tuple(sorted(ops))
            for t, ops in touch.items()
            if t not in graph.boundary_ids
        }
        self._size = {
            t: align(graph.tensors[t].nbytes, alignment) for t in self._touch
        }
        self._span: dict[int, tuple[int, int]] = {}
        # record objects are cached per tensor (insertion order = sorted
        # tensor id) so a swap only reconstructs the affected ones and
        # ``records()`` is a plain list copy
        self._rec: dict[int, TensorUsageRecord] = {}
        for t in sorted(self._touch):
            ps = [self._pos[i] for i in self._touch[t]]
            span = (min(ps), max(ps))
            self._span[t] = span
            self._rec[t] = TensorUsageRecord(
                first_op=span[0], last_op=span[1],
                size=self._size[t], tensor_id=t,
            )
        self._preds, _ = _dependencies(graph)

    def can_swap(self, k: int) -> bool:
        """True iff swapping positions k, k+1 preserves topological order
        (no producer/consumer edge between the two ops)."""
        return self.order[k] not in self._preds[self.order[k + 1]]

    def swap(self, k: int) -> list[int]:
        """Swap order positions k and k+1; returns the tensor ids whose
        usage interval changed. Self-inverse: ``swap(k)`` twice restores
        both the order and every record."""
        a, b = self.order[k], self.order[k + 1]
        self.order[k], self.order[k + 1] = b, a
        self._pos[a], self._pos[b] = k + 1, k
        changed = []
        ops = self.graph.ops
        for t in {*ops[a].inputs, *ops[a].outputs,
                  *ops[b].inputs, *ops[b].outputs}:
            touched = self._touch.get(t)
            if touched is None:  # boundary tensor: no record
                continue
            ps = [self._pos[i] for i in touched]
            span = (min(ps), max(ps))
            if span != self._span[t]:
                self._span[t] = span
                self._rec[t] = TensorUsageRecord(
                    first_op=span[0], last_op=span[1],
                    size=self._size[t], tensor_id=t,
                )
                changed.append(t)
        return changed

    def records(self) -> list[TensorUsageRecord]:
        return list(self._rec.values())

    def reordered_graph(self) -> Graph:
        return _reorder(self.graph, self.order)


def memory_aware_order(graph: Graph, *, validate: bool = True) -> list[int]:
    """Greedy order (op indices): always schedule the ready op with the
    best (freed - added) byte delta; ties broken by smaller added bytes
    then original index."""
    if validate:
        graph.validate()
    preds, succs = _dependencies(graph)
    n = len(graph.ops)
    remaining_uses: dict[int, int] = {}
    for op in graph.ops:
        for t in op.inputs:
            remaining_uses[t] = remaining_uses.get(t, 0) + 1
    indeg = [len(p) for p in preds]
    ready = sorted(i for i in range(n) if indeg[i] == 0)
    order: list[int] = []
    uses = dict(remaining_uses)

    def delta(i: int) -> tuple[int, int, int]:
        op = graph.ops[i]
        freed = sum(
            graph.tensors[t].nbytes
            for t in set(op.inputs)
            if t not in graph.boundary_ids and uses.get(t, 0) == op.inputs.count(t)
        )
        added = sum(
            graph.tensors[t].nbytes
            for t in op.outputs
            if t not in graph.boundary_ids
        )
        return (added - freed, added, i)

    while ready:
        ready.sort(key=delta)
        i = ready.pop(0)
        order.append(i)
        for t in graph.ops[i].inputs:
            if t in uses:
                uses[t] -= 1
        for j in succs[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                ready.append(j)
    assert len(order) == n, "graph has a cycle"
    return order


def memory_aware_topo_order(graph: Graph) -> Graph:
    """Greedy live-set scheduler; see :func:`memory_aware_order`."""
    return _reorder(graph, memory_aware_order(graph))


@dataclasses.dataclass
class OrderSearchResult:
    """Outcome of :func:`search_order`: the best order found, its plan,
    the default-order baseline plan, and search-loop statistics."""

    graph: Graph
    plan: "MemoryPlan"
    baseline_plan: "MemoryPlan"
    order: list[int]
    evaluations: int
    cache_hits: int
    cache_misses: int
    wall_s: float

    @property
    def delta_bytes(self) -> int:
        return self.baseline_plan.total_size - self.plan.total_size

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def provenance(self) -> dict:
        """Deterministic compile-time metadata for plan artifacts
        (:mod:`repro.core.unified` merges this into bundle provenance)."""
        return {
            "order_total_bytes": self.plan.total_size,
            "order_evaluations": self.evaluations,
            "order_cache_hits": self.cache_hits,
        }


def _make_objective(
    objective: Objective,
    mode: str,
    strategy: str,
    cache: "plan_io.PlanCache",
) -> Callable[[Sequence[TensorUsageRecord]], int]:
    if objective == "lower_bound":
        return offsets_lower_bound
    from repro.core.planner import plan_records  # late: planner is heavier

    def cost(records: Sequence[TensorUsageRecord]) -> int:
        return plan_records(
            records, mode=mode, strategy=strategy, cache=cache
        ).total_size

    return cost


def search_order(
    graph: Graph,
    *,
    iters: int = 2000,
    seed: int = 0,
    t0: float = 0.15,
    mode: str = "offsets",
    strategy: str = "auto",
    objective: Objective = "plan",
    cache: "plan_io.PlanCache | None" = None,
    start: Literal["memory_aware", "identity"] = "memory_aware",
    alignment: int = DEFAULT_ALIGNMENT,
) -> OrderSearchResult:
    """Anneal over the adjacent-swap neighborhood of topological orders,
    costing every candidate with the real (cached) planner.

    The identity order is always evaluated first and kept as the
    incumbent, so the returned plan is never worse than the default-order
    baseline. ``start="memory_aware"`` additionally seeds the walk from
    the greedy live-set order. Deterministic for a fixed seed.
    """
    from repro.core.planner import plan_records

    wall0 = time.perf_counter()
    cache = cache if cache is not None else plan_io.PlanCache()
    hits0, misses0 = cache.hits, cache.misses
    cost_of = _make_objective(objective, mode, strategy, cache)
    evaluations = 0

    graph.validate()  # once; candidates below are valid by construction
    n = len(graph.ops)
    identity_records = graph.usage_records(alignment)

    baseline_plan = plan_records(
        identity_records,
        mode=mode,
        strategy=strategy,
        graph_name=graph.name,
        cache=cache,
    )
    evaluations += 1
    best_order = list(range(n))
    best = (
        baseline_plan.total_size
        if objective == "plan"
        else offsets_lower_bound(identity_records)
    )

    # seed the walk: replay the greedy order as adjacent swaps is overkill —
    # just build the incremental state around it directly
    if start == "memory_aware" and n > 1:
        greedy = memory_aware_order(graph, validate=False)
        inc = IncrementalRecords(
            _reorder(graph, greedy), alignment, validate=False
        )
        # positions refer to the reseeded graph; map back through `greedy`
        seed_map = greedy
    else:
        inc = IncrementalRecords(graph, alignment, validate=False)
        seed_map = list(range(n))

    cur = cost_of(inc.records())
    evaluations += 1
    if cur < best:
        best = cur
        best_order = [seed_map[i] for i in inc.order]

    rng = random.Random(seed)
    for it in range(iters):
        if n < 2:
            break
        k = rng.randrange(n - 1)
        if not inc.can_swap(k):
            continue
        if not inc.swap(k):
            # no interval changed — identical record multiset, same cost;
            # keep the (equivalent) swapped order and move on
            continue
        new = cost_of(inc.records())
        evaluations += 1
        temp = t0 * (1.0 - it / iters) + 1e-9
        if new <= cur or rng.random() < math.exp(
            -(new - cur) / (temp * max(cur, 1))
        ):
            cur = new
            if cur < best:
                best = cur
                best_order = [seed_map[i] for i in inc.order]
        else:
            inc.swap(k)  # revert

    result_graph = _reorder(graph, best_order)
    plan = plan_records(
        result_graph.usage_records(alignment),
        mode=mode,
        strategy=strategy,
        graph_name=graph.name,
        cache=cache,
    )
    if plan.total_size > baseline_plan.total_size:
        # a proxy objective (lower_bound) can prefer an order whose REAL
        # plan is larger; the never-worse contract holds regardless
        result_graph, plan, best_order = graph, baseline_plan, list(range(n))
    return OrderSearchResult(
        graph=result_graph,
        plan=plan,
        baseline_plan=baseline_plan,
        order=best_order,
        evaluations=evaluations,
        cache_hits=cache.hits - hits0,
        cache_misses=cache.misses - misses0,
        wall_s=time.perf_counter() - wall0,
    )


def simulated_annealing_order(
    graph: Graph,
    *,
    iters: int = 2000,
    seed: int = 0,
    t0: float = 0.15,
    objective: Objective = "plan",
    mode: str = "offsets",
    strategy: str = "auto",
    cache: "plan_io.PlanCache | None" = None,
) -> Graph:
    """Back-compat wrapper around :func:`search_order` returning just the
    reordered graph (annealed from the identity order)."""
    return search_order(
        graph,
        iters=iters,
        seed=seed,
        t0=t0,
        mode=mode,
        strategy=strategy,
        objective=objective,
        cache=cache,
        start="identity",
    ).graph
