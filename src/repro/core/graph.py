"""A minimal tensor-program IR: operators × tensors → usage records.

This is the substrate the planner consumes. Two producers exist:
* hand-built graphs (the paper's six conv nets, ``models/convnets.py``)
* traced JAX programs (``trace/jaxpr_liveness.py``)

A ``Graph`` is a list of ``Op``s in a fixed topological execution order (the
paper assumes the order is fixed; ``core/order_search.py`` explores
re-ordering as the paper's §7.1 future work). Tensors are identified by
integer ids; each has a byte size (or a shape+dtype from which the aligned
size is derived).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.records import DEFAULT_ALIGNMENT, TensorUsageRecord, align


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A tensor in the graph. Size is bytes *before* alignment."""

    tensor_id: int
    nbytes: int
    name: str = ""
    shape: tuple[int, ...] | None = None
    dtype: str | None = None

    @staticmethod
    def from_shape(
        tensor_id: int,
        shape: Sequence[int],
        dtype: str = "float32",
        name: str = "",
    ) -> "TensorSpec":
        nbytes = int(math.prod(shape)) * np.dtype(dtype).itemsize
        return TensorSpec(
            tensor_id=tensor_id,
            nbytes=nbytes,
            name=name,
            shape=tuple(int(s) for s in shape),
            dtype=dtype,
        )


@dataclasses.dataclass(frozen=True)
class Op:
    """One operator: consumes ``inputs`` tensor ids, produces ``outputs``."""

    name: str
    inputs: tuple[int, ...]
    outputs: tuple[int, ...]


@dataclasses.dataclass
class Graph:
    """Operator list in execution order + tensor table.

    ``boundary_ids`` are tensors that are NOT intermediates (graph inputs,
    weights, final outputs — the paper's Fig. 1 excludes tensor #8, the
    output). They never receive usage records.
    """

    name: str
    ops: list[Op]
    tensors: dict[int, TensorSpec]
    boundary_ids: frozenset[int] = frozenset()

    def intermediate_ids(self) -> list[int]:
        used: set[int] = set()
        for op in self.ops:
            used.update(op.inputs)
            used.update(op.outputs)
        return sorted(t for t in used if t not in self.boundary_ids)

    def usage_records(
        self, alignment: int = DEFAULT_ALIGNMENT
    ) -> list[TensorUsageRecord]:
        """Extract the paper's tensor usage records (§3)."""
        first: dict[int, int] = {}
        last: dict[int, int] = {}
        for op_idx, op in enumerate(self.ops):
            for t in (*op.inputs, *op.outputs):
                if t not in first:
                    first[t] = op_idx
                last[t] = op_idx
        records = []
        for t in self.intermediate_ids():
            if t not in first:
                continue  # unused tensor — no memory needed
            records.append(
                TensorUsageRecord(
                    first_op=first[t],
                    last_op=last[t],
                    size=align(self.tensors[t].nbytes, alignment),
                    tensor_id=t,
                )
            )
        return records

    def validate(self) -> None:
        """Topological-order sanity: every input is produced earlier (or is
        a boundary tensor), every tensor has a spec, no double-produce."""
        produced: set[int] = set()
        for op_idx, op in enumerate(self.ops):
            for t in op.inputs:
                if t not in self.tensors:
                    raise ValueError(f"{self.name}: op {op_idx} input {t} has no spec")
                if t not in produced and t not in self.boundary_ids:
                    raise ValueError(
                        f"{self.name}: op {op_idx} ({op.name}) reads tensor {t} "
                        "before it is produced"
                    )
            for t in op.outputs:
                if t not in self.tensors:
                    raise ValueError(f"{self.name}: op {op_idx} output {t} has no spec")
                if t in produced:
                    raise ValueError(f"{self.name}: tensor {t} produced twice")
                produced.add(t)


class GraphBuilder:
    """Imperative helper for constructing ``Graph``s (used by convnets)."""

    def __init__(self, name: str, dtype: str = "float32"):
        self.name = name
        self.dtype = dtype
        self._ops: list[Op] = []
        self._tensors: dict[int, TensorSpec] = {}
        self._boundary: set[int] = set()
        self._next_id = 0

    def tensor(self, shape: Sequence[int], name: str = "", dtype: str | None = None) -> int:
        tid = self._next_id
        self._next_id += 1
        self._tensors[tid] = TensorSpec.from_shape(
            tid, shape, dtype or self.dtype, name
        )
        return tid

    def input(self, shape: Sequence[int], name: str = "input") -> int:
        tid = self.tensor(shape, name)
        self._boundary.add(tid)
        return tid

    def mark_output(self, tensor_id: int) -> None:
        self._boundary.add(tensor_id)

    def op(
        self,
        name: str,
        inputs: Sequence[int],
        out_shape: Sequence[int],
        out_name: str = "",
    ) -> int:
        """Add an op producing one new tensor; returns its id."""
        out = self.tensor(out_shape, out_name or name)
        self._ops.append(Op(name=name, inputs=tuple(inputs), outputs=(out,)))
        return out

    def raw_op(self, name: str, inputs: Sequence[int], outputs: Sequence[int]) -> None:
        self._ops.append(Op(name=name, inputs=tuple(inputs), outputs=tuple(outputs)))

    def build(self) -> Graph:
        g = Graph(
            name=self.name,
            ops=list(self._ops),
            tensors=dict(self._tensors),
            boundary_ids=frozenset(self._boundary),
        )
        g.validate()
        return g


def graph_from_records(
    records: Iterable[TensorUsageRecord], name: str = "synthetic"
) -> Graph:
    """Build a degenerate Graph whose usage records equal ``records``.

    Used by property tests: the planner algorithms only ever look at
    records, so a record-level generator covers them fully.
    """
    records = list(records)
    n_ops = 0 if not records else 1 + max(r.last_op for r in records)
    produces: dict[int, list[int]] = {i: [] for i in range(n_ops)}
    consumes: dict[int, list[int]] = {i: [] for i in range(n_ops)}
    tensors = {}
    for r in records:
        tensors[r.tensor_id] = TensorSpec(tensor_id=r.tensor_id, nbytes=r.size)
        produces[r.first_op].append(r.tensor_id)
        if r.last_op != r.first_op:
            consumes[r.last_op].append(r.tensor_id)
    ops = [
        Op(name=f"op{i}", inputs=tuple(consumes[i]), outputs=tuple(produces[i]))
        for i in range(n_ops)
    ]
    return Graph(name=name, ops=ops, tensors=tensors)
