"""Beyond-paper strategies.

``greedy_by_conflict``: the paper orders by size (GBS) or operator breadth
(GBB). Interval-graph coloring theory suggests a third signal: a tensor's
*conflict mass* — the total size of tensors whose intervals overlap it —
measures how constrained its placement is. Ordering by (conflict mass,
size) descending and assigning best-fit objects places the most
constrained tensors while the object set is still flexible.

``offsets_best_of_all``: portfolio planner — run every offsets strategy
(ours + baselines + converted shared-objects solutions) and keep the
minimum; generalizes the paper's §6 "evaluate both" advice.
"""

from __future__ import annotations

import bisect
from typing import Sequence

from repro.core import baselines, offsets, shared_objects
from repro.core.offsets import OffsetAssignment, from_shared_objects
from repro.core.records import TensorUsageRecord
from repro.core.shared_objects import (
    SharedObjectsAssignment,
    _new_assignment,
    _ObjectPool,
    _pool_select_is_better,
)


def conflict_mass(records: Sequence[TensorUsageRecord]) -> dict[int, int]:
    """For each tensor, the total size of the tensors overlapping it.

    Sorted-event formulation (no pairwise scan): ``b`` overlaps ``a`` iff
    ``first_b <= last_a`` and ``last_b >= first_a``, so the overlap mass is
    (sum of sizes with first <= last_a) − (sum of sizes with last < first_a)
    − size_a, each term a prefix sum over a sorted key array.
    """
    firsts = sorted((r.first_op, r.size) for r in records)
    lasts = sorted((r.last_op, r.size) for r in records)
    first_keys = [f for f, _ in firsts]
    last_keys = [l for l, _ in lasts]
    first_cum = [0]
    for _, s in firsts:
        first_cum.append(first_cum[-1] + s)
    last_cum = [0]
    for _, s in lasts:
        last_cum.append(last_cum[-1] + s)
    out: dict[int, int] = {}
    for r in records:
        started = first_cum[bisect.bisect_right(first_keys, r.last_op)]
        retired = last_cum[bisect.bisect_left(last_keys, r.first_op)]
        out[r.tensor_id] = started - retired - r.size
    return out


def greedy_by_conflict(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    records = list(records)
    conflict = conflict_mass(records)
    order = sorted(
        records,
        key=lambda r: (-(conflict[r.tensor_id] + r.size), -r.size, r.tensor_id),
    )
    asn = _new_assignment("greedy_by_conflict")
    pool = _ObjectPool()
    for rec in order:
        best = _pool_select_is_better(asn, pool, rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


def offsets_best_of_all(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    cands = [
        offsets.greedy_by_size_offsets(records),
        offsets.greedy_by_breadth_offsets(records),
        baselines.strip_packing_bestfit(records),
        baselines.tflite_greedy_in_order_offsets(records),
        from_shared_objects(shared_objects.greedy_by_size_improved(records)),
        from_shared_objects(greedy_by_conflict(records)),
    ]
    best = min(cands, key=lambda a: a.total_size)
    return OffsetAssignment("best_of_all:" + best.strategy, best.offsets, best.total_size)
