"""Beyond-paper strategies.

``greedy_by_conflict``: the paper orders by size (GBS) or operator breadth
(GBB). Interval-graph coloring theory suggests a third signal: a tensor's
*conflict mass* — the total size of tensors whose intervals overlap it —
measures how constrained its placement is. Ordering by (conflict mass,
size) descending and assigning best-fit objects places the most
constrained tensors while the object set is still flexible.

``offsets_best_of_all``: portfolio planner — run every offsets strategy
(ours + baselines + converted shared-objects solutions) and keep the
minimum; generalizes the paper's §6 "evaluate both" advice.
"""

from __future__ import annotations

from typing import Sequence

from repro.core import baselines, offsets, shared_objects
from repro.core.offsets import OffsetAssignment, from_shared_objects
from repro.core.records import TensorUsageRecord
from repro.core.shared_objects import (
    SharedObject,
    SharedObjectsAssignment,
    _create_object,
    _new_assignment,
)


def greedy_by_conflict(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    records = list(records)
    conflict = {r.tensor_id: 0 for r in records}
    for i, a in enumerate(records):
        for b in records[i + 1 :]:
            if a.overlaps(b):
                conflict[a.tensor_id] += b.size
                conflict[b.tensor_id] += a.size
    order = sorted(
        records,
        key=lambda r: (-(conflict[r.tensor_id] + r.size), -r.size, r.tensor_id),
    )
    asn = _new_assignment("greedy_by_conflict")
    for rec in order:
        best: SharedObject | None = None
        for obj in asn.objects:
            if not obj.fits(rec):
                continue
            if best is None:
                best = obj
            elif best.size < rec.size:
                if obj.size > best.size:
                    best = obj
            elif rec.size <= obj.size < best.size:
                best = obj
        if best is None:
            best = _create_object(asn, rec)
        best.assign(rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


def offsets_best_of_all(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    cands = [
        offsets.greedy_by_size_offsets(records),
        offsets.greedy_by_breadth_offsets(records),
        baselines.strip_packing_bestfit(records),
        baselines.tflite_greedy_in_order_offsets(records),
        from_shared_objects(shared_objects.greedy_by_size_improved(records)),
        from_shared_objects(greedy_by_conflict(records)),
    ]
    best = min(cands, key=lambda a: a.total_size)
    return OffsetAssignment("best_of_all:" + best.strategy, best.offsets, best.total_size)
