"""Shared interval-overlap engine for the planning core.

Every strategy in this package reduces to two queries over closed integer
intervals ``[first_op, last_op]`` (the paper's tensor usage intervals):

* "does this interval overlap anything already placed *here*?"
* "which already-placed tensors overlap this interval?"

The seed implementations answered both with per-object/per-record linear
walks (the paper's O(k·n²) inner loop). This module centralizes the three
data structures that make every strategy O(n log n)-ish; the frozen naive
versions live on in :mod:`repro.core.reference` as the differential-test
oracle.

* :class:`DisjointIntervalSet` — the intervals assigned to one shared
  object are pairwise disjoint *by construction* (that is the shared-object
  invariant), so sorted-by-start order is a total order and only the
  immediate predecessor/successor of a query interval can matter:
  overlap and smallest-gap queries are a single ``bisect``, O(log n).

* :class:`IntervalTree` — a balanced interval tree (treap with
  deterministic pseudo-random priorities) augmented with the maximum
  endpoint of each subtree, over *arbitrary* mutually-overlapping
  intervals. ``overlapping(first, last)`` enumerates the m intersecting
  entries in O(m log n) by pruning subtrees whose ``max_end`` ends before
  the query.

* :class:`BestFitArena` — the shared offset allocator built on
  :class:`IntervalTree`: places records one at a time at the best-fit
  (paper Algorithm 3) or first-fit (Sekiyama'18 strip packing) gap among
  the already-placed, lifetime-overlapping tensors. Gap-scan order and
  tie-breaking are byte-identical to the oracle's full scan — it merely
  skips the records that the oracle's ``rec.overlaps(x)`` filter would
  have discarded anyway.
"""

from __future__ import annotations

import bisect
from typing import Any, Iterator

try:  # the vectorized arena path wants numpy; the scalar engine does not
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is a baked-in runtime dep
    _np = None  # type: ignore[assignment]

_INF = 1 << 60

# Overlap count at which BestFitArena.find_offset switches from the
# per-record Python gap scan to the numpy batch path. Dense graphs (long
# activation lifetimes — the prefill regime) cross it and stay ~flat per
# query; sparse decode graphs never do and keep the cheap tree walk. Per-
# arena override via BestFitArena(vector_threshold=...): 0 forces the
# vectorized path (differential tests), a huge value disables it.
VECTOR_THRESHOLD = 1024

_MASK64 = (1 << 64) - 1
_GAMMA = 0x9E3779B97F4A7C15  # splitmix64 increment


class DisjointIntervalSet:
    """Sorted set of pairwise-disjoint closed intervals ``[first, last]``.

    The caller guarantees disjointness (``add`` only after ``overlaps``
    returned False); under that invariant start order == end order, so
    every query is one predecessor lookup.
    """

    __slots__ = ("_starts", "_ends", "_items")

    def __init__(self) -> None:
        self._starts: list[int] = []
        self._ends: list[int] = []
        self._items: list[Any] = []

    def __len__(self) -> int:
        return len(self._starts)

    def __iter__(self) -> Iterator[tuple[int, int, Any]]:
        return iter(zip(self._starts, self._ends, self._items))

    def add(self, first: int, last: int, item: Any = None) -> None:
        idx = bisect.bisect_left(self._starts, first)
        self._starts.insert(idx, first)
        self._ends.insert(idx, last)
        self._items.insert(idx, item)

    def overlaps(self, first: int, last: int) -> bool:
        """True iff ``[first, last]`` intersects any stored interval.

        Only the stored interval with the greatest start <= ``last`` can
        intersect: anything starting later begins past the query, anything
        earlier ends before it (disjointness orders the ends too).
        """
        idx = bisect.bisect_right(self._starts, last) - 1
        return idx >= 0 and self._ends[idx] >= first

    def smallest_gap(self, first: int, last: int) -> int:
        """Smallest idle gap adjacent to ``[first, last]`` (paper §4.4's
        pairing criterion), assuming the query overlaps nothing stored.
        ``_INF``-ish when the set is empty / has no neighbor on either side.
        """
        best = _INF
        i = bisect.bisect_left(self._starts, first) - 1
        if i >= 0:
            best = first - self._ends[i] - 1
        j = bisect.bisect_right(self._starts, last)
        if j < len(self._starts):
            best = min(best, self._starts[j] - last - 1)
        return best

    def neighbors(self, first: int, last: int) -> tuple[int, int]:
        """``(pred_end, succ_start)`` of the intervals flanking
        ``[first, last]`` — which may itself be stored or merely storable
        (disjoint from everything). Sentinels ``-_INF`` / ``_INF`` stand in
        for a missing flank, so the pair always bounds the idle window
        around the query."""
        i = bisect.bisect_left(self._starts, first) - 1
        pred = self._ends[i] if i >= 0 else -_INF
        j = bisect.bisect_right(self._starts, last)
        succ = self._starts[j] if j < len(self._starts) else _INF
        return pred, succ


class _Node:
    __slots__ = ("first", "last", "item", "prio", "left", "right", "max_end")

    def __init__(self, first: int, last: int, item: Any, prio: int):
        self.first = first
        self.last = last
        self.item = item
        self.prio = prio
        self.left: "_Node | None" = None
        self.right: "_Node | None" = None
        self.max_end = last


def _update(n: _Node) -> None:
    m = n.last
    if n.left is not None and n.left.max_end > m:
        m = n.left.max_end
    if n.right is not None and n.right.max_end > m:
        m = n.right.max_end
    n.max_end = m


def _rotate_right(y: _Node) -> _Node:
    x = y.left
    assert x is not None
    y.left = x.right
    x.right = y
    _update(y)
    _update(x)
    return x


def _rotate_left(x: _Node) -> _Node:
    y = x.right
    assert y is not None
    x.right = y.left
    y.left = x
    _update(x)
    _update(y)
    return y


class IntervalTree:
    """Balanced interval tree (treap, max-endpoint augmented).

    Keys are interval starts; priorities come from a deterministic
    splitmix64 stream so identical insertion sequences build identical
    trees (plan results must be reproducible across runs).
    """

    __slots__ = ("_root", "_n", "_state")

    def __init__(self) -> None:
        self._root: _Node | None = None
        self._n = 0
        self._state = 0

    def __len__(self) -> int:
        return self._n

    def _next_prio(self) -> int:
        self._state = (self._state + _GAMMA) & _MASK64
        z = self._state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        return z ^ (z >> 31)

    def insert(self, first: int, last: int, item: Any = None) -> None:
        self._n += 1
        self._root = self._insert(self._root, first, last, item, self._next_prio())

    def _insert(
        self, node: _Node | None, first: int, last: int, item: Any, prio: int
    ) -> _Node:
        if node is None:
            return _Node(first, last, item, prio)
        if first < node.first:
            node.left = self._insert(node.left, first, last, item, prio)
            if node.left.prio < node.prio:
                node = _rotate_right(node)
            else:
                _update(node)
        else:
            node.right = self._insert(node.right, first, last, item, prio)
            if node.right.prio < node.prio:
                node = _rotate_left(node)
            else:
                _update(node)
        return node

    def overlapping(self, first: int, last: int) -> list[Any]:
        """All stored items whose interval intersects ``[first, last]``.

        Prunes on ``max_end`` (left descents) and on key order (right
        descents): O(log n + m·log n) worst case, O(log n + m) typical.
        """
        out: list[Any] = []
        node = self._root
        stack: list[_Node] = []
        while node is not None or stack:
            while node is not None and node.max_end >= first:
                stack.append(node)
                node = node.left
            if not stack:
                break
            node = stack.pop()
            if node.first <= last:
                if node.last >= first:
                    out.append(node.item)
                node = node.right
            else:
                # every key in the right subtree is >= node.first > last
                node = None
        return out


class BestFitArena:
    """Incremental offset allocator shared by every offsets strategy.

    Reproduces the paper's Algorithm 3 gap search exactly: scan the
    already-placed, lifetime-overlapping records in increasing
    (offset, tensor_id) order; best-fit takes the smallest gap that fits
    (first such gap on ties), first-fit (``first_fit=True``) takes the
    lowest; either appends after the rightmost overlapping record when no
    gap fits.

    Two byte-identical engines answer the same query. The scalar path
    (tree walk + Python scan) wins when few placed records overlap the
    query; once a query sees >= ``vector_threshold`` overlapping records
    the next queries run the numpy batch path — one boolean lifetime mask
    over all placed records, a ``lexsort`` by (offset, tensor_id), and a
    prefix-max gap scan — whose per-query cost is a handful of
    vectorized passes instead of m sort comparisons in Python. The
    overlap count observed by either engine feeds the same estimate, so
    an arena moves between them as its density changes and the choice
    stays deterministic for a given placement sequence.
    """

    __slots__ = (
        "offsets", "total", "first_fit", "vector_threshold", "_tree",
        "_rows", "_n", "_firsts", "_lasts", "_offs", "_sizes", "_ids",
        "_last_overlap",
    )

    def __init__(
        self, *, first_fit: bool = False, vector_threshold: int | None = None
    ):
        self.offsets: dict[int, int] = {}
        self.total = 0
        self.first_fit = first_fit
        self.vector_threshold = (
            VECTOR_THRESHOLD if vector_threshold is None else vector_threshold
        )
        self._tree = IntervalTree()
        # placement log: cheap append-only rows until the vector path
        # first engages (sparse arenas never pay for columns they never
        # query), then (offset, tensor_id)-sorted int64 numpy columns
        # maintained incrementally
        self._rows: list[tuple[int, int, int, int, int]] | None = []
        self._n = 0
        self._firsts = None
        self._lasts = None
        self._offs = None
        self._sizes = None
        self._ids = None
        self._last_overlap = 0

    def __len__(self) -> int:
        return len(self._tree)

    def find_offset(self, rec) -> int:
        """The offset ``rec`` would get; does not place it."""
        if _np is not None and self._last_overlap >= self.vector_threshold:
            if self._rows is not None:
                self._build_columns()
            return self._find_offset_vector(rec)
        over = self._tree.overlapping(rec.first_op, rec.last_op)
        self._last_overlap = len(over)
        offsets = self.offsets
        over.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
        prev = 0
        best: int | None = None
        smallest: int | None = None
        size = rec.size
        for x in over:
            x_off = offsets[x.tensor_id]
            gap = x_off - prev
            if gap >= size:
                if self.first_fit:
                    return prev
                if smallest is None or gap < smallest:
                    smallest = gap
                    best = prev
            end = x_off + x.size
            if end > prev:
                prev = end
        return prev if best is None else best

    def _find_offset_vector(self, rec) -> int:
        """Numpy twin of the scalar gap scan. The columns are kept sorted
        by (offset, tensor_id) at insertion time, so the lifetime-masked
        compress is already in the scalar scan order — no per-query sort.
        Same running ``prev`` (a shifted prefix-max of placement ends —
        every end is positive, so max(0, ...) is the prefix-max itself),
        same first-occurrence tie-breaks (``argmin``/first candidate)."""
        np = _np
        n = self._n
        if n == 0:
            self._last_overlap = 0
            return 0
        mask = (self._firsts[:n] <= rec.last_op) & (
            self._lasts[:n] >= rec.first_op
        )
        m = int(np.count_nonzero(mask))
        self._last_overlap = m
        if m == 0:
            return 0
        offs = self._offs[:n][mask]
        cum = np.maximum.accumulate(offs + self._sizes[:n][mask])
        prev = np.empty(m, np.int64)
        prev[0] = 0
        prev[1:] = cum[:-1]
        gaps = offs - prev
        cand = np.flatnonzero(gaps >= rec.size)
        if cand.size == 0:
            return int(cum[-1])
        if self.first_fit:
            return int(prev[cand[0]])
        return int(prev[cand[np.argmin(gaps[cand])]])

    def place(self, rec) -> int:
        """Find the gap for ``rec``, place it there, return its offset."""
        off = self.find_offset(rec)
        self.place_at(rec, off)
        return off

    def place_at(self, rec, off: int) -> None:
        """Record ``rec`` at a caller-chosen offset (fixed placements)."""
        self.offsets[rec.tensor_id] = off
        self._tree.insert(rec.first_op, rec.last_op, rec)
        if self._rows is not None:
            self._rows.append(
                (rec.first_op, rec.last_op, off, rec.size, rec.tensor_id)
            )
        else:
            self._append_column(rec, off)
        end = off + rec.size
        if end > self.total:
            self.total = end

    def _build_columns(self) -> None:
        """One-time switch from the append-only log to sorted columns,
        at the first vector-path query."""
        rows = self._rows
        assert rows is not None
        self._rows = None
        self._n = len(rows)
        if not rows:
            return
        cols = _np.asarray(rows, _np.int64).T
        order = _np.lexsort((cols[4], cols[2]))
        self._firsts = _np.ascontiguousarray(cols[0][order])
        self._lasts = _np.ascontiguousarray(cols[1][order])
        self._offs = _np.ascontiguousarray(cols[2][order])
        self._sizes = _np.ascontiguousarray(cols[3][order])
        self._ids = _np.ascontiguousarray(cols[4][order])

    def _append_column(self, rec, off: int) -> None:
        """Insert the placement into the columns at its (offset,
        tensor_id) rank — a searchsorted + one vectorized shift per
        column, so vector queries never sort."""
        n = self._n
        if self._firsts is None:
            cap = 256
            self._firsts = _np.empty(cap, _np.int64)
            self._lasts = _np.empty(cap, _np.int64)
            self._offs = _np.empty(cap, _np.int64)
            self._sizes = _np.empty(cap, _np.int64)
            self._ids = _np.empty(cap, _np.int64)
        elif n + 1 > len(self._firsts):
            for name in ("_firsts", "_lasts", "_offs", "_sizes", "_ids"):
                old = getattr(self, name)
                new = _np.empty(2 * n, _np.int64)
                new[:n] = old[:n]
                setattr(self, name, new)
        lo = int(_np.searchsorted(self._offs[:n], off, side="left"))
        hi = int(_np.searchsorted(self._offs[:n], off, side="right"))
        pos = lo + int(
            _np.searchsorted(self._ids[lo:hi], rec.tensor_id, side="left")
        )
        for arr, val in (
            (self._firsts, rec.first_op),
            (self._lasts, rec.last_op),
            (self._offs, off),
            (self._sizes, rec.size),
            (self._ids, rec.tensor_id),
        ):
            arr[pos + 1 : n + 1] = arr[pos:n]
            arr[pos] = val
        self._n = n + 1
