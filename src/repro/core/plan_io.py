"""Deterministic plan artifacts + content-addressed plan cache.

Two concerns, one module:

**Serialization** — a :class:`repro.core.planner.MemoryPlan` round-trips
through a versioned, canonical JSON document (sorted keys, no whitespace
variance), so plans can be diffed, committed as golden files, and shipped
to a serving process that never runs the planner. ``PLAN_FORMAT_VERSION``
bumps on any schema change; loaders reject unknown versions rather than
guessing.

**Caching** — planning is pure: the result is fully determined by the
record set (sizes already alignment-rounded), the mode, and the strategy
name. :func:`plan_signature` hashes exactly those inputs (sha256 over the
canonical encoding, prefixed with the format version so cache entries
self-invalidate when serialization changes), and :class:`PlanCache` maps
signature -> plan, in memory and optionally on disk (one
``<signature>.json`` per plan under ``cache_dir``; set the
``REPRO_PLAN_CACHE_DIR`` environment variable to give the default cache a
disk tier). ``plan_records``/``plan_graph`` consult the cache, which makes
repeat engine construction, auto-strategy sweeps, and outer search loops
(MAFAT-style fusing search, budget-driven tiling enumeration) near-free.

Key properties of the signature scheme:
* alignment is captured *through the record sizes* — ``plan_graph`` with a
  different alignment produces different sizes, hence a different key;
* ``strategy="auto"`` is keyed with its evaluated portfolio spelled out
  (``planner._cache_strategy_key`` produces ``"auto[a,b,...]"``), so
  adding a strategy to a portfolio invalidates cached auto plans while
  auto and a pinned strategy never share an entry;
* every key includes :data:`PLANNER_REVISION` — bump it when any strategy
  implementation may change its output, and persisted caches
  self-invalidate without a schema change;
* graph names are NOT part of the key — two graphs with identical records
  share one entry (the cached plan is re-labelled on the way out).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import TYPE_CHECKING, Sequence

from repro.core.records import TensorUsageRecord
from repro.core.shared_objects import SharedObject, SharedObjectsAssignment

if TYPE_CHECKING:  # planner imports this module; avoid the import cycle
    from repro.core.planner import MemoryPlan

PLAN_FORMAT_VERSION = 1

# Bump whenever ANY strategy implementation may produce different output
# for the same inputs (new tie-breaking, algorithm changes, bug fixes).
# It is part of every plan signature, so persisted disk caches
# (REPRO_PLAN_CACHE_DIR) self-invalidate on planner upgrades instead of
# silently serving plans a current run would no longer produce.
PLANNER_REVISION = 1


# ----------------------------------------------------------- serialization


def _records_to_obj(records: Sequence[TensorUsageRecord]) -> list[list[int]]:
    return [[r.first_op, r.last_op, r.size, r.tensor_id] for r in records]


def _records_from_obj(obj: Sequence[Sequence[int]]) -> list[TensorUsageRecord]:
    return [
        TensorUsageRecord(first_op=f, last_op=l, size=s, tensor_id=t)
        for f, l, s, t in obj
    ]


def _shared_objects_to_obj(asn: SharedObjectsAssignment) -> dict:
    return {
        "strategy": asn.strategy,
        "objects": [
            {"object_id": o.object_id, "size": o.size, "intervals": o.intervals}
            for o in asn.objects
        ],
        "assignment": {str(tid): oid for tid, oid in asn.assignment.items()},
    }


def _shared_objects_from_obj(obj: dict) -> SharedObjectsAssignment:
    objects = []
    for o in obj["objects"]:
        so = SharedObject(object_id=o["object_id"], size=o["size"])
        for f, l, tid in o["intervals"]:
            so.interval_set.add(f, l, tid)
        objects.append(so)
    return SharedObjectsAssignment(
        strategy=obj["strategy"],
        objects=objects,
        assignment={int(t): oid for t, oid in obj["assignment"].items()},
    )


def plan_to_obj(plan: "MemoryPlan") -> dict:
    return {
        "format_version": PLAN_FORMAT_VERSION,
        "graph_name": plan.graph_name,
        "strategy": plan.strategy,
        "records": _records_to_obj(plan.records),
        "offsets": {str(t): off for t, off in plan.offsets.items()},
        "total_size": plan.total_size,
        "lower_bound": plan.lower_bound,
        "naive_size": plan.naive_size,
        "plan_wall_s": plan.plan_wall_s,
        "shared_objects": (
            _shared_objects_to_obj(plan.shared_objects)
            if plan.shared_objects is not None
            else None
        ),
    }


def plan_from_obj(obj: dict) -> "MemoryPlan":
    from repro.core.planner import MemoryPlan

    version = obj.get("format_version")
    if version != PLAN_FORMAT_VERSION:
        raise ValueError(
            f"unsupported plan format version {version!r} "
            f"(this build reads version {PLAN_FORMAT_VERSION})"
        )
    so = obj.get("shared_objects")
    return MemoryPlan(
        graph_name=obj["graph_name"],
        strategy=obj["strategy"],
        records=_records_from_obj(obj["records"]),
        offsets={int(t): off for t, off in obj["offsets"].items()},
        total_size=obj["total_size"],
        lower_bound=obj["lower_bound"],
        naive_size=obj["naive_size"],
        plan_wall_s=obj["plan_wall_s"],
        shared_objects=_shared_objects_from_obj(so) if so is not None else None,
    )


def plan_to_json(plan: "MemoryPlan") -> str:
    """Canonical encoding: sorted keys, fixed separators — byte-stable."""
    return json.dumps(plan_to_obj(plan), sort_keys=True, separators=(",", ":"))


def plan_from_json(text: str) -> "MemoryPlan":
    return plan_from_obj(json.loads(text))


def save_plan(plan: "MemoryPlan", path: str | Path) -> None:
    Path(path).write_text(plan_to_json(plan))


def load_plan(path: str | Path) -> "MemoryPlan":
    return plan_from_json(Path(path).read_text())


# ------------------------------------------------------------- signatures


def canonical_records(
    records: Sequence[TensorUsageRecord],
) -> list[tuple[int, int, int, int]]:
    """Producer-order-independent canonical form, shared by every content
    key over a record set: the plan-cache signature, the unified-plan
    spec fingerprint, and the executor's precompiled-plan identity check.
    """
    return sorted((r.tensor_id, r.first_op, r.last_op, r.size) for r in records)


def plan_signature(
    records: Sequence[TensorUsageRecord], *, mode: str, strategy: str
) -> str:
    """Content hash of everything the planner's output depends on.

    Records are keyed in ``tensor_id`` order so producer iteration order
    does not fragment the cache. Sizes are post-alignment, so alignment
    changes re-key automatically.
    """
    canon = canonical_records(records)
    payload = json.dumps(
        {
            "format_version": PLAN_FORMAT_VERSION,
            "planner_revision": PLANNER_REVISION,
            "mode": mode,
            "strategy": strategy,
            "records": canon,
        },
        sort_keys=True,
        separators=(",", ":"),
    )
    return hashlib.sha256(payload.encode()).hexdigest()


# ------------------------------------------------------------------ cache


def _env_max_disk_bytes() -> int | None:
    """Size cap for the disk tier from ``REPRO_PLAN_CACHE_MAX_BYTES``
    (re-read per put, like the cache dir itself); unset/invalid/<=0
    disables eviction."""
    raw = os.environ.get("REPRO_PLAN_CACHE_MAX_BYTES")
    if not raw:
        return None
    try:
        val = int(raw)
    except ValueError:
        return None
    return val if val > 0 else None


class PlanCache:
    """signature -> MemoryPlan, memory-first with an optional disk tier.

    The disk tier stores one canonical-JSON file per plan, named by
    signature, so it is safe to share between processes (writes go through
    a same-directory temp file + atomic rename). Under outer-search sweeps
    it would grow without bound, so every put enforces a size cap
    (``max_disk_bytes`` or ``REPRO_PLAN_CACHE_MAX_BYTES``) by evicting
    oldest-mtime entries first — best-effort like the writes themselves:
    entries deleted concurrently by another process are simply skipped.
    """

    def __init__(
        self,
        cache_dir: str | Path | None = None,
        max_disk_bytes: int | None = None,
    ):
        self._mem: dict[str, "MemoryPlan"] = {}
        self.cache_dir = Path(cache_dir) if cache_dir else None
        self.max_disk_bytes = max_disk_bytes
        # running upper bound on the disk tier's size, so a sweep of puts
        # under the cap stays O(1) per put: the directory is only rescanned
        # when the estimate crosses the cap (None = unknown, scan next put)
        self._disk_bytes_estimate: int | None = None
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._mem)

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "entries": len(self._mem)}

    def _disk_path(self, key: str) -> Path | None:
        return self.cache_dir / f"{key}.json" if self.cache_dir else None

    def get(self, key: str) -> "MemoryPlan | None":
        plan = self._mem.get(key)
        if plan is None:
            path = self._disk_path(key)
            if path is not None:
                # read directly instead of exists()+read: another process
                # may delete entries between the check and the read
                try:
                    plan = plan_from_json(path.read_text())
                except FileNotFoundError:
                    plan = None
                except (OSError, ValueError, KeyError, json.JSONDecodeError):
                    plan = None  # unreadable/stale/foreign: treat as miss
                else:
                    self._mem[key] = plan
        if plan is None:
            self.misses += 1
            return None
        self.hits += 1
        return _copy_plan(plan)

    def put(self, key: str, plan: "MemoryPlan") -> None:
        self._mem[key] = _copy_plan(plan)
        path = self._disk_path(key)
        if path is not None:
            # the disk tier is best-effort: a full/unwritable cache dir
            # must not fail the planning call that already succeeded
            try:
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(f".tmp{os.getpid()}")
                text = plan_to_json(plan)
                tmp.write_text(text)
                tmp.replace(path)
            except OSError:
                pass
            else:
                self._evict_disk(keep=path, written_bytes=len(text))

    def _evict_disk(self, keep: Path, written_bytes: int) -> None:
        """Shrink the disk tier to the size cap, oldest mtime first. The
        just-written entry is never evicted (even if it alone exceeds the
        cap). Best-effort: stat/unlink races with other processes are
        ignored, never surfaced to the planning call.

        The directory is only rescanned when the running estimate crosses
        the cap — a sustained sweep writing under the cap costs O(1) per
        put, not a full glob+stat of every entry. The estimate cannot see
        other processes' writes; that is acceptable for a best-effort cap
        (each writer still bounds its own contribution, and every scan
        re-syncs to the directory's true size)."""
        limit = (
            self.max_disk_bytes
            if self.max_disk_bytes is not None
            else _env_max_disk_bytes()
        )
        if limit is None or self.cache_dir is None:
            return
        if self._disk_bytes_estimate is not None:
            self._disk_bytes_estimate += written_bytes
            if self._disk_bytes_estimate <= limit:
                return
        entries = []  # (mtime, size, path)
        try:
            for p in self.cache_dir.glob("*.json"):
                try:
                    st = p.stat()
                except OSError:
                    continue  # deleted by another process mid-scan
                entries.append((st.st_mtime, st.st_size, p))
        except OSError:
            return
        total = sum(size for _, size, _ in entries)
        if total > limit:
            for _, size, p in sorted(entries):
                if p == keep:
                    continue
                try:
                    p.unlink(missing_ok=True)
                except OSError:
                    continue
                total -= size
                if total <= limit:
                    break
        self._disk_bytes_estimate = total

    def clear(self) -> None:
        self._mem.clear()
        self.hits = 0
        self.misses = 0


def _copy_plan(plan: "MemoryPlan") -> "MemoryPlan":
    """Isolating copy: callers may re-label or mutate what they get back,
    the cached original must stay pristine. Records are frozen ->
    shareable; offsets are copied; the shared-objects graph is mutable
    (``assign`` grows objects in place), so it is rebuilt through its own
    serializer rather than shared."""
    so = plan.shared_objects
    if so is not None:
        so = _shared_objects_from_obj(_shared_objects_to_obj(so))
    return dataclasses.replace(
        plan,
        records=list(plan.records),
        offsets=dict(plan.offsets),
        shared_objects=so,
    )


_default_cache: PlanCache | None = None
_default_cache_dir: str | None = None


def default_cache() -> PlanCache:
    """The process-wide cache. ``REPRO_PLAN_CACHE_DIR`` is re-read on every
    call (not frozen at import time), so setting it after importing
    ``repro.core`` still enables the disk tier; changing it swaps in a
    fresh cache for the new directory."""
    global _default_cache, _default_cache_dir
    env = os.environ.get("REPRO_PLAN_CACHE_DIR") or None
    if _default_cache is None or env != _default_cache_dir:
        _default_cache = PlanCache(env)
        _default_cache_dir = env
    return _default_cache
