"""FROZEN reference planner — the differential-testing oracle.

These are the seed repository's naive implementations of the paper's
strategies, preserved verbatim-in-spirit when the production planner moved
to the shared interval-overlap engine (:mod:`repro.core.interval_set`).
They re-derive *everything* locally — their own operator profiles, breadths
and positional maximums, their own per-object interval walks, their own
full-scan best-fit — so a bug in the fast engine cannot hide behind shared
code.

Contract (enforced by ``tests/test_differential_planner.py``): for every
strategy named in ``REFERENCE_SHARED_OBJECT_STRATEGIES`` /
``REFERENCE_OFFSET_STRATEGIES``, the fast implementation in
:mod:`repro.core.shared_objects` / :mod:`repro.core.offsets` /
:mod:`repro.core.baselines` must produce the **identical** assignment /
offsets (and therefore identical ``total_size``) on any record set. The
fast paths are pure data-structure swaps; tie-breaking is preserved
exactly.

DO NOT "improve" this module. Its only job is to stay simple, obviously
correct, and byte-for-byte stable; performance is irrelevant (it is
O(k·n²) by design). New strategies get a frozen twin here *before* their
fast implementation lands.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Sequence

from repro.core.offsets import OffsetAssignment
from repro.core.records import TensorUsageRecord
from repro.core.shared_objects import SharedObjectsAssignment

# --------------------------------------------------------------- profiles
# Local copies: the oracle must not share derived-quantity code with the
# fast engine (records.py now computes breadths by event sweep).


def _num_operators(records: Sequence[TensorUsageRecord]) -> int:
    return 0 if not records else 1 + max(r.last_op for r in records)


def _operator_profiles(
    records: Sequence[TensorUsageRecord],
) -> list[list[TensorUsageRecord]]:
    profiles: list[list[TensorUsageRecord]] = [
        [] for _ in range(_num_operators(records))
    ]
    for r in records:
        for op in range(r.first_op, r.last_op + 1):
            profiles[op].append(r)
    for p in profiles:
        p.sort(key=lambda r: (-r.size, r.tensor_id))
    return profiles


def _operator_breadths(records: Sequence[TensorUsageRecord]) -> list[int]:
    breadths = [0] * _num_operators(records)
    for r in records:
        for op in range(r.first_op, r.last_op + 1):
            breadths[op] += r.size
    return breadths


def _positional_maximums(records: Sequence[TensorUsageRecord]) -> list[int]:
    profiles = _operator_profiles(records)
    depth = max((len(p) for p in profiles), default=0)
    return [
        max(p[i].size for p in profiles if len(p) > i) for i in range(depth)
    ]


# ---------------------------------------------------- naive shared object


@dataclasses.dataclass
class _RefObject:
    """The seed ``SharedObject``: sorted interval list + neighborhood walk."""

    object_id: int
    size: int
    intervals: list[tuple[int, int, int]] = dataclasses.field(default_factory=list)

    def fits(self, rec: TensorUsageRecord) -> bool:
        starts = [iv[0] for iv in self.intervals]
        idx = bisect.bisect_right(starts, rec.last_op)
        for i in range(idx - 1, -1, -1):
            f, l, _ = self.intervals[i]
            if l >= rec.first_op:
                return False
        return True

    def assign(self, rec: TensorUsageRecord) -> None:
        starts = [iv[0] for iv in self.intervals]
        idx = bisect.bisect_left(starts, rec.first_op)
        self.intervals.insert(idx, (rec.first_op, rec.last_op, rec.tensor_id))
        self.size = max(self.size, rec.size)

    def gap_to(self, rec: TensorUsageRecord) -> int:
        if not self.intervals:
            return 1 << 60
        best = 1 << 60
        for f, l, _ in self.intervals:
            if l < rec.first_op:
                best = min(best, rec.first_op - l - 1)
            elif f > rec.last_op:
                best = min(best, f - rec.last_op - 1)
        return best


def _new_assignment(strategy: str) -> SharedObjectsAssignment:
    return SharedObjectsAssignment(strategy=strategy, objects=[], assignment={})


def _create_object(asn: SharedObjectsAssignment, rec: TensorUsageRecord) -> _RefObject:
    obj = _RefObject(object_id=len(asn.objects), size=rec.size)
    asn.objects.append(obj)  # type: ignore[arg-type]
    return obj


# ------------------------------------------------ shared-objects oracles


def greedy_by_size(records: Sequence[TensorUsageRecord]) -> SharedObjectsAssignment:
    """Seed Greedy-by-Size (paper §4.3 Algorithm 2), full object scan."""
    asn = _new_assignment("greedy_by_size")
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        best: _RefObject | None = None
        for obj in asn.objects:
            if obj.fits(rec) and (best is None or obj.size < best.size):
                best = obj
        if best is None:
            best = _create_object(asn, rec)
        best.assign(rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


def greedy_by_breadth(records: Sequence[TensorUsageRecord]) -> SharedObjectsAssignment:
    """Seed Greedy-by-Breadth (paper §4.2 Algorithm 1)."""
    asn = _new_assignment("greedy_by_breadth")
    breadths = _operator_breadths(records)
    profiles = _operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:
            if rec.tensor_id in asn.assignment:
                continue
            best: _RefObject | None = None
            for obj in asn.objects:
                if not obj.fits(rec):
                    continue
                if best is None:
                    best = obj
                    continue
                if best.size < rec.size:
                    if obj.size > best.size:
                        best = obj
                else:
                    if rec.size <= obj.size < best.size:
                        best = obj
            if best is None:
                best = _create_object(asn, rec)
            best.assign(rec)
            asn.assignment[rec.tensor_id] = best.object_id
    return asn


def _stages_by_positional_maximums(
    records: Sequence[TensorUsageRecord],
) -> list[list[TensorUsageRecord]]:
    pms = sorted(set(_positional_maximums(records)), reverse=True)
    recs = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    stages: list[list[TensorUsageRecord]] = []
    for i, pm in enumerate(pms):
        eq = [r for r in recs if r.size == pm]
        if eq:
            stages.append(eq)
        lo = pms[i + 1] if i + 1 < len(pms) else 0
        mid = [r for r in recs if lo < r.size < pm]
        if mid:
            stages.append(mid)
    return stages


def _greedy_by_size_improved_staged(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    asn = _new_assignment("greedy_by_size_improved")
    for stage in _stages_by_positional_maximums(records):
        pending = list(stage)
        while pending:
            best_pair: tuple[int, TensorUsageRecord, _RefObject] | None = None
            for rec in pending:
                for obj in asn.objects:
                    if not obj.fits(rec):
                        continue
                    gap = obj.gap_to(rec)
                    if best_pair is None or gap < best_pair[0]:
                        best_pair = (gap, rec, obj)
            if best_pair is None:
                pending.sort(key=lambda r: (-r.size, r.first_op, r.tensor_id))
                rec = pending.pop(0)
                obj = _create_object(asn, rec)
                obj.assign(rec)
                asn.assignment[rec.tensor_id] = obj.object_id
            else:
                _, rec, obj = best_pair
                obj.assign(rec)
                asn.assignment[rec.tensor_id] = obj.object_id
                pending.remove(rec)
    return asn


def greedy_by_size_improved(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Seed Greedy-by-Size-Improved (paper §4.4): best of staged / plain."""
    staged = _greedy_by_size_improved_staged(records)
    plain = greedy_by_size(records)
    if plain.total_size < staged.total_size:
        return SharedObjectsAssignment(
            strategy="greedy_by_size_improved",
            objects=plain.objects,
            assignment=plain.assignment,
        )
    return staged


# ------------------------------------------------------- offsets oracles


def _best_fit_offset(
    rec: TensorUsageRecord,
    allocated: list[TensorUsageRecord],
    offsets: dict[int, int],
) -> int:
    """Seed Algorithm 3 L.7–20: full scan over ALL allocated records."""
    prev_offset = 0
    best_offset: int | None = None
    smallest_gap = None
    for x in allocated:
        if rec.overlaps(x):
            x_off = offsets[x.tensor_id]
            gap = x_off - prev_offset
            if gap >= rec.size and (smallest_gap is None or gap < smallest_gap):
                smallest_gap = gap
                best_offset = prev_offset
            prev_offset = max(prev_offset, x_off + x.size)
    if best_offset is None:
        best_offset = prev_offset
    return best_offset


def greedy_by_size_offsets(records: Sequence[TensorUsageRecord]) -> OffsetAssignment:
    """Seed Greedy-by-Size offsets (paper §5.2 Algorithm 3)."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []
    total = 0
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        off = _best_fit_offset(rec, allocated, offsets)
        offsets[rec.tensor_id] = off
        total = max(total, off + rec.size)
        allocated.append(rec)
        allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("greedy_by_size", offsets, total)


def greedy_by_breadth_offsets(records: Sequence[TensorUsageRecord]) -> OffsetAssignment:
    """Seed Greedy-by-Breadth offsets (paper §5.3)."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []
    total = 0
    breadths = _operator_breadths(records)
    profiles = _operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:
            if rec.tensor_id in offsets:
                continue
            off = _best_fit_offset(rec, allocated, offsets)
            offsets[rec.tensor_id] = off
            total = max(total, off + rec.size)
            allocated.append(rec)
            allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("greedy_by_breadth", offsets, total)


def strip_packing_bestfit(records: Sequence[TensorUsageRecord]) -> OffsetAssignment:
    """Seed Sekiyama'18 strip packing (first-fit decreasing), full scan."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []
    total = 0
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        prev_offset = 0
        placed_off: int | None = None
        for x in allocated:
            if rec.overlaps(x):
                x_off = offsets[x.tensor_id]
                if x_off - prev_offset >= rec.size:
                    placed_off = prev_offset
                    break
                prev_offset = max(prev_offset, x_off + x.size)
        if placed_off is None:
            placed_off = prev_offset
        offsets[rec.tensor_id] = placed_off
        total = max(total, placed_off + rec.size)
        allocated.append(rec)
        allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("strip_packing_bestfit", offsets, total)


def tflite_greedy_in_order_offsets(
    records: Sequence[TensorUsageRecord],
) -> OffsetAssignment:
    """Seed Lee'19 'Greedy' offsets: execution order + full-scan best-fit."""
    offsets: dict[int, int] = {}
    allocated: list[TensorUsageRecord] = []
    total = 0
    order = sorted(records, key=lambda r: (r.first_op, -r.size, r.tensor_id))
    for rec in order:
        off = _best_fit_offset(rec, allocated, offsets)
        offsets[rec.tensor_id] = off
        total = max(total, off + rec.size)
        allocated.append(rec)
        allocated.sort(key=lambda r: (offsets[r.tensor_id], r.tensor_id))
    return OffsetAssignment("tflite_greedy_in_order", offsets, total)


def greedy_by_conflict(records: Sequence[TensorUsageRecord]) -> SharedObjectsAssignment:
    """Seed beyond-paper strategy (core/extensions.py): pairwise conflict
    mass + the Greedy-by-Breadth ``is_better`` object scan."""
    records = list(records)
    conflict = {r.tensor_id: 0 for r in records}
    for i, a in enumerate(records):
        for b in records[i + 1:]:
            if a.overlaps(b):
                conflict[a.tensor_id] += b.size
                conflict[b.tensor_id] += a.size
    order = sorted(
        records,
        key=lambda r: (-(conflict[r.tensor_id] + r.size), -r.size, r.tensor_id),
    )
    asn = _new_assignment("greedy_by_conflict")
    for rec in order:
        best: _RefObject | None = None
        for obj in asn.objects:
            if not obj.fits(rec):
                continue
            if best is None:
                best = obj
            elif best.size < rec.size:
                if obj.size > best.size:
                    best = obj
            elif rec.size <= obj.size < best.size:
                best = obj
        if best is None:
            best = _create_object(asn, rec)
        best.assign(rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


REFERENCE_SHARED_OBJECT_STRATEGIES: dict[
    str, Callable[[Sequence[TensorUsageRecord]], SharedObjectsAssignment]
] = {
    "greedy_by_size": greedy_by_size,
    "greedy_by_size_improved": greedy_by_size_improved,
    "greedy_by_breadth": greedy_by_breadth,
    "greedy_by_conflict": greedy_by_conflict,
}

REFERENCE_OFFSET_STRATEGIES: dict[
    str, Callable[[Sequence[TensorUsageRecord]], OffsetAssignment]
] = {
    "greedy_by_size": greedy_by_size_offsets,
    "greedy_by_breadth": greedy_by_breadth_offsets,
    "strip_packing_bestfit": strip_packing_bestfit,
    "tflite_greedy_in_order": tflite_greedy_in_order_offsets,
}
