"""Activation-half planner: strategies, MemoryPlan, and thin wrappers.

``plan_records``/``plan_graph`` are wrappers over the unified facade
(:func:`repro.core.plan` in :mod:`repro.core.unified`), which also covers
the cross-step state half; the strategy dispatch itself lives here in
``_plan_records_impl``.

Implements the paper's §6 deployment recommendations:
* Shared Objects engines: default to Greedy-by-Size-Improved.
* Offset Calculation engines: evaluate Greedy-by-Size AND Strip-Packing
  Best-fit before first inference, pick the smaller (§6 last paragraph).
``strategy="auto"`` runs every known strategy and returns the best.

Every ``plan_records``/``plan_graph`` call consults the content-addressed
plan cache (:mod:`repro.core.plan_io`): the signature covers the record
set, mode and strategy, so repeat engine construction and auto-strategy
sweeps over an unchanged graph return the stored plan (``cache_hit=True``)
without re-running any strategy. Pass ``use_cache=False`` to force a
fresh run, or ``cache=`` to use a private :class:`~repro.core.plan_io.PlanCache`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Literal, Sequence

from repro.core import baselines, offsets, plan_io, shared_objects
from repro.core.graph import Graph
from repro.core.offsets import OffsetAssignment, from_shared_objects
from repro.core.records import (
    DEFAULT_ALIGNMENT,
    TensorUsageRecord,
    naive_consumption,
    offsets_lower_bound,
    shared_objects_lower_bound,
)
from repro.core.shared_objects import SharedObjectsAssignment

Mode = Literal["shared_objects", "offsets"]

# Instrumentation: total plan_records entries this process (cache hits
# included — a bundle-served engine must not even consult the planner).
# Tests snapshot it around engine construction.
PLAN_CALLS = 0

SHARED_OBJECT_STRATEGIES: dict[
    str, Callable[[Sequence[TensorUsageRecord]], SharedObjectsAssignment]
] = {
    **shared_objects.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order,
    "min_cost_flow": baselines.min_cost_flow_assignment,
    "naive": baselines.naive_shared_objects,
}


def _register_extensions() -> None:
    # late import: extensions depend on the base strategies above
    from repro.core import extensions

    SHARED_OBJECT_STRATEGIES["greedy_by_conflict"] = extensions.greedy_by_conflict
    OFFSET_STRATEGIES["best_of_all"] = extensions.offsets_best_of_all

OFFSET_STRATEGIES: dict[
    str, Callable[[Sequence[TensorUsageRecord]], OffsetAssignment]
] = {
    **offsets.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order_offsets,
    "strip_packing_bestfit": baselines.strip_packing_bestfit,
    "naive": baselines.naive_offsets,
}

_register_extensions()

# The strategy portfolios "auto" evaluates, by mode. Named here (not
# inline) because the cache key spells them out: adding a strategy to a
# portfolio must invalidate previously cached auto plans.
AUTO_SHARED_OBJECT_PORTFOLIO: tuple[str, ...] = tuple(shared_objects.STRATEGIES)
AUTO_OFFSET_PORTFOLIO: tuple[str, ...] = (
    "greedy_by_size",
    "greedy_by_breadth",
    "strip_packing_bestfit",
)


def _cache_strategy_key(mode: Mode, strategy: str) -> str:
    if strategy != "auto":
        return strategy
    portfolio = (
        AUTO_SHARED_OBJECT_PORTFOLIO
        if mode == "shared_objects"
        else AUTO_OFFSET_PORTFOLIO
    )
    return "auto[" + ",".join(sorted(portfolio)) + "]"


@dataclasses.dataclass
class MemoryPlan:
    """An offset plan ready for arena materialization."""

    graph_name: str
    strategy: str
    records: list[TensorUsageRecord]
    offsets: dict[int, int]  # tensor_id -> byte offset
    total_size: int
    lower_bound: int
    naive_size: int
    plan_wall_s: float
    shared_objects: SharedObjectsAssignment | None = None
    # True when this plan came out of the plan cache instead of a strategy
    # run (not serialized; see plan_io).
    cache_hit: bool = False

    @property
    def reduction_vs_naive(self) -> float:
        return self.naive_size / max(self.total_size, 1)

    @property
    def fraction_of_lower_bound(self) -> float:
        return self.total_size / max(self.lower_bound, 1)

    def summary(self) -> str:
        return (
            f"{self.graph_name}[{self.strategy}]: {self.total_size / 2**20:.3f} MiB "
            f"(naive {self.naive_size / 2**20:.3f}, LB {self.lower_bound / 2**20:.3f}, "
            f"{self.reduction_vs_naive:.2f}x smaller than naive, "
            f"{self.fraction_of_lower_bound:.3f}x LB)"
        )


def plan_records(
    records: Sequence[TensorUsageRecord],
    *,
    mode: Mode = "offsets",
    strategy: str = "auto",
    graph_name: str = "records",
    cache: plan_io.PlanCache | None = None,
    use_cache: bool = True,
) -> MemoryPlan:
    """Thin wrapper over the unified facade (:func:`repro.core.plan`):
    plans the activation half only. The strategy implementations live in
    :func:`_plan_records_impl`, which ``unified.plan`` dispatches to."""
    from repro.core import unified  # function-level: unified imports planner

    spec = unified.PlanSpec(
        records=list(records), mode=mode, strategy=strategy,
        graph_name=graph_name, cache=cache, use_cache=use_cache,
    )
    return unified.plan(spec).activation


def _plan_records_impl(
    records: Sequence[TensorUsageRecord],
    *,
    mode: Mode = "offsets",
    strategy: str = "auto",
    graph_name: str = "records",
    cache: plan_io.PlanCache | None = None,
    use_cache: bool = True,
) -> MemoryPlan:
    global PLAN_CALLS
    PLAN_CALLS += 1
    records = list(records)
    t0 = time.perf_counter()
    key: str | None = None
    if use_cache:
        cache = cache if cache is not None else plan_io.default_cache()
        key = plan_io.plan_signature(
            records, mode=mode, strategy=_cache_strategy_key(mode, strategy)
        )
        hit = cache.get(key)
        if hit is not None:
            return dataclasses.replace(
                hit,
                graph_name=graph_name,
                plan_wall_s=time.perf_counter() - t0,
                cache_hit=True,
            )
    so: SharedObjectsAssignment | None = None
    if mode == "shared_objects":
        lb = shared_objects_lower_bound(records)
        if strategy == "auto":
            # paper: GBS-Improved is the recommended default, but evaluate all
            cands = [
                shared_objects.STRATEGIES[name](records)
                for name in AUTO_SHARED_OBJECT_PORTFOLIO
            ]
            so = min(cands, key=lambda a: a.total_size)
        else:
            so = SHARED_OBJECT_STRATEGIES[strategy](records)
        off = from_shared_objects(so)
        name = so.strategy
    else:
        lb = offsets_lower_bound(records)
        if strategy == "auto":
            # paper §6: evaluate GBS and Strip-Packing Best-fit, pick best;
            # we also throw in GBB for completeness.
            cands = [
                OFFSET_STRATEGIES[name](records)
                for name in AUTO_OFFSET_PORTFOLIO
            ]
            off = min(cands, key=lambda a: a.total_size)
        else:
            off = OFFSET_STRATEGIES[strategy](records)
        name = off.strategy
    wall = time.perf_counter() - t0
    plan = MemoryPlan(
        graph_name=graph_name,
        strategy=name,
        records=records,
        offsets=dict(off.offsets),
        total_size=off.total_size,
        lower_bound=lb,
        naive_size=naive_consumption(records),
        plan_wall_s=wall,
        shared_objects=so,
    )
    if key is not None and cache is not None:
        cache.put(key, plan)
    return plan


def plan_graph(
    graph: Graph,
    *,
    mode: Mode = "offsets",
    strategy: str = "auto",
    alignment: int = DEFAULT_ALIGNMENT,
    cache: plan_io.PlanCache | None = None,
    use_cache: bool = True,
) -> MemoryPlan:
    """Thin wrapper over the unified facade. Alignment needs no explicit
    cache key: it is baked into the record sizes the signature hashes."""
    from repro.core import unified  # function-level: unified imports planner

    spec = unified.PlanSpec(
        graph=graph, mode=mode, strategy=strategy, alignment=alignment,
        cache=cache, use_cache=use_cache,
    )
    return unified.plan(spec).activation
