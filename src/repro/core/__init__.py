"""Core memory-planning library (the paper's contribution).

Public API:
    unified      — THE planning facade: ``repro.core.plan(PlanSpec) ->
                   UnifiedPlan`` covering the activation half (MemoryPlan)
                   and the cross-step slot/KV state half (StatePlan) under
                   one fingerprint and one total; PlanSession is the
                   single plan source an InferenceEngine serves from
    records      — usage records, profiles, breadths, lower bounds
    interval_set — shared overlap engine: DisjointIntervalSet (per-object
                   disjoint intervals, O(log n) fit/gap), IntervalTree
                   (balanced, max-endpoint augmented), BestFitArena
                   (incremental Algorithm-3 gap search)
    shared_objects — Greedy-by-Size / -Improved / Greedy-by-Breadth (paper §4)
    offsets      — Greedy-by-Size / Greedy-by-Breadth offsets (paper §5)
    baselines    — naive, TFLite Greedy, min-cost flow, strip packing
    planner      — MemoryPlan facade (auto strategy selection per paper §6)
    plan_io      — versioned plan JSON + content-addressed plan cache
    reference    — FROZEN seed implementations (the differential oracle)
    optimal      — exact branch-and-bound (beyond paper)
    order_search — topological-order search over REAL cached plans, with
                   an incremental usage-record updater (paper §7.1)
    fusion_search — MAFAT-style fusion search over graph partitions;
                   keeps partitions that shrink the planned arena

Oracle-vs-fast contract
    ``reference`` preserves the seed's naive O(k·n²) strategies, with
    their own local copies of every derived quantity. The fast strategies
    are pure data-structure swaps over ``interval_set`` with iteration
    order and tie-breaking preserved EXACTLY, so for every strategy with
    a frozen twin the assignments/offsets — not merely the totals — must
    be identical on any record set. ``tests/test_differential_planner.py``
    enforces this over hundreds of randomized graphs plus all model
    configs; ``benchmarks/planner_scaling.py`` re-checks totals at sizes
    the test harness doesn't reach. A new strategy lands its frozen twin
    in ``reference`` BEFORE its fast implementation.

Plan-cache keying
    Planning is pure: output = f(records, mode, strategy). The cache key
    (``plan_io.plan_signature``) is a sha256 over the format version, the
    mode, the strategy string, and the records canonicalized in tensor_id
    order. Alignment needs no explicit
    key component — it is baked into the record sizes ``plan_graph``
    hashes. ``"auto"`` keys additionally spell out the evaluated
    portfolio, and every key includes ``plan_io.PLANNER_REVISION`` (bump
    it whenever a strategy's output may change), so persisted caches
    self-invalidate on planner upgrades. Graph names are excluded
    (identical graphs share one entry; plans are re-labelled on cache
    hit). The default cache is in-memory; point ``REPRO_PLAN_CACHE_DIR``
    at a directory for a shared, atomically-written disk tier (the
    variable is re-read on every planning call, not frozen at import).
"""

from repro.core.fusion_search import (
    FusionSearchResult,
    fuse_groups,
    fusion_search,
)
from repro.core.graph import Graph, GraphBuilder, Op, TensorSpec
from repro.core.interval_set import BestFitArena, DisjointIntervalSet, IntervalTree
from repro.core.order_search import (
    IncrementalRecords,
    OrderSearchResult,
    memory_aware_topo_order,
    search_order,
    simulated_annealing_order,
)
from repro.core.plan_io import (
    PLAN_FORMAT_VERSION,
    PLANNER_REVISION,
    PlanCache,
    load_plan,
    plan_from_json,
    plan_signature,
    plan_to_json,
    save_plan,
)
from repro.core.planner import (
    MemoryPlan,
    OFFSET_STRATEGIES,
    SHARED_OBJECT_STRATEGIES,
    plan_graph,
    plan_records,
)
from repro.core.records import (
    TensorUsageRecord,
    align,
    make_records,
    naive_consumption,
    offsets_lower_bound,
    operator_breadths,
    operator_profiles,
    positional_maximums,
    shared_objects_lower_bound,
)
from repro.core.unified import (
    PlanSession,
    PlanSpec,
    StatePlan,
    StateRecord,
    UnifiedPlan,
    plan,
    plan_state,
    state_records_from_pytree,
)

__all__ = [
    "PlanSession",
    "PlanSpec",
    "StatePlan",
    "StateRecord",
    "UnifiedPlan",
    "plan",
    "plan_state",
    "state_records_from_pytree",
    "FusionSearchResult",
    "fuse_groups",
    "fusion_search",
    "Graph",
    "GraphBuilder",
    "Op",
    "TensorSpec",
    "IncrementalRecords",
    "OrderSearchResult",
    "memory_aware_topo_order",
    "search_order",
    "simulated_annealing_order",
    "BestFitArena",
    "DisjointIntervalSet",
    "IntervalTree",
    "PLAN_FORMAT_VERSION",
    "PLANNER_REVISION",
    "PlanCache",
    "load_plan",
    "plan_from_json",
    "plan_signature",
    "plan_to_json",
    "save_plan",
    "MemoryPlan",
    "OFFSET_STRATEGIES",
    "SHARED_OBJECT_STRATEGIES",
    "plan_graph",
    "plan_records",
    "TensorUsageRecord",
    "align",
    "make_records",
    "naive_consumption",
    "offsets_lower_bound",
    "operator_breadths",
    "operator_profiles",
    "positional_maximums",
    "shared_objects_lower_bound",
]
