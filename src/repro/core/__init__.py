"""Core memory-planning library (the paper's contribution).

Public API:
    records      — usage records, profiles, breadths, lower bounds
    shared_objects — Greedy-by-Size / -Improved / Greedy-by-Breadth (paper §4)
    offsets      — Greedy-by-Size / Greedy-by-Breadth offsets (paper §5)
    baselines    — naive, TFLite Greedy, min-cost flow, strip packing
    planner      — MemoryPlan facade (auto strategy selection per paper §6)
    optimal      — exact branch-and-bound (beyond paper)
    order_search — topological-order optimization (paper §7.1 future work)
"""

from repro.core.graph import Graph, GraphBuilder, Op, TensorSpec
from repro.core.planner import (
    MemoryPlan,
    OFFSET_STRATEGIES,
    SHARED_OBJECT_STRATEGIES,
    plan_graph,
    plan_records,
)
from repro.core.records import (
    TensorUsageRecord,
    align,
    make_records,
    naive_consumption,
    offsets_lower_bound,
    operator_breadths,
    operator_profiles,
    positional_maximums,
    shared_objects_lower_bound,
)

__all__ = [
    "Graph",
    "GraphBuilder",
    "Op",
    "TensorSpec",
    "MemoryPlan",
    "OFFSET_STRATEGIES",
    "SHARED_OBJECT_STRATEGIES",
    "plan_graph",
    "plan_records",
    "TensorUsageRecord",
    "align",
    "make_records",
    "naive_consumption",
    "offsets_lower_bound",
    "operator_breadths",
    "operator_profiles",
    "positional_maximums",
    "shared_objects_lower_bound",
]
