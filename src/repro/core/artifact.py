"""Plan bundles: ahead-of-time compiled memory plans as serving artifacts.

The paper's planner is an ahead-of-time optimization — "the memory manager
needs to run only once before the first inference" (§5). This module makes
that literal: a :class:`PlanBundle` carries everything a serving process
needs to materialize its activation arena *without* tracing a jaxpr or
running a planning strategy:

* the chosen :class:`~repro.core.planner.MemoryPlan` (usage records,
  strategy name, offsets, total size) serialized through ``plan_io``;
* the searched order / fusion partition that produced it (when
  ``launch/compile.py --search`` found a smaller plan than the default
  program order), so provenance of the footprint is auditable;
* two fingerprints: a **cheap config-level** one (:func:`decode_fingerprint`
  — hash of the graph-shaping inputs: architecture config, slot count,
  cache length, pipeline revision) that a serving engine verifies without
  tracing anything, and a **structural** one (:func:`graph_fingerprint` —
  hash of the traced op/tensor graph) that the compile step records and
  the fallback path can check after a fresh trace.

Bundles are stored content-addressed under a directory managed by
:class:`BundleManifest`: the bundle file is named by the sha256 of its
canonical JSON (byte-deterministic — ``plan_wall_s`` is zeroed at publish
time), and ``manifest.json`` maps human-readable bucket keys
(``arch|layers|d_model|slots|len|dtype``) to bundle files. Two buckets
whose compiled bundles coincide byte-for-byte (config aliases, recompiles)
share one file. Loaders reject unknown format versions rather than
guessing.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path
from typing import TYPE_CHECKING, Any

from repro.core import plan_io

if TYPE_CHECKING:  # keep this module importable without jax
    from repro.configs.base import ArchConfig
    from repro.core.graph import Graph
    from repro.core.planner import MemoryPlan

BUNDLE_FORMAT_VERSION = 1

# Revision of the trace→plan pipeline semantics. Part of every
# fingerprint: bump it when the tracer (scan expansion, inlining set),
# graph extraction, or any MODEL IMPLEMENTATION (``models/``) may produce
# a different decode graph for the same config, and previously compiled
# bundles self-invalidate instead of silently serving a plan a current
# trace would no longer produce. The config-level fingerprint cannot see
# code changes on its own — this constant is how they re-key; for a
# trace-backed check at serving time use
# ``InferenceEngine(verify_bundle=True)``, which compares the stored
# ``graph_fingerprint`` against a fresh trace. Planner output changes are
# covered separately by ``plan_io.PLANNER_REVISION``.
PIPELINE_REVISION = 1


# ------------------------------------------------------------ fingerprints


def _sha(payload: Any) -> str:
    text = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(text.encode()).hexdigest()


def decode_fingerprint(cfg: "ArchConfig", *, n_slots: int, max_len: int) -> str:
    """Hash of everything that shapes the decode-step graph, computable in
    microseconds — no trace, no planner. Covers the full architecture
    config (minus ``source``, a citation string that cannot affect any
    tensor), the serving bucket (``n_slots``, ``max_len``), and the
    pipeline/planner revisions."""
    cfg_obj = dataclasses.asdict(cfg)
    cfg_obj.pop("source", None)
    return _sha(
        {
            "format_version": BUNDLE_FORMAT_VERSION,
            "pipeline_revision": PIPELINE_REVISION,
            "planner_revision": plan_io.PLANNER_REVISION,
            "config": cfg_obj,
            "n_slots": n_slots,
            "max_len": max_len,
        }
    )


def graph_fingerprint(graph: "Graph") -> str:
    """Structural hash of a traced graph: op names and tensor wiring in
    execution order, tensor byte sizes, boundary set. Two graphs with the
    same fingerprint yield identical usage records, hence identical plans."""
    return _sha(
        {
            "ops": [
                [op.name, list(op.inputs), list(op.outputs)]
                for op in graph.ops
            ],
            "tensors": sorted(
                (t.tensor_id, t.nbytes) for t in graph.tensors.values()
            ),
            "boundary": sorted(graph.boundary_ids),
        }
    )


def bucket_key(cfg: "ArchConfig", *, n_slots: int, max_len: int) -> str:
    """Human-readable manifest index for an (arch, n_slots, max_len, dtype)
    serving bucket. Layer count / width distinguish full configs from
    their ``reduced()`` variants, which share ``cfg.name``. The fingerprint
    (stored alongside) remains the actual correctness guard."""
    return (
        f"{cfg.name}|L{cfg.n_layers}|d{cfg.d_model}"
        f"|slots{n_slots}|len{max_len}|{cfg.dtype}"
    )


# ----------------------------------------------------------------- bundles


@dataclasses.dataclass
class PlanBundle:
    """One compiled decode-graph memory plan, ready to serve from.

    ``plan.plan_wall_s`` is normalized to 0.0 so the canonical encoding is
    byte-deterministic (content addressing stays stable across recompiles
    of an unchanged graph).
    """

    fingerprint: str  # decode_fingerprint of the compiled bucket
    graph_fingerprint: str  # structural hash of the traced graph
    arch: str
    n_slots: int
    max_len: int
    dtype: str
    plan: "MemoryPlan"
    # searched-order op permutation (original index order) when order
    # search won; None when the default program order was kept
    order: list[int] | None = None
    # fusion partition (contiguous op-index groups) when fusion won
    fusion_groups: list[list[int]] | None = None
    # deterministic compile-time metadata: tool, strategy, search stats,
    # greedy-vs-searched footprints, xla_temp_bytes when measured
    provenance: dict = dataclasses.field(default_factory=dict)

    @property
    def total_size(self) -> int:
        return self.plan.total_size

    def summary(self) -> str:
        searched = self.provenance.get("searched_total_bytes")
        greedy = self.provenance.get("greedy_total_bytes")
        extra = ""
        if searched is not None and greedy is not None:
            extra = (
                f" (greedy {greedy / 2**20:.3f} MiB -> "
                f"searched {searched / 2**20:.3f} MiB)"
            )
        return (
            f"bundle {self.arch} slots={self.n_slots} len={self.max_len} "
            f"{self.dtype}: {self.plan.total_size / 2**20:.3f} MiB "
            f"[{self.plan.strategy}]{extra}"
        )


def bundle_to_obj(bundle: PlanBundle) -> dict:
    plan = dataclasses.replace(bundle.plan, plan_wall_s=0.0)
    return {
        "format_version": BUNDLE_FORMAT_VERSION,
        "fingerprint": bundle.fingerprint,
        "graph_fingerprint": bundle.graph_fingerprint,
        "arch": bundle.arch,
        "n_slots": bundle.n_slots,
        "max_len": bundle.max_len,
        "dtype": bundle.dtype,
        "plan": plan_io.plan_to_obj(plan),
        "order": bundle.order,
        "fusion_groups": bundle.fusion_groups,
        "provenance": bundle.provenance,
    }


def bundle_from_obj(obj: dict) -> PlanBundle:
    if not isinstance(obj, dict):
        raise ValueError(
            f"plan bundle must be a JSON object, got {type(obj).__name__}"
        )
    version = obj.get("format_version")
    if version != BUNDLE_FORMAT_VERSION:
        raise ValueError(
            f"unsupported plan-bundle format version {version!r} "
            f"(this build reads version {BUNDLE_FORMAT_VERSION})"
        )
    return PlanBundle(
        fingerprint=obj["fingerprint"],
        graph_fingerprint=obj["graph_fingerprint"],
        arch=obj["arch"],
        n_slots=obj["n_slots"],
        max_len=obj["max_len"],
        dtype=obj["dtype"],
        plan=plan_io.plan_from_obj(obj["plan"]),
        order=obj["order"],
        fusion_groups=obj["fusion_groups"],
        provenance=obj["provenance"] or {},
    )


def bundle_to_json(bundle: PlanBundle) -> str:
    """Canonical encoding: sorted keys, fixed separators — byte-stable."""
    return json.dumps(
        bundle_to_obj(bundle), sort_keys=True, separators=(",", ":")
    )


def bundle_from_json(text: str) -> PlanBundle:
    return bundle_from_obj(json.loads(text))


def save_bundle(bundle: PlanBundle, path: str | Path) -> None:
    Path(path).write_text(bundle_to_json(bundle))


def load_bundle(path: str | Path) -> PlanBundle:
    return bundle_from_json(Path(path).read_text())


# ---------------------------------------------------------------- manifest

MANIFEST_NAME = "manifest.json"


@contextlib.contextmanager
def _locked(lock_path: Path):
    """Advisory exclusive lock (flock) held for a manifest index update.
    Degrades to unlocked on platforms/filesystems without flock — the
    rename below is still atomic, only lost-update protection is lost."""
    try:
        import fcntl
    except ImportError:
        yield
        return
    with open(lock_path, "a+") as fh:
        try:
            fcntl.flock(fh, fcntl.LOCK_EX)
        except OSError:
            yield
            return
        try:
            yield
        finally:
            fcntl.flock(fh, fcntl.LOCK_UN)


class BundleManifest:
    """A directory of content-addressed bundle files + a bucket index.

    Layout::

        <dir>/manifest.json            # {"format_version", "buckets": {...}}
        <dir>/bundle-<sha16>.json      # canonical PlanBundle documents

    ``buckets`` maps :func:`bucket_key` strings to
    ``{"file", "fingerprint", "total_size", "created_unix", "command"}``.
    Timestamps and the compile command live here (mutable index), never in
    the bundle payload (immutable, content-addressed).
    """

    def __init__(self, directory: str | Path):
        self.dir = Path(directory)

    @property
    def manifest_path(self) -> Path:
        return self.dir / MANIFEST_NAME

    def _read_index(self) -> dict:
        try:
            obj = json.loads(self.manifest_path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return {"format_version": BUNDLE_FORMAT_VERSION, "buckets": {}}
        if obj.get("format_version") != BUNDLE_FORMAT_VERSION:
            raise ValueError(
                f"unsupported manifest format version "
                f"{obj.get('format_version')!r} in {self.manifest_path}"
            )
        return obj

    def buckets(self) -> dict[str, dict]:
        return self._read_index()["buckets"]

    def publish(
        self, key: str, bundle: PlanBundle, *, command: str | None = None
    ) -> Path:
        """Write ``bundle`` content-addressed and point ``key`` at it.
        Recompiles of an unchanged graph rewrite the same file. The index
        read-modify-write is serialized through an advisory file lock so
        concurrent compiles into one manifest (fleet sweeps, parallel
        ``serve --compile-first``) cannot drop each other's buckets, then
        lands via an atomic same-directory rename."""
        self.dir.mkdir(parents=True, exist_ok=True)
        text = bundle_to_json(bundle)
        sha = hashlib.sha256(text.encode()).hexdigest()
        path = self.dir / f"bundle-{sha[:16]}.json"
        if not path.exists():
            path.write_text(text)
        with _locked(self.dir / ".manifest.lock"):
            index = self._read_index()
            index["buckets"][key] = {
                "file": path.name,
                "fingerprint": bundle.fingerprint,
                "total_size": bundle.plan.total_size,
                "strategy": bundle.plan.strategy,
                "created_unix": time.time(),
                "command": command,
            }
            tmp = self.manifest_path.with_suffix(f".tmp{os.getpid()}")
            tmp.write_text(json.dumps(index, sort_keys=True, indent=1))
            tmp.replace(self.manifest_path)
        return path

    def lookup(self, key: str) -> PlanBundle | None:
        entry = self.buckets().get(key)
        if entry is None:
            return None
        return load_bundle(self.dir / entry["file"])


def resolve_bundle(
    source: "PlanBundle | str | Path",
    cfg: "ArchConfig",
    *,
    n_slots: int,
    max_len: int,
) -> PlanBundle:
    """Accept what a serving caller naturally has: a loaded bundle, a path
    to one bundle file, or a manifest directory (looked up by bucket key).
    Raises ``FileNotFoundError``/``ValueError`` on missing or unreadable
    sources; fingerprint verification is the caller's job (the engine
    checks and falls back)."""
    if isinstance(source, PlanBundle):
        return source
    path = Path(source)
    if path.is_dir():
        key = bucket_key(cfg, n_slots=n_slots, max_len=max_len)
        bundle = BundleManifest(path).lookup(key)
        if bundle is None:
            raise FileNotFoundError(
                f"no bundle for bucket {key!r} in manifest {path}"
            )
        return bundle
    return load_bundle(path)
