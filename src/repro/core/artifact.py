"""Plan bundles: ahead-of-time compiled memory plans as serving artifacts.

The paper's planner is an ahead-of-time optimization — "the memory manager
needs to run only once before the first inference" (§5). This module makes
that literal: a :class:`PlanBundle` carries everything a serving process
needs to materialize its activation arena *without* tracing a jaxpr or
running a planning strategy:

* the chosen :class:`~repro.core.planner.MemoryPlan` (usage records,
  strategy name, offsets, total size) serialized through ``plan_io``;
* **format v2**: the cross-step :class:`~repro.core.unified.StatePlan`
  (slot/KV shared-objects layout with concrete offsets), so one artifact
  covers BOTH halves of the serving bucket's memory — a v2 bundle
  round-trips a full :class:`~repro.core.unified.UnifiedPlan`
  (:func:`unified_from_bundle`);
* the searched order / fusion partition that produced the activation plan
  (when ``launch/compile.py --search`` found a smaller plan than the
  default program order), so provenance of the footprint is auditable;
* two fingerprints: a **cheap config-level** one (:func:`decode_fingerprint`
  — hash of the graph-shaping inputs: architecture config, slot count,
  cache length, pipeline revision) that a serving engine verifies without
  tracing anything, and a **structural** one (:func:`graph_fingerprint` —
  hash of the traced op/tensor graph) that the compile step records and
  the fallback path can check after a fresh trace;
* **format v3**: an :class:`ExecutablePack` of AOT-serialized decode
  executables (the exact step / reset / scan-block functions the state
  backends jit), keyed by the bundle fingerprint plus a platform +
  jax-version pair, so a swept fleet node goes process-start→first-token
  with **zero XLA compiles**. A stale or cross-platform pack is refused
  with a one-line warning and the engine degrades to lazy compile —
  never a crash (see ``runtime/aot.py``).

Bundles are stored content-addressed under a directory managed by
:class:`BundleManifest`: the bundle file is named by the sha256 of its
canonical JSON (byte-deterministic — ``plan_wall_s`` is zeroed at publish
time), and ``manifest.json`` maps human-readable bucket keys
(``arch|layers|d_model|slots|len|dtype``) to bundle files. Two buckets
whose compiled bundles coincide byte-for-byte (config aliases, recompiles)
share one file. Loaders reject unknown *newer* format versions rather
than guessing; v1 bundles still load through a shim (one
``DeprecationWarning``, no state plan) — their fingerprints no longer
match a v2 engine's bucket, so they fall back to plan-at-construction
with the usual one-line warning. A truncated or garbage ``manifest.json``
is quarantined (renamed ``manifest.json.corrupt-<ts>``) and the index is
rebuilt from the ``bundle-*.json`` files on disk.
"""

from __future__ import annotations

import base64
import contextlib
import dataclasses
import hashlib
import json
import os
import re
import time
import warnings
from pathlib import Path
from typing import TYPE_CHECKING, Any

from repro.core import plan_io
from repro.core.unified import (
    StatePlan,
    UnifiedPlan,
    state_plan_from_obj,
    state_plan_to_obj,
)

if TYPE_CHECKING:  # keep this module importable without jax
    from repro.configs.base import ArchConfig
    from repro.core.graph import Graph
    from repro.core.planner import MemoryPlan

# v2: + state_plan (cross-step slot/KV layout), + n_layers/d_model (the
# bucket-key shape fields, so a manifest index can be rebuilt from bundle
# files alone)
# v3: + executables (AOT-serialized decode step/reset/block, platform +
# jax-version keyed — zero XLA compiles on the serving path)
# v4: + prefill_plan/prefill_len (the planned prefill activation arena —
# long-lifetime full-sequence regime — compiled alongside the decode
# plan; the prefill shape joins the fingerprint and the bucket key)
BUNDLE_FORMAT_VERSION = 4

# What ``decode_fingerprint`` hashes is versioned SEPARATELY from the
# bundle container: the v2->v3 rev only ADDS the executable payload (the
# graph-shaping inputs are untouched), so v2 bundles must keep
# fingerprint-matching a v3 engine and degrade to lazy compile — not fall
# all the way back to plan-at-construction. Bump this only when the
# fingerprint *payload itself* changes meaning.
FINGERPRINT_SCHEMA_VERSION = 2

# The manifest index schema is versioned separately: v1 manifest dirs
# remain readable across the bundle v1->v2 rev (their per-bucket entries
# just point at bundles a v2 engine will refuse by fingerprint).
MANIFEST_FORMAT_VERSION = 1

# Revision of the trace→plan pipeline semantics. Part of every
# fingerprint: bump it when the tracer (scan expansion, inlining set),
# graph extraction, or any MODEL IMPLEMENTATION (``models/``) may produce
# a different decode graph for the same config, and previously compiled
# bundles self-invalidate instead of silently serving a plan a current
# trace would no longer produce. The config-level fingerprint cannot see
# code changes on its own — this constant is how they re-key; for a
# trace-backed check at serving time use
# ``InferenceEngine(verify_bundle=True)``, which compares the stored
# ``graph_fingerprint`` against a fresh trace. Planner output changes are
# covered separately by ``plan_io.PLANNER_REVISION``.
PIPELINE_REVISION = 1


# ------------------------------------------------------------ fingerprints


def _sha(payload: Any) -> str:
    text = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(text.encode()).hexdigest()


def serve_fingerprint(
    *,
    block_size: int = 1,
    greedy: bool = True,
    temperature: float = 1.0,
    top_k: int = 0,
    page_size: "int | None" = None,
    page_pool: "int | None" = None,
) -> "dict | None":
    """Canonical serve-loop payload for :func:`decode_fingerprint`: the
    sampling knobs + scan block size that shape the compiled serving
    graph (the scan length and the sampling ops live inside the decode
    jit on the block path), and the paged-state knobs (the page table is
    a decode input whose shape — and the physical buffer size — follow
    ``page_size``/``page_pool``). Returns ``None`` for the default
    single-wave greedy host loop so default fingerprints — and every
    pre-existing bundle — are unchanged. Greedy canonicalizes
    ``temperature``/``top_k`` away (they do not shape the greedy graph);
    the sample seed never joins (it is a traced key argument, not graph
    structure)."""
    if greedy:
        temperature, top_k = 1.0, 0
    if block_size == 1 and greedy and not page_size:
        return None
    payload = {
        "block_size": int(block_size),
        "greedy": bool(greedy),
        "temperature": float(temperature),
        "top_k": int(top_k),
    }
    if page_size:
        payload["page_size"] = int(page_size)
        if page_pool is not None:
            payload["page_pool"] = int(page_pool)
    return payload


def decode_fingerprint(
    cfg: "ArchConfig",
    *,
    n_slots: int,
    max_len: int,
    serve_params: "dict | None" = None,
    prefill_len: "int | None" = None,
) -> str:
    """Hash of everything that shapes the decode-step graph, computable in
    microseconds — no trace, no planner. Covers the full architecture
    config (minus ``source``, a citation string that cannot affect any
    tensor), the serving bucket (``n_slots``, ``max_len``), the
    pipeline/planner revisions, and — when the serving loop deviates from
    the default greedy host loop — the :func:`serve_fingerprint` payload
    (block size + sampling knobs), so bundles compiled for one serving
    configuration self-invalidate under another. ``prefill_len`` joins
    only when set (same None-canonicalization as ``serve_params``), so
    every decode-only bundle and engine expectation is byte-unchanged."""
    cfg_obj = dataclasses.asdict(cfg)
    cfg_obj.pop("source", None)
    payload = {
        "format_version": FINGERPRINT_SCHEMA_VERSION,
        "pipeline_revision": PIPELINE_REVISION,
        "planner_revision": plan_io.PLANNER_REVISION,
        "config": cfg_obj,
        "n_slots": n_slots,
        "max_len": max_len,
    }
    if serve_params:
        payload["serve_params"] = serve_params
    if prefill_len:
        payload["prefill_len"] = int(prefill_len)
    return _sha(payload)


def graph_fingerprint(graph: "Graph") -> str:
    """Structural hash of a traced graph: op names and tensor wiring in
    execution order, tensor byte sizes, boundary set. Two graphs with the
    same fingerprint yield identical usage records, hence identical plans."""
    return _sha(
        {
            "ops": [
                [op.name, list(op.inputs), list(op.outputs)]
                for op in graph.ops
            ],
            "tensors": sorted(
                (t.tensor_id, t.nbytes) for t in graph.tensors.values()
            ),
            "boundary": sorted(graph.boundary_ids),
        }
    )


def bucket_key(
    cfg: "ArchConfig", *, n_slots: int, max_len: int,
    page_size: "int | None" = None,
    prefill_len: "int | None" = None,
) -> str:
    """Human-readable manifest index for an (arch, n_slots, max_len, dtype
    [, page_size][, prefill_len]) serving bucket. Layer count / width
    distinguish full configs from their ``reduced()`` variants, which
    share ``cfg.name``; paged buckets carry a ``|page{P}`` suffix so a
    paged and a symmetric compile of the same shape coexist in one
    manifest; prefill-carrying buckets add ``|pf{S}`` (the planned prefill
    sequence length). The fingerprint (stored alongside) remains the
    actual correctness guard."""
    key = (
        f"{cfg.name}|L{cfg.n_layers}|d{cfg.d_model}"
        f"|slots{n_slots}|len{max_len}|{cfg.dtype}"
    )
    if page_size:
        key += f"|page{int(page_size)}"
    if prefill_len:
        key += f"|pf{int(prefill_len)}"
    return key


_BUCKET_KEY_RE = re.compile(
    r"(?P<arch>.+)\|L(?P<n_layers>\d+)\|d(?P<d_model>\d+)"
    r"\|slots(?P<n_slots>\d+)\|len(?P<max_len>\d+)\|(?P<dtype>[^|]+?)"
    r"(\|page(?P<page_size>\d+))?(\|pf(?P<prefill_len>\d+))?"
)


def parse_bucket_key(key: str) -> dict | None:
    """Inverse of :func:`bucket_key`: the structured bucket, or None for a
    foreign/hand-made key (bucket auto-selection skips those).
    ``page_size`` is None for symmetric buckets; ``prefill_len`` is None
    for decode-only buckets."""
    m = _BUCKET_KEY_RE.fullmatch(key)
    if m is None:
        return None
    out: dict[str, Any] = m.groupdict()
    for field in ("n_layers", "d_model", "n_slots", "max_len"):
        out[field] = int(out[field])
    for field in ("page_size", "prefill_len"):
        out[field] = int(out[field]) if out[field] is not None else None
    return out


def bundle_bucket_key(bundle: PlanBundle) -> str | None:
    """Reconstruct the canonical bucket key from a bundle's own fields —
    the manifest-rebuild path. None for bundles that predate the shape
    fields (v1 shims, hand-built test bundles)."""
    if not bundle.n_layers or not bundle.d_model:
        return None
    key = (
        f"{bundle.arch}|L{bundle.n_layers}|d{bundle.d_model}"
        f"|slots{bundle.n_slots}|len{bundle.max_len}|{bundle.dtype}"
    )
    page_size = getattr(bundle.state_plan, "page_size", None)
    if page_size:
        key += f"|page{int(page_size)}"
    if bundle.prefill_len:
        key += f"|pf{int(bundle.prefill_len)}"
    return key


# ------------------------------------------------------------- executables


def _payload_sha(payload: bytes) -> str:
    return hashlib.sha256(payload).hexdigest()


@dataclasses.dataclass
class ExecutableEntry:
    """One AOT-serialized compiled function (opaque bytes — produced and
    consumed only by ``runtime/aot.py``; this module never unpickles)."""

    payload: bytes
    sha256: str  # of payload — integrity check before deserialization
    nbytes: int  # == len(payload); surfaced in docs/lint size reporting


def executable_entry(payload: bytes) -> ExecutableEntry:
    return ExecutableEntry(
        payload=payload, sha256=_payload_sha(payload), nbytes=len(payload)
    )


@dataclasses.dataclass
class ExecutablePack:
    """The v3 bundle's AOT half: serialized executables for every decode
    function a state backend would otherwise jit, keyed by the platform
    and jax version they were compiled under. A pack whose platform or
    jax_version does not match the serving process is *refused* (one-line
    warning, lazy-compile fallback) — serialized XLA executables are not
    portable across backends or jax releases."""

    platform: str  # jax.default_backend() at compile time
    jax_version: str
    entries: dict[str, ExecutableEntry]

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self.entries.values())


def executables_to_obj(pack: ExecutablePack) -> dict:
    return {
        "platform": pack.platform,
        "jax_version": pack.jax_version,
        "entries": {
            name: {
                "payload_b64": base64.b64encode(entry.payload).decode(
                    "ascii"
                ),
                "sha256": entry.sha256,
                "nbytes": entry.nbytes,
            }
            for name, entry in sorted(pack.entries.items())
        },
    }


def block_entry_name(backend: str, length: int) -> str:
    """Pack entry name for a scan-block executable
    (``resident_block_4``, ``pytree_block_4``, ...)."""
    return f"{backend}_block_{int(length)}"


def expected_executable_entries(
    block_size: int = 1, *, paged: bool = False
) -> list[str]:
    """The entry names a complete pack carries for one serving bucket:
    decode + reset for BOTH state backends (residency is a serving-time
    knob the compile step cannot predict; paged buckets pair the paged
    backend with the pytree fallback), plus the full-size scan block on
    block-mode buckets (tail blocks have engine-chosen shorter lengths
    and lazy-compile)."""
    backend = "paged" if paged else "resident"
    names = [
        "pytree_decode",
        "pytree_reset",
        f"{backend}_decode",
        f"{backend}_reset",
    ]
    if block_size > 1:
        names.append(block_entry_name(backend, block_size))
        names.append(block_entry_name("pytree", block_size))
    return sorted(names)


def executables_from_obj(obj: dict) -> ExecutablePack:
    entries = {}
    for name, e in obj.get("entries", {}).items():
        entries[name] = ExecutableEntry(
            payload=base64.b64decode(e["payload_b64"]),
            sha256=e["sha256"],
            nbytes=e["nbytes"],
        )
    return ExecutablePack(
        platform=obj["platform"],
        jax_version=obj["jax_version"],
        entries=entries,
    )


# ----------------------------------------------------------------- bundles


@dataclasses.dataclass
class PlanBundle:
    """One compiled decode-graph memory plan, ready to serve from.

    ``plan.plan_wall_s`` is normalized to 0.0 so the canonical encoding is
    byte-deterministic (content addressing stays stable across recompiles
    of an unchanged graph).
    """

    fingerprint: str  # decode_fingerprint of the compiled bucket
    graph_fingerprint: str  # structural hash of the traced graph
    arch: str
    n_slots: int
    max_len: int
    dtype: str
    plan: "MemoryPlan"
    # searched-order op permutation (original index order) when order
    # search won; None when the default program order was kept
    order: list[int] | None = None
    # fusion partition (contiguous op-index groups) when fusion won
    fusion_groups: list[list[int]] | None = None
    # deterministic compile-time metadata: tool, strategy, search stats,
    # greedy-vs-searched footprints, xla_temp_bytes when measured
    provenance: dict = dataclasses.field(default_factory=dict)
    # v2: cross-step slot/KV state layout — None only in v1-shim bundles
    state_plan: StatePlan | None = None
    # v2: bucket-key shape fields (reduced() variants share cfg.name), so
    # the manifest index is rebuildable from bundle files alone; 0 means
    # "unknown" (v1-shim bundles, hand-built test bundles)
    n_layers: int = 0
    d_model: int = 0
    # v3: AOT-serialized decode executables — None in v1/v2-shim bundles
    # and under ``compile.py --no-aot`` (the engine lazy-compiles)
    executables: ExecutablePack | None = None
    # v4: the planned prefill activation arena (full-sequence forward at
    # ``prefill_len`` tokens — the long-lifetime regime) — None in
    # v1/v2/v3-shim bundles and decode-only compiles (prefill_len 0)
    prefill_plan: "MemoryPlan | None" = None
    prefill_len: int = 0

    @property
    def total_size(self) -> int:
        """Unified footprint: activation arena + cross-step state. The
        prefill arena is NOT summed in — prefill and decode never run
        concurrently in one slot's lifetime, so the prefill arena aliases
        the decode arena's address space (the peak activation demand is
        ``max(plan, prefill_plan)``, see :attr:`peak_activation_size`)."""
        return self.plan.total_size + (
            self.state_plan.total_size if self.state_plan is not None else 0
        )

    @property
    def peak_activation_size(self) -> int:
        """Peak transient-arena demand across both phases: the decode-step
        arena and (when planned) the prefill arena, whichever is larger."""
        prefill = (
            self.prefill_plan.total_size
            if self.prefill_plan is not None else 0
        )
        return max(self.plan.total_size, prefill)

    def summary(self) -> str:
        searched = self.provenance.get("searched_total_bytes")
        greedy = self.provenance.get("greedy_total_bytes")
        extra = ""
        if searched is not None and greedy is not None:
            extra = (
                f" (greedy {greedy / 2**20:.3f} MiB -> "
                f"searched {searched / 2**20:.3f} MiB)"
            )
        state = ""
        if self.state_plan is not None:
            state = (
                f" + state {self.state_plan.total_size / 2**20:.3f} MiB "
                f"= {self.total_size / 2**20:.3f} MiB unified"
            )
        aot = ""
        if self.executables is not None:
            aot = (
                f" + {len(self.executables.entries)} AOT executable(s) "
                f"({self.executables.nbytes / 2**20:.3f} MiB, "
                f"{self.executables.platform})"
            )
        prefill = ""
        if self.prefill_plan is not None:
            prefill = (
                f" + prefill[{self.prefill_len}] "
                f"{self.prefill_plan.total_size / 2**20:.3f} MiB "
                f"[{self.prefill_plan.strategy}]"
            )
        return (
            f"bundle {self.arch} slots={self.n_slots} len={self.max_len} "
            f"{self.dtype}: {self.plan.total_size / 2**20:.3f} MiB "
            f"[{self.plan.strategy}]{extra}{state}{prefill}{aot}"
        )


def unified_from_bundle(bundle: PlanBundle) -> UnifiedPlan:
    """A v2 bundle round-trips a full UnifiedPlan: activation offsets +
    cross-step state offsets under the bundle's fingerprint. v1-shim
    bundles yield ``state=None`` (the engine plans that half itself)."""
    return UnifiedPlan(
        activation=bundle.plan,
        state=bundle.state_plan,
        prefill=bundle.prefill_plan,
        fingerprint=bundle.fingerprint,
        order=bundle.order,
        fusion_groups=bundle.fusion_groups,
        provenance=dict(bundle.provenance),
    )


def bundle_to_obj(bundle: PlanBundle) -> dict:
    plan = dataclasses.replace(bundle.plan, plan_wall_s=0.0)
    return {
        "format_version": BUNDLE_FORMAT_VERSION,
        "fingerprint": bundle.fingerprint,
        "graph_fingerprint": bundle.graph_fingerprint,
        "arch": bundle.arch,
        "n_layers": bundle.n_layers,
        "d_model": bundle.d_model,
        "n_slots": bundle.n_slots,
        "max_len": bundle.max_len,
        "dtype": bundle.dtype,
        "plan": plan_io.plan_to_obj(plan),
        "state_plan": (
            state_plan_to_obj(bundle.state_plan)
            if bundle.state_plan is not None
            else None
        ),
        "order": bundle.order,
        "fusion_groups": bundle.fusion_groups,
        "provenance": bundle.provenance,
        "executables": (
            executables_to_obj(bundle.executables)
            if bundle.executables is not None
            else None
        ),
        "prefill_len": bundle.prefill_len,
        "prefill_plan": (
            plan_io.plan_to_obj(
                dataclasses.replace(bundle.prefill_plan, plan_wall_s=0.0)
            )
            if bundle.prefill_plan is not None
            else None
        ),
    }


def bundle_from_obj(obj: dict) -> PlanBundle:
    if not isinstance(obj, dict):
        raise ValueError(
            f"plan bundle must be a JSON object, got {type(obj).__name__}"
        )
    version = obj.get("format_version")
    if version == 1:
        # v1 shim: no state plan, no bucket shape fields. The bundle
        # loads, but its fingerprint hashed fingerprint-schema v1 — a
        # current engine's expectation never matches, so fallback
        # semantics are preserved (plan-at-construction, one-line
        # warning).
        warnings.warn(
            "loading plan-bundle format v1 (activation half only); "
            "recompile with launch/compile.py for a v3 bundle carrying "
            "the cross-step state plan and AOT decode executables",
            DeprecationWarning,
            stacklevel=2,
        )
    elif version == 2:
        # v2 shim: both plan halves but no AOT executables. The
        # fingerprint schema is unchanged across v2->v3, so the bundle
        # still matches its bucket and serves — the engine merely
        # degrades to lazy-compiling the decode jits.
        warnings.warn(
            "loading plan-bundle format v2 (no AOT decode executables); "
            "recompile with launch/compile.py for a v4 bundle that "
            "serves with zero XLA compiles",
            DeprecationWarning,
            stacklevel=2,
        )
    elif version == 3:
        # v3 shim: decode plans + executables but no prefill plan. The
        # fingerprint schema is unchanged across v3->v4 (prefill_len is
        # None-canonicalized out of decode-only fingerprints), so the
        # bundle still matches its bucket and serves with zero compiles
        # — it just carries no planned prefill arena. A warning, never a
        # refusal.
        warnings.warn(
            "loading plan-bundle format v3 (no planned prefill arena); "
            "recompile with launch/compile.py --prefill-len for a v4 "
            "bundle that carries the full-sequence prefill plan",
            DeprecationWarning,
            stacklevel=2,
        )
    elif version != BUNDLE_FORMAT_VERSION:
        raise ValueError(
            f"unsupported plan-bundle format version {version!r} "
            f"(this build reads versions 1-{BUNDLE_FORMAT_VERSION})"
        )
    state_obj = obj.get("state_plan")
    exec_obj = obj.get("executables")
    prefill_obj = obj.get("prefill_plan")
    return PlanBundle(
        fingerprint=obj["fingerprint"],
        graph_fingerprint=obj["graph_fingerprint"],
        arch=obj["arch"],
        n_slots=obj["n_slots"],
        max_len=obj["max_len"],
        dtype=obj["dtype"],
        plan=plan_io.plan_from_obj(obj["plan"]),
        order=obj["order"],
        fusion_groups=obj["fusion_groups"],
        provenance=obj["provenance"] or {},
        state_plan=state_plan_from_obj(state_obj) if state_obj else None,
        n_layers=obj.get("n_layers", 0),
        d_model=obj.get("d_model", 0),
        executables=executables_from_obj(exec_obj) if exec_obj else None,
        prefill_plan=plan_io.plan_from_obj(prefill_obj) if prefill_obj else None,
        prefill_len=obj.get("prefill_len", 0) or 0,
    )


def bundle_to_json(bundle: PlanBundle) -> str:
    """Canonical encoding: sorted keys, fixed separators — byte-stable."""
    return json.dumps(
        bundle_to_obj(bundle), sort_keys=True, separators=(",", ":")
    )


def bundle_from_json(text: str) -> PlanBundle:
    return bundle_from_obj(json.loads(text))


def save_bundle(bundle: PlanBundle, path: str | Path) -> None:
    Path(path).write_text(bundle_to_json(bundle))


def load_bundle(path: str | Path) -> PlanBundle:
    return bundle_from_json(Path(path).read_text())


# ---------------------------------------------------------------- manifest

MANIFEST_NAME = "manifest.json"


@contextlib.contextmanager
def _locked(lock_path: Path):
    """Advisory exclusive lock (flock) held for a manifest index update.
    Degrades to unlocked on platforms/filesystems without flock — the
    rename below is still atomic, only lost-update protection is lost."""
    try:
        import fcntl
    except ImportError:
        yield
        return
    try:
        fh = open(lock_path, "a+")
    except OSError:
        yield  # e.g. read-only manifest dir: degrade to unlocked
        return
    with fh:
        try:
            fcntl.flock(fh, fcntl.LOCK_EX)
        except OSError:
            yield
            return
        try:
            yield
        finally:
            fcntl.flock(fh, fcntl.LOCK_UN)


class BundleManifest:
    """A directory of content-addressed bundle files + a bucket index.

    Layout::

        <dir>/manifest.json            # {"format_version", "buckets": {...}}
        <dir>/bundle-<sha16>.json      # canonical PlanBundle documents

    ``buckets`` maps :func:`bucket_key` strings to
    ``{"file", "fingerprint", "total_size", "created_unix", "command"}``.
    Timestamps and the compile command live here (mutable index), never in
    the bundle payload (immutable, content-addressed).
    """

    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        # memo for pre-`unified_total` index entries whose bundles could
        # not be read during the one-shot index upgrade below
        self._legacy_totals: dict[str, int] = {}
        # the upgrade runs at most once per handle even when it cannot
        # persist (read-only manifest dir)
        self._upgraded = False

    @property
    def manifest_path(self) -> Path:
        return self.dir / MANIFEST_NAME

    def _read_index(self, *, locked: bool = False) -> dict:
        """Parse the index; a corrupt one is quarantined and rebuilt from
        the bundle files (see :meth:`_quarantine_and_rebuild`). The
        rebuild rewrites ``manifest.json``, so it must hold the same lock
        ``publish()`` serializes through — callers already inside the
        lock pass ``locked=True`` (flock is per-open-file-description:
        re-acquiring on a fresh fd would self-deadlock)."""
        index, reason = self._try_parse_index()
        if reason is None:
            return index
        if locked:
            return self._quarantine_and_rebuild(reason)
        with _locked(self.dir / ".manifest.lock"):
            # re-read first: a concurrent publish/rebuild may have fixed
            # the index while we waited on the lock
            index, reason = self._try_parse_index()
            if reason is None:
                return index
            return self._quarantine_and_rebuild(reason)

    def _try_parse_index(self) -> tuple[dict | None, str | None]:
        """(index, None) on success, (None, reason) on a corrupt index —
        the bundle files are the durable record, so corruption must not
        crash publish()/lookup()."""
        try:
            obj = json.loads(self.manifest_path.read_text())
        except FileNotFoundError:
            return (
                {"format_version": MANIFEST_FORMAT_VERSION, "buckets": {}},
                None,
            )
        except json.JSONDecodeError:
            # truncated/garbage index (killed writer, disk hiccup)
            return None, "unparseable JSON"
        if not isinstance(obj, dict) or not isinstance(
            obj.get("buckets"), dict
        ):
            return None, "not a bucket index"
        if obj.get("format_version") != MANIFEST_FORMAT_VERSION:
            raise ValueError(
                f"unsupported manifest format version "
                f"{obj.get('format_version')!r} in {self.manifest_path}"
            )
        return obj, None

    def _quarantine_and_rebuild(self, reason: str) -> dict:
        """Rename the corrupt index aside and rebuild it from the
        ``bundle-*.json`` files on disk. v2 bundles carry their bucket
        shape fields, so their canonical keys are reconstructible;
        unreadable or pre-v2 files are skipped (their buckets are lost
        from the index but the files stay on disk)."""
        quarantine = self.manifest_path.with_name(
            f"{MANIFEST_NAME}.corrupt-{int(time.time())}"
        )
        try:
            self.manifest_path.replace(quarantine)
        except OSError:
            quarantine = None
        index = {"format_version": MANIFEST_FORMAT_VERSION, "buckets": {}}
        for path in sorted(self.dir.glob("bundle-*.json")):
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DeprecationWarning)
                    bundle = load_bundle(path)
            except Exception:
                continue  # not a readable bundle; leave it alone
            key = bundle_bucket_key(bundle)
            if key is None:
                continue  # v1 shim: bucket shape fields unknown
            index["buckets"][key] = {
                "file": path.name,
                "fingerprint": bundle.fingerprint,
                "total_size": bundle.plan.total_size,
                "unified_total": bundle.total_size,
                "strategy": bundle.plan.strategy,
                "created_unix": path.stat().st_mtime,
                "command": None,
                "rebuilt_from": reason,
            }
        warnings.warn(
            f"manifest index {self.manifest_path} was corrupt ({reason}); "
            + (f"quarantined to {quarantine.name} and " if quarantine else "")
            + f"rebuilt {len(index['buckets'])} bucket(s) from bundle files",
            RuntimeWarning,
            stacklevel=3,
        )
        tmp = self.manifest_path.with_suffix(f".tmp{os.getpid()}")
        try:
            tmp.write_text(json.dumps(index, sort_keys=True, indent=1))
            tmp.replace(self.manifest_path)
        except OSError:
            pass  # read-only dir: serve the rebuilt index from memory
        return index

    def buckets(self) -> dict[str, dict]:
        return self._read_index()["buckets"]

    def publish(
        self, key: str, bundle: PlanBundle, *, command: str | None = None
    ) -> Path:
        """Write ``bundle`` content-addressed and point ``key`` at it.
        Recompiles of an unchanged graph rewrite the same file. The index
        read-modify-write is serialized through an advisory file lock so
        concurrent compiles into one manifest (fleet sweeps, parallel
        ``serve --compile-first``) cannot drop each other's buckets, then
        lands via an atomic same-directory rename."""
        self.dir.mkdir(parents=True, exist_ok=True)
        text = bundle_to_json(bundle)
        sha = hashlib.sha256(text.encode()).hexdigest()
        path = self.dir / f"bundle-{sha[:16]}.json"
        if not path.exists():
            path.write_text(text)
        with _locked(self.dir / ".manifest.lock"):
            index = self._read_index(locked=True)
            index["buckets"][key] = {
                "file": path.name,
                "fingerprint": bundle.fingerprint,
                "total_size": bundle.plan.total_size,
                "unified_total": bundle.total_size,
                "strategy": bundle.plan.strategy,
                "created_unix": time.time(),
                "command": command,
            }
            tmp = self.manifest_path.with_suffix(f".tmp{os.getpid()}")
            tmp.write_text(json.dumps(index, sort_keys=True, indent=1))
            tmp.replace(self.manifest_path)
        return path

    def lookup(self, key: str) -> PlanBundle | None:
        entry = self.buckets().get(key)
        if entry is None:
            return None
        return load_bundle(self.dir / entry["file"])

    # an unreadable bundle must LOSE the smallest-footprint ranking (0
    # would win it and hijack selection from every valid bucket)
    _UNRANKABLE = 1 << 62

    def _unified_total(self, key: str, entry: dict) -> int:
        """The bucket's unified footprint (activation + state) for the
        admission tie-break. Indexed since the v2 manifest revision;
        entries still missing it after :meth:`_upgrade_legacy_index`
        (unreadable bundles) rank last via the per-handle memo."""
        if isinstance(entry.get("unified_total"), int):
            return entry["unified_total"]
        fname = entry.get("file")
        if fname in self._legacy_totals:
            return self._legacy_totals[fname]
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                total = load_bundle(self.dir / fname).total_size
        except Exception:
            total = self._UNRANKABLE
        self._legacy_totals[fname] = total
        return total

    def _upgrade_legacy_index(self) -> dict:
        """One-shot upgrade of a pre-``unified_total`` index: load each
        legacy bundle ONCE, stamp its unified footprint into the entry,
        and persist the index — so bucket auto-selection stops re-reading
        every bundle file on every :meth:`lookup_nearest`. Best-effort on
        a read-only manifest dir: the computed totals are then served
        from the per-handle memo instead. Returns the (possibly upgraded)
        index."""
        with _locked(self.dir / ".manifest.lock"):
            index = self._read_index(locked=True)
            changed = False
            for entry in index["buckets"].values():
                if isinstance(entry.get("unified_total"), int):
                    continue
                fname = entry.get("file", "")
                try:
                    with warnings.catch_warnings():
                        warnings.simplefilter("ignore", DeprecationWarning)
                        total = load_bundle(self.dir / fname).total_size
                except Exception:
                    # unreadable: memoize the loss, keep the entry legacy
                    # so a later repair is picked up
                    self._legacy_totals[fname] = self._UNRANKABLE
                    continue
                entry["unified_total"] = total
                self._legacy_totals[fname] = total
                changed = True
            if changed:
                tmp = self.manifest_path.with_suffix(f".tmp{os.getpid()}")
                try:
                    tmp.write_text(
                        json.dumps(index, sort_keys=True, indent=1)
                    )
                    tmp.replace(self.manifest_path)
                except OSError:
                    pass  # read-only dir: totals live in the memo
        self._upgraded = True
        return index

    def lookup_nearest(
        self, cfg: "ArchConfig", *, n_slots: int, max_len: int,
        page_size: "int | None" = None,
    ) -> tuple[str, PlanBundle] | None:
        """Bucket auto-selection: the exact bucket if compiled, else the
        smallest-footprint admissible compiled bucket. Admissible means
        identical arch/layers/width/dtype/page_size with
        ``max_len >= requested`` (a longer cache serves any shorter
        request) AND ``n_slots >= requested`` (slots are the §4 shared
        objects — a bigger pool is admissible, just wasteful); paged and
        symmetric buckets are distinct families and never substitute for
        each other, while a prefill-carrying bucket (``|pf{S}``) IS
        admissible for a decode-only request — the extra prefill plan is
        inert metadata on the decode path. Ties break on the smallest
        unified footprint (activation + state), then the smallest
        (max_len, n_slots, prefill_len) for determinism. None when no
        admissible bucket exists."""
        exact = bucket_key(
            cfg, n_slots=n_slots, max_len=max_len, page_size=page_size
        )
        buckets = self.buckets()
        if exact in buckets:
            return exact, load_bundle(self.dir / buckets[exact]["file"])
        if not self._upgraded and any(
            not isinstance(e.get("unified_total"), int)
            for e in buckets.values()
        ):
            buckets = self._upgrade_legacy_index()["buckets"]
        want = parse_bucket_key(exact)
        wild = {"max_len": 0, "n_slots": 0, "prefill_len": 0}
        best: tuple[tuple[int, int, int, int], str] | None = None
        for key, entry in buckets.items():
            got = parse_bucket_key(key)
            if got is None:
                continue
            if {**got, **wild} != {**want, **wild}:
                continue
            if got["max_len"] < max_len or got["n_slots"] < n_slots:
                continue
            rank = (
                self._unified_total(key, entry),
                got["max_len"],
                got["n_slots"],
                got["prefill_len"] or 0,
            )
            if best is None or rank < best[0]:
                best = (rank, key)
        if best is None:
            return None
        return best[1], load_bundle(self.dir / buckets[best[1]]["file"])


def _describe_buckets(manifest: BundleManifest, limit: int = 12) -> str:
    """The compiled bucket keys, for miss messages — a common fleet
    misconfiguration (wrong --slots, unswept max_len) should read as
    'these buckets exist, yours does not', not as a perf mystery."""
    try:
        keys = sorted(manifest.buckets())
    except Exception:
        return "manifest index unreadable"
    if not keys:
        return "manifest is empty"
    shown = ", ".join(keys[:limit])
    more = f", ... ({len(keys) - limit} more)" if len(keys) > limit else ""
    return f"compiled buckets: {shown}{more}"


def resolve_bundle(
    source: "PlanBundle | str | Path",
    cfg: "ArchConfig",
    *,
    n_slots: int,
    max_len: int,
    nearest: bool = False,
    page_size: "int | None" = None,
) -> PlanBundle:
    """Accept what a serving caller naturally has: a loaded bundle, a path
    to one bundle file, or a manifest directory (looked up by bucket key;
    with ``nearest=True`` the lookup auto-selects the smallest-footprint
    admissible compiled bucket — ``max_len`` and ``n_slots`` both
    >= requested, same ``page_size`` family). Raises
    ``FileNotFoundError``/``ValueError`` on missing or unreadable sources
    — a manifest miss lists the bucket keys that DO exist; fingerprint
    verification is the caller's job (the engine checks and falls
    back)."""
    if isinstance(source, PlanBundle):
        return source
    path = Path(source)
    if path.is_dir():
        key = bucket_key(
            cfg, n_slots=n_slots, max_len=max_len, page_size=page_size
        )
        manifest = BundleManifest(path)
        if nearest:
            found = manifest.lookup_nearest(
                cfg, n_slots=n_slots, max_len=max_len, page_size=page_size
            )
            if found is not None:
                return found[1]
        else:
            bundle = manifest.lookup(key)
            if bundle is not None:
                return bundle
        raise FileNotFoundError(
            f"no {'admissible ' if nearest else ''}bundle for bucket "
            f"{key!r} in manifest {path}; {_describe_buckets(manifest)}"
        )
    return load_bundle(path)
