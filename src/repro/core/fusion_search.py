"""MAFAT-style fusion search over graph partitions (Farley & Gerstlauer '21).

Operator fusion changes the *record set* the planner sees: when a
contiguous run of ops is fused into one kernel, tensors produced and fully
consumed inside the run are never materialized in the arena — they stream
through kernel-local scratch (VMEM/registers). That can break the one
barrier order search cannot move: the peak operator breadth pinned by a
single producer→consumer pair of large tensors.

The model here is deliberately conservative:

* only contiguous runs of the execution order fuse (a fused kernel is one
  op in the schedule);
* a tensor is internalized only if it is not a boundary tensor and EVERY
  consumer lies inside the run — anything observable outside the fused
  kernel is still planned;
* the internalized bytes of a group must fit ``local_budget`` (the MAFAT
  local-memory constraint; the default comes from the TPU VMEM model in
  ``kernels/vmem_plan`` — per-core VMEM minus the pipeline reserve the
  kernels keep resident), and a group fuses at most ``max_group_ops`` ops;
* a candidate partition is kept ONLY if re-planning the fused graph (via
  the content-addressed plan cache) strictly shrinks the arena, so the
  result is never worse than the unfused baseline.

The search is a deterministic steepest-descent hill-climb over adjacent
group merges — every candidate costs one (cached) ``plan_records`` call,
which is the access pattern the plan cache was built for.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Sequence

from repro.core import plan_io
from repro.core.graph import Graph, Op
from repro.core.records import DEFAULT_ALIGNMENT, align

if TYPE_CHECKING:
    from repro.core.planner import MemoryPlan

# Fallback scratch budget when the kernel layer is unavailable (stripped
# install, missing pallas deps): one whole v5e core's VMEM.
_FALLBACK_LOCAL_BUDGET = 16 * 2**20


def default_local_budget() -> int:
    """Kernel-local scratch budget for fusion legality, derived from the
    TPU VMEM model in ``kernels/vmem_plan`` (total VMEM minus the pipeline
    reserve the kernels themselves keep resident). Imported lazily so the
    planner core stays usable without the kernels layer."""
    try:
        from repro.kernels.vmem_plan import fusion_scratch_budget
    except Exception:
        return _FALLBACK_LOCAL_BUDGET
    return fusion_scratch_budget()


# import-time snapshot for callers that want a number to display; the
# authoritative value is default_local_budget(), which fusion_search
# resolves at CALL time (so VMEM-model adjustments are picked up)
DEFAULT_LOCAL_BUDGET = default_local_budget()


def _consumers(graph: Graph) -> dict[int, set[int]]:
    """tensor id -> op indices reading it."""
    cons: dict[int, set[int]] = {}
    for idx, op in enumerate(graph.ops):
        for t in op.inputs:
            cons.setdefault(t, set()).add(idx)
    return cons


def _internal_ids(
    graph: Graph, group: Sequence[int], consumers: dict[int, set[int]]
) -> list[int]:
    """Tensors produced in ``group`` whose every consumer is also in the
    group (and that have at least one consumer, and are not boundary) —
    these stream through kernel-local scratch when the group fuses."""
    members = set(group)
    out = []
    for i in group:
        for t in graph.ops[i].outputs:
            if t in graph.boundary_ids:
                continue
            cons = consumers.get(t, set())
            if cons and cons <= members:
                out.append(t)
    return out


def internal_bytes(
    graph: Graph,
    group: Sequence[int],
    consumers: dict[int, set[int]] | None = None,
    alignment: int = DEFAULT_ALIGNMENT,
) -> int:
    """Aligned bytes of scratch the fused ``group`` keeps on-chip."""
    consumers = consumers if consumers is not None else _consumers(graph)
    return sum(
        align(graph.tensors[t].nbytes, alignment)
        for t in _internal_ids(graph, group, consumers)
    )


def fuse_groups(graph: Graph, groups: Sequence[Sequence[int]]) -> Graph:
    """Build the fused graph for a partition of ``range(len(graph.ops))``
    into contiguous runs (each run becomes one op).

    Internalized tensors vanish from the op list entirely — they get no
    usage record, modelling streaming through kernel-local scratch. The
    tensor table and boundary set are untouched, so everything observable
    outside a fused kernel keeps its spec and its record.
    """
    flat = [i for g in groups for i in g]
    if flat != list(range(len(graph.ops))):
        raise ValueError(
            "groups must partition op indices into contiguous in-order runs"
        )
    consumers = _consumers(graph)
    ops: list[Op] = []
    for group in groups:
        if len(group) == 1:
            ops.append(graph.ops[group[0]])
            continue
        members = set(group)
        internal = set(_internal_ids(graph, group, consumers))
        produced = {t for i in group for t in graph.ops[i].outputs}
        inputs: list[int] = []
        outputs: list[int] = []
        for i in group:
            op = graph.ops[i]
            for t in op.inputs:
                if t not in produced and t not in inputs:
                    inputs.append(t)
            for t in op.outputs:
                if t not in internal:
                    outputs.append(t)
        ops.append(
            Op(
                name="fused(" + "+".join(graph.ops[i].name for i in group) + ")",
                inputs=tuple(inputs),
                outputs=tuple(outputs),
            )
        )
    return Graph(
        name=graph.name,
        ops=ops,
        tensors=graph.tensors,
        boundary_ids=graph.boundary_ids,
    )


@dataclasses.dataclass
class FusionSearchResult:
    """Outcome of :func:`fusion_search`: the fused graph, its plan, the
    unfused baseline plan, the partition, and search statistics."""

    graph: Graph
    plan: "MemoryPlan"
    baseline_plan: "MemoryPlan"
    groups: tuple[tuple[int, ...], ...]
    internalized_bytes: int
    evaluations: int
    cache_hits: int
    cache_misses: int
    wall_s: float

    @property
    def delta_bytes(self) -> int:
        return self.baseline_plan.total_size - self.plan.total_size

    @property
    def n_fused_groups(self) -> int:
        return sum(1 for g in self.groups if len(g) > 1)

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def provenance(self) -> dict:
        """Deterministic compile-time metadata for plan artifacts
        (:mod:`repro.core.unified` merges this into bundle provenance)."""
        return {
            "fused_total_bytes": self.plan.total_size,
            "fused_groups": self.n_fused_groups,
            "internalized_bytes": self.internalized_bytes,
            "fusion_evaluations": self.evaluations,
            "fusion_cache_hits": self.cache_hits,
        }


def fusion_search(
    graph: Graph,
    *,
    mode: str = "offsets",
    strategy: str = "auto",
    max_group_ops: int = 4,
    local_budget: int | None = None,
    cache: "plan_io.PlanCache | None" = None,
    max_rounds: int | None = None,
    alignment: int = DEFAULT_ALIGNMENT,
) -> FusionSearchResult:
    """Steepest-descent search over adjacent group merges.

    Starts from the all-singletons partition; each round evaluates every
    dataflow-adjacent merge that respects ``max_group_ops`` and
    ``local_budget``, re-plans the fused graph through the plan cache, and
    commits the single merge with the smallest planned arena — but only if
    it strictly shrinks it. Terminates when no merge improves (or after
    ``max_rounds``). Deterministic; result is never worse than baseline.
    """
    from repro.core.planner import plan_records

    wall0 = time.perf_counter()
    if local_budget is None:
        local_budget = default_local_budget()
    graph.validate()  # once; fused candidates are valid by construction
    cache = cache if cache is not None else plan_io.PlanCache()
    hits0, misses0 = cache.hits, cache.misses
    evaluations = 0

    consumers = _consumers(graph)
    n = len(graph.ops)
    groups: list[tuple[int, ...]] = [(i,) for i in range(n)]

    baseline_plan = plan_records(
        graph.usage_records(alignment),
        mode=mode,
        strategy=strategy,
        graph_name=graph.name,
        cache=cache,
    )
    evaluations += 1
    best_total = baseline_plan.total_size

    def dataflow_adjacent(a: tuple[int, ...], b: tuple[int, ...]) -> bool:
        produced = {t for i in a for t in graph.ops[i].outputs}
        return any(t in produced for i in b for t in graph.ops[i].inputs)

    rounds = 0
    limit = max_rounds if max_rounds is not None else n
    while rounds < limit:
        rounds += 1
        best_merge: int | None = None
        best_merge_total = best_total
        for gi in range(len(groups) - 1):
            a, b = groups[gi], groups[gi + 1]
            if len(a) + len(b) > max_group_ops:
                continue
            if not dataflow_adjacent(a, b):
                continue
            merged = a + b
            if internal_bytes(graph, merged, consumers, alignment) > local_budget:
                continue
            cand = groups[:gi] + [merged] + groups[gi + 2:]
            fused = fuse_groups(graph, cand)
            total = plan_records(
                fused.usage_records(alignment),
                mode=mode,
                strategy=strategy,
                graph_name=graph.name,
                cache=cache,
            ).total_size
            evaluations += 1
            if total < best_merge_total:
                best_merge, best_merge_total = gi, total
        if best_merge is None:
            break
        groups = (
            groups[:best_merge]
            + [groups[best_merge] + groups[best_merge + 1]]
            + groups[best_merge + 2:]
        )
        best_total = best_merge_total

    final = fuse_groups(graph, groups)
    plan = plan_records(
        final.usage_records(alignment),
        mode=mode,
        strategy=strategy,
        graph_name=graph.name,
        cache=cache,
    )
    return FusionSearchResult(
        graph=final,
        plan=plan,
        baseline_plan=baseline_plan,
        groups=tuple(tuple(g) for g in groups),
        internalized_bytes=sum(
            internal_bytes(graph, g, consumers, alignment)
            for g in groups
            if len(g) > 1
        ),
        evaluations=evaluations,
        cache_hits=cache.hits - hits0,
        cache_misses=cache.misses - misses0,
        wall_s=time.perf_counter() - wall0,
    )
