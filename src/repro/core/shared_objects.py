"""Shared Objects strategies (paper §4).

Each intermediate tensor is assigned exactly one *shared object* (reusable
buffer). No two tensors with intersecting usage intervals may share an
object; an object's size is the max of its assigned tensor sizes. Objective:
minimize the total size of all shared objects.

Three strategies from the paper:
* ``greedy_by_breadth``      — §4.2, Algorithm 1
* ``greedy_by_size``         — §4.3, Algorithm 2
* ``greedy_by_size_improved``— §4.4 (staged by positional maximums +
  smallest-gap pairing inside a stage)

All return a :class:`SharedObjectsAssignment`.

Complexity: the paper's naive formulation is O(k·n²). Here every
per-object overlap/gap query goes through
:class:`repro.core.interval_set.DisjointIntervalSet` (one ``bisect``, the
paper's "interval tree" refinement made exact: an object's intervals are
disjoint, so only the query's immediate neighbor can conflict) and object
*selection* walks a pool kept sorted by ``(size, object_id)`` instead of
scanning every object. Results are byte-identical to the frozen oracle in
:mod:`repro.core.reference` — tie-breaking is preserved exactly — which
``tests/test_differential_planner.py`` enforces.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Callable, Sequence

from repro.core.interval_set import DisjointIntervalSet
from repro.core.records import (
    TensorUsageRecord,
    operator_breadths,
    operator_profiles,
    positional_maximums,
)


@dataclasses.dataclass
class SharedObject:
    object_id: int
    size: int
    interval_set: DisjointIntervalSet = dataclasses.field(
        default_factory=DisjointIntervalSet
    )

    @property
    def intervals(self) -> list[tuple[int, int, int]]:
        """Assigned (first_op, last_op, tensor_id), sorted by first_op."""
        return list(self.interval_set)

    def fits(self, rec: TensorUsageRecord) -> bool:
        """True iff ``rec``'s interval intersects no assigned interval."""
        return not self.interval_set.overlaps(rec.first_op, rec.last_op)

    def assign(self, rec: TensorUsageRecord) -> None:
        self.interval_set.add(rec.first_op, rec.last_op, rec.tensor_id)
        if rec.size > self.size:
            self.size = rec.size

    def gap_to(self, rec: TensorUsageRecord) -> int:
        """Smallest idle gap this object would have right before/after
        ``rec``'s interval (paper §4.4's pairing criterion). Infinite-ish if
        the object is empty."""
        return self.interval_set.smallest_gap(rec.first_op, rec.last_op)


@dataclasses.dataclass
class SharedObjectsAssignment:
    strategy: str
    objects: list[SharedObject]
    # tensor_id -> object_id
    assignment: dict[int, int]

    @property
    def total_size(self) -> int:
        return sum(o.size for o in self.objects)

    def object_of(self, tensor_id: int) -> SharedObject:
        return self.objects[self.assignment[tensor_id]]


def _new_assignment(strategy: str) -> SharedObjectsAssignment:
    return SharedObjectsAssignment(strategy=strategy, objects=[], assignment={})


def _create_object(asn: SharedObjectsAssignment, rec: TensorUsageRecord) -> SharedObject:
    obj = SharedObject(object_id=len(asn.objects), size=rec.size)
    asn.objects.append(obj)
    return obj


class _ObjectPool:
    """Objects kept sorted ascending by ``(size, object_id)``.

    Selection rules become ordered scans from a bisect point instead of
    full sweeps; the scan still stops at the first *fitting* object, so the
    worst case matches the naive loop but the common case touches O(1)
    objects after an O(log k) bisect.
    """

    __slots__ = ("_keys", "_objs")

    def __init__(self) -> None:
        self._keys: list[tuple[int, int]] = []
        self._objs: list[SharedObject] = []

    def add(self, obj: SharedObject) -> None:
        k = (obj.size, obj.object_id)
        i = bisect.bisect_left(self._keys, k)
        self._keys.insert(i, k)
        self._objs.insert(i, obj)

    def remove(self, obj: SharedObject) -> None:
        i = bisect.bisect_left(self._keys, (obj.size, obj.object_id))
        del self._keys[i]
        del self._objs[i]

    def smallest_fitting(self, rec: TensorUsageRecord) -> SharedObject | None:
        """Smallest (then lowest-id) object with size >= rec.size that fits
        — the Greedy-by-Size selection (all pool sizes >= rec.size there)
        and the first branch of Greedy-by-Breadth's ``is_better``."""
        start = bisect.bisect_left(self._keys, (rec.size, -1))
        for i in range(start, len(self._objs)):
            if self._objs[i].fits(rec):
                return self._objs[i]
        return None

    def largest_smaller_fitting(self, rec: TensorUsageRecord) -> SharedObject | None:
        """Largest (then lowest-id) object with size < rec.size that fits —
        Greedy-by-Breadth's grow-the-biggest fallback branch."""
        i = bisect.bisect_left(self._keys, (rec.size, -1)) - 1
        while i >= 0:
            if self._objs[i].fits(rec):
                best = self._objs[i]
                # equal-size ties break on LOWEST object id (the oracle
                # scans ids ascending and only replaces on strictly-larger
                # size); walk the tie run down to find it
                j = i - 1
                while j >= 0 and self._objs[j].size == best.size:
                    if self._objs[j].fits(rec):
                        best = self._objs[j]
                    j -= 1
                return best
            i -= 1
        return None


def _pool_select_is_better(
    asn: SharedObjectsAssignment, pool: _ObjectPool, rec: TensorUsageRecord
) -> SharedObject:
    """The paper's ``is_better`` object choice (§4.2 L.11–17) with pool
    bookkeeping: smallest fitting object >= size_t, else grow the largest
    smaller one, else create. Shared by greedy_by_breadth and
    extensions.greedy_by_conflict — the tie-break contract with the frozen
    oracle lives in exactly one place."""
    best = pool.smallest_fitting(rec)
    if best is not None:
        best.assign(rec)
        return best
    best = pool.largest_smaller_fitting(rec)
    if best is None:
        best = _create_object(asn, rec)
        best.assign(rec)
    else:
        pool.remove(best)  # assign() below may grow its size
        best.assign(rec)
    pool.add(best)
    return best


def greedy_by_size(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.3, Algorithm 2.

    Tensors in non-increasing size order; assign the smallest suitable
    object (all suitable objects are >= size_t since sizes are
    non-increasing); create a new object if none is suitable.
    """
    asn = _new_assignment("greedy_by_size")
    pool = _ObjectPool()
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        best = pool.smallest_fitting(rec)
        if best is None:
            best = _create_object(asn, rec)
            pool.add(best)
        # sizes arrive non-increasing, so assign() never grows an object
        # here and the pool order stays valid
        best.assign(rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


def greedy_by_breadth(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.2, Algorithm 1.

    Operators in non-increasing breadth order; within each operator's
    profile, unassigned tensors largest-first. Object choice (paper's
    ``is_better`` logic, L.11–17):
      * prefer suitable objects with size >= size_t, smallest such;
      * else (all suitable objects smaller) take the largest and grow it;
      * else create a new object.
    """
    asn = _new_assignment("greedy_by_breadth")
    pool = _ObjectPool()
    breadths = operator_breadths(records)
    profiles = operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:  # already sorted by size desc
            if rec.tensor_id in asn.assignment:
                continue
            best = _pool_select_is_better(asn, pool, rec)
            asn.assignment[rec.tensor_id] = best.object_id
    return asn


def _stages_by_positional_maximums(
    records: Sequence[TensorUsageRecord],
) -> list[list[TensorUsageRecord]]:
    """Split records into stages (paper §4.4): stage boundaries at the
    distinct positional-maximum values, descending. Stage 2i collects
    tensors with size == pm_i; stage 2i+1 those with pm_{i+1} < size < pm_i.
    (Equivalently: group by the interval of pm values the size falls in.)

    One pointer walk over the size-descending record order — every record
    size is <= pm_0 (each tensor is live somewhere, so the global maximum
    size IS a positional maximum), so peeling the == and the in-between
    runs per pm is an exact linear merge of the oracle's per-pm filters.
    """
    pms = sorted(set(positional_maximums(records)), reverse=True)
    recs = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    stages: list[list[TensorUsageRecord]] = []
    i, n = 0, len(recs)
    for k, pm in enumerate(pms):
        eq: list[TensorUsageRecord] = []
        while i < n and recs[i].size == pm:
            eq.append(recs[i])
            i += 1
        if eq:
            stages.append(eq)
        lo = pms[k + 1] if k + 1 < len(pms) else 0
        mid: list[TensorUsageRecord] = []
        while i < n and lo < recs[i].size < pm:
            mid.append(recs[i])
            i += 1
        if mid:
            stages.append(mid)
    return stages


def greedy_by_size_improved(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.4: Greedy-by-Size staged by positional maximums; inside a
    stage, repeatedly pick the (tensor, suitable object) pair with the
    smallest idle gap; tensors with no suitable object get new objects
    last (largest first).

    The paper claims the improvements give "better or the same result"
    than plain Greedy-by-Size; staging is a heuristic, so we guarantee the
    claim by construction: return whichever of (staged, plain) is smaller.
    """
    staged = _greedy_by_size_improved_staged(records)
    plain = greedy_by_size(records)
    if plain.total_size < staged.total_size:
        plain = SharedObjectsAssignment(
            strategy="greedy_by_size_improved",
            objects=plain.objects,
            assignment=plain.assignment,
        )
        return plain
    return staged


def _greedy_by_size_improved_staged(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    asn = _new_assignment("greedy_by_size_improved")
    for stage in _stages_by_positional_maximums(records):
        _assign_stage_pairs(asn, stage)
    return asn


def _assign_stage_pairs(
    asn: SharedObjectsAssignment, stage: list[TensorUsageRecord]
) -> None:
    """One §4.4 stage through a lazily-invalidated min-heap of
    (gap, pending rank, object id) pairs instead of the oracle's full
    (pending x objects) rescan per assignment.

    Why the heap order IS the oracle's tie-break: the oracle scans pending
    in list order and ``asn.objects`` in id order, replacing only on a
    strictly smaller gap — its pick is the lexicographic minimum over
    (gap, pending position, object id). The stage list arrives sorted by
    ``(-size, first_op, tensor_id)`` and the oracle's in-stage re-sort
    uses the same key (a stable no-op), so pending position == rank in
    ``stage``, and the heap's tuple order reproduces the pick exactly.

    Why lazy invalidation is sound: an object only ever GAINS intervals,
    so a pair's gap is non-increasing over a stage (and ``fits`` never
    flips back to True). Every gap change is caused by an insertion into
    the pair's enclosing idle window, and each insertion re-pushes exact
    entries for exactly the pending records inside the two windows it
    split — so every live pair always has one exact entry queued, stale
    entries are strictly gap-high, and a popped entry whose stored gap no
    longer matches can be discarded outright.
    """
    n = len(stage)
    if not n:
        return
    alive = [True] * n
    n_alive = n
    # window index: ranks ordered by first_op (ties by rank), so "pending
    # records fully inside an idle window" is one bisect + a bounded scan
    by_first = sorted(range(n), key=lambda r: (stage[r].first_op, r))
    first_keys = [stage[r].first_op for r in by_first]
    heap: list[tuple[int, int, int]] = []
    objs = asn.objects

    def push_window(obj: SharedObject, lo: int, hi: int) -> None:
        # exact-gap entries for every alive record fully inside the open
        # idle window (lo, hi) of ``obj`` (sentinel-bounded at the edges)
        i = bisect.bisect_right(first_keys, lo)
        oid = obj.object_id
        while i < len(first_keys) and first_keys[i] < hi:
            r = by_first[i]
            if alive[r]:
                rec = stage[r]
                if rec.last_op < hi:
                    heapq.heappush(heap, (obj.gap_to(rec), r, oid))
            i += 1

    if objs:
        # Seed pairs against the objects earlier stages built. Two exact
        # enumerations of the same fitting pairs — pick the cheaper side:
        # rec-major probes every (rec, object) once; window-major walks
        # each object's idle windows (better when objects carry many
        # intervals and the stage is small).
        n_windows = sum(len(o.interval_set) + 1 for o in objs)
        if n * len(objs) <= n_windows:
            for r in range(n):
                rec = stage[r]
                for obj in objs:
                    if obj.fits(rec):
                        heapq.heappush(
                            heap, (obj.gap_to(rec), r, obj.object_id)
                        )
        else:
            for obj in objs:
                lo = -(1 << 60)
                for start, end, _ in obj.interval_set:
                    push_window(obj, lo, start)
                    lo = end
                push_window(obj, lo, 1 << 60)

    rank_ptr = 0  # lowest possibly-alive rank (ranks die monotonically
    #               under the no-pair branch; heap picks can skip around)
    while n_alive:
        picked: tuple[int, SharedObject] | None = None
        while heap:
            gap, r, oid = heapq.heappop(heap)
            if not alive[r]:
                continue
            obj = objs[oid]
            rec = stage[r]
            if not obj.fits(rec):
                continue
            if obj.gap_to(rec) != gap:
                continue  # stale-high; the exact entry is still queued
            picked = (r, obj)
            break
        if picked is None:
            # No suitable existing object for any pending tensor: open a
            # new object for the largest pending tensor (== lowest alive
            # rank), then resume pairing (remaining tensors may fit it).
            while not alive[rank_ptr]:
                rank_ptr += 1
            r = rank_ptr
            rec = stage[r]
            obj = _create_object(asn, rec)
        else:
            r, obj = picked
            rec = stage[r]
        obj.assign(rec)
        asn.assignment[rec.tensor_id] = obj.object_id
        alive[r] = False
        n_alive -= 1
        if n_alive:
            lo, hi = obj.interval_set.neighbors(rec.first_op, rec.last_op)
            push_window(obj, lo, rec.first_op)
            push_window(obj, rec.last_op, hi)


def from_slot_log(
    slot_log: Sequence[tuple[int, int, int, int]],
    *,
    n_slots: int | None = None,
    slot_size: int = 1,
    state_plan=None,
) -> SharedObjectsAssignment:
    """Build the §4-style assignment from a serving slot log
    (``(slot, first_wave, last_wave, request_id)`` tuples, as recorded by
    the engine): slots are the shared objects, requests the tensors, the
    decode wave the operator index. Raises ``ValueError`` if two requests
    overlap on one slot — this is the runtime audit of the cross-step
    :class:`~repro.core.unified.StatePlan`'s shared-objects claim.

    Pass ``state_plan`` to audit against the plan the engine actually
    serves from — ``n_slots`` and ``slot_size`` then come from the plan's
    own slot regions (bucket auto-selection may serve a wider pool than a
    caller requested, so deriving them from the plan is the only
    assignment that cannot disagree with the live layout)."""
    if state_plan is not None:
        n_slots = state_plan.n_slots
        slot_size = state_plan.bytes_per_slot
    if n_slots is None:
        raise ValueError("from_slot_log needs n_slots or a state_plan")
    asn = SharedObjectsAssignment(
        strategy="slot_log",
        objects=[SharedObject(object_id=s, size=slot_size) for s in range(n_slots)],
        assignment={},
    )
    for slot, first, last, rid in slot_log:
        if not 0 <= slot < n_slots:
            raise ValueError(f"request {rid}: slot {slot} outside [0, {n_slots})")
        obj = asn.objects[slot]
        # closed wave intervals; the engine frees a slot at the END of its
        # finishing wave and admits at the start of the next, so legal
        # hand-offs never share a wave and plain overlap is a violation
        if obj.interval_set.overlaps(first, last):
            raise ValueError(
                f"request {rid}: interval [{first}, {last}] overlaps an "
                f"earlier request on slot {slot}"
            )
        obj.interval_set.add(first, last, rid)
        asn.assignment[rid] = slot
    return asn


def from_page_log(
    page_log: Sequence[tuple[int, int, int, int]],
    *,
    n_pages: int | None = None,
    page_size: int = 1,
    state_plan=None,
) -> SharedObjectsAssignment:
    """Build the page-granular §4-style assignment from a serving page
    log (``(page, first_wave, last_wave, request_id)`` tuples, as
    recorded by the paged state backend): POOL PAGES are the shared
    objects, request-page holds the tensors, the decode wave the
    operator index. The twin of :func:`from_slot_log` one level down —
    it proves no page served two requests at overlapping waves, i.e.
    the runtime page allocator never double-assigned a live page.

    Pass ``state_plan`` (a :class:`~repro.core.unified.PagedStatePlan`)
    to derive ``n_pages``/``page_size`` from the plan the engine
    actually serves from. Physical page indices are 1-based (0 is the
    reserved null page, which is never allocated and must never appear
    in a log). Assignment keys are ``(request_id, page)`` — one request
    legitimately holds many pages."""
    if state_plan is not None:
        n_pages = state_plan.n_pages_pool
        page_size = state_plan.page_size
    if n_pages is None:
        raise ValueError("from_page_log needs n_pages or a paged state_plan")
    asn = SharedObjectsAssignment(
        strategy="page_log",
        objects=[
            SharedObject(object_id=p, size=page_size)
            for p in range(1, n_pages + 1)
        ],
        assignment={},
    )
    by_id = {obj.object_id: obj for obj in asn.objects}
    for page, first, last, rid in page_log:
        obj = by_id.get(page)
        if obj is None:
            raise ValueError(
                f"request {rid}: page {page} outside the pool [1, {n_pages}]"
                + (" (0 is the reserved null page)" if page == 0 else "")
            )
        # closed wave intervals, same hand-off rule as from_slot_log:
        # freed at the END of the finishing wave, reallocatable at the
        # start of the next — sharing a wave is a double assignment
        if obj.interval_set.overlaps(first, last):
            raise ValueError(
                f"request {rid}: interval [{first}, {last}] overlaps an "
                f"earlier occupant on page {page}"
            )
        obj.interval_set.add(first, last, rid)
        asn.assignment[(rid, page)] = page
    return asn


STRATEGIES: dict[str, Callable[[Sequence[TensorUsageRecord]], SharedObjectsAssignment]] = {
    "greedy_by_size": greedy_by_size,
    "greedy_by_size_improved": greedy_by_size_improved,
    "greedy_by_breadth": greedy_by_breadth,
}
