"""Shared Objects strategies (paper §4).

Each intermediate tensor is assigned exactly one *shared object* (reusable
buffer). No two tensors with intersecting usage intervals may share an
object; an object's size is the max of its assigned tensor sizes. Objective:
minimize the total size of all shared objects.

Three strategies from the paper:
* ``greedy_by_breadth``      — §4.2, Algorithm 1
* ``greedy_by_size``         — §4.3, Algorithm 2
* ``greedy_by_size_improved``— §4.4 (staged by positional maximums +
  smallest-gap pairing inside a stage)

All return a :class:`SharedObjectsAssignment`.

Complexity: the naive inner loop over all records per (tensor, object) pair
is the paper's O(k·n²). We keep per-object interval lists sorted by
``first_op`` and binary-search the neighborhood, which is the paper's
"interval tree" refinement giving O(k·n·log n) in practice.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Sequence

from repro.core.records import (
    TensorUsageRecord,
    operator_breadths,
    operator_profiles,
    positional_maximums,
)


@dataclasses.dataclass
class SharedObject:
    object_id: int
    size: int
    # intervals sorted by first_op: (first_op, last_op, tensor_id)
    intervals: list[tuple[int, int, int]] = dataclasses.field(default_factory=list)

    def fits(self, rec: TensorUsageRecord) -> bool:
        """True iff ``rec``'s interval intersects no assigned interval."""
        starts = [iv[0] for iv in self.intervals]
        idx = bisect.bisect_right(starts, rec.last_op)
        # Any interval starting after rec.last_op cannot overlap. Intervals
        # before idx start at or before rec.last_op; they overlap iff their
        # last_op >= rec.first_op. Check those — but we can't binary search
        # on last_op (not sorted), so walk left. In DNN graphs intervals are
        # short, so this neighborhood walk is effectively O(log n + overlap).
        for i in range(idx - 1, -1, -1):
            f, l, _ = self.intervals[i]
            if l >= rec.first_op:
                return False
            # Cannot early-break on f alone (last_ops are unsorted), keep
            # walking; in practice assigned intervals rarely nest deeply.
        return True

    def assign(self, rec: TensorUsageRecord) -> None:
        starts = [iv[0] for iv in self.intervals]
        idx = bisect.bisect_left(starts, rec.first_op)
        self.intervals.insert(idx, (rec.first_op, rec.last_op, rec.tensor_id))
        self.size = max(self.size, rec.size)

    def gap_to(self, rec: TensorUsageRecord) -> int:
        """Smallest idle gap this object would have right before/after
        ``rec``'s interval (paper §4.4's pairing criterion). Infinite-ish if
        the object is empty."""
        if not self.intervals:
            return 1 << 60
        best = 1 << 60
        for f, l, _ in self.intervals:
            if l < rec.first_op:
                best = min(best, rec.first_op - l - 1)
            elif f > rec.last_op:
                best = min(best, f - rec.last_op - 1)
        return best


@dataclasses.dataclass
class SharedObjectsAssignment:
    strategy: str
    objects: list[SharedObject]
    # tensor_id -> object_id
    assignment: dict[int, int]

    @property
    def total_size(self) -> int:
        return sum(o.size for o in self.objects)

    def object_of(self, tensor_id: int) -> SharedObject:
        return self.objects[self.assignment[tensor_id]]


def _new_assignment(strategy: str) -> SharedObjectsAssignment:
    return SharedObjectsAssignment(strategy=strategy, objects=[], assignment={})


def _create_object(asn: SharedObjectsAssignment, rec: TensorUsageRecord) -> SharedObject:
    obj = SharedObject(object_id=len(asn.objects), size=rec.size)
    asn.objects.append(obj)
    return obj


def greedy_by_size(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.3, Algorithm 2.

    Tensors in non-increasing size order; assign the smallest suitable
    object (all suitable objects are >= size_t since sizes are
    non-increasing); create a new object if none is suitable.
    """
    asn = _new_assignment("greedy_by_size")
    order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    for rec in order:
        best: SharedObject | None = None
        for obj in asn.objects:
            if obj.fits(rec) and (best is None or obj.size < best.size):
                best = obj
        if best is None:
            best = _create_object(asn, rec)
        best.assign(rec)
        asn.assignment[rec.tensor_id] = best.object_id
    return asn


def greedy_by_breadth(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.2, Algorithm 1.

    Operators in non-increasing breadth order; within each operator's
    profile, unassigned tensors largest-first. Object choice (paper's
    ``is_better`` logic, L.11–17):
      * prefer suitable objects with size >= size_t, smallest such;
      * else (all suitable objects smaller) take the largest and grow it;
      * else create a new object.
    """
    asn = _new_assignment("greedy_by_breadth")
    breadths = operator_breadths(records)
    profiles = operator_profiles(records)
    op_order = sorted(range(len(breadths)), key=lambda i: (-breadths[i], i))
    for op_idx in op_order:
        for rec in profiles[op_idx]:  # already sorted by size desc
            if rec.tensor_id in asn.assignment:
                continue
            best: SharedObject | None = None
            for obj in asn.objects:
                if not obj.fits(rec):
                    continue
                if best is None:
                    best = obj
                    continue
                if best.size < rec.size:
                    # best is too small: prefer larger objects (less growth)
                    if obj.size > best.size:
                        best = obj
                else:
                    # best already fits rec: prefer the smallest that fits
                    if rec.size <= obj.size < best.size:
                        best = obj
            if best is None:
                best = _create_object(asn, rec)
            best.assign(rec)
            asn.assignment[rec.tensor_id] = best.object_id
    return asn


def _stages_by_positional_maximums(
    records: Sequence[TensorUsageRecord],
) -> list[list[TensorUsageRecord]]:
    """Split records into stages (paper §4.4): stage boundaries at the
    distinct positional-maximum values, descending. Stage 2i collects
    tensors with size == pm_i; stage 2i+1 those with pm_{i+1} < size < pm_i.
    (Equivalently: group by the interval of pm values the size falls in.)
    """
    pms = sorted(set(positional_maximums(records)), reverse=True)
    recs = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
    stages: list[list[TensorUsageRecord]] = []
    for i, pm in enumerate(pms):
        eq = [r for r in recs if r.size == pm]
        if eq:
            stages.append(eq)
        lo = pms[i + 1] if i + 1 < len(pms) else 0
        mid = [r for r in recs if lo < r.size < pm]
        if mid:
            stages.append(mid)
    return stages


def greedy_by_size_improved(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    """Paper §4.4: Greedy-by-Size staged by positional maximums; inside a
    stage, repeatedly pick the (tensor, suitable object) pair with the
    smallest idle gap; tensors with no suitable object get new objects
    last (largest first).

    The paper claims the improvements give "better or the same result"
    than plain Greedy-by-Size; staging is a heuristic, so we guarantee the
    claim by construction: return whichever of (staged, plain) is smaller.
    """
    staged = _greedy_by_size_improved_staged(records)
    plain = greedy_by_size(records)
    if plain.total_size < staged.total_size:
        plain = SharedObjectsAssignment(
            strategy="greedy_by_size_improved",
            objects=plain.objects,
            assignment=plain.assignment,
        )
        return plain
    return staged


def _greedy_by_size_improved_staged(
    records: Sequence[TensorUsageRecord],
) -> SharedObjectsAssignment:
    asn = _new_assignment("greedy_by_size_improved")
    for stage in _stages_by_positional_maximums(records):
        pending = list(stage)
        while pending:
            best_pair: tuple[int, TensorUsageRecord, SharedObject] | None = None
            for rec in pending:
                for obj in asn.objects:
                    # Same suitability as greedy_by_size plus: within a
                    # stage sizes are ~equal, but we must never shrink an
                    # object below an assigned tensor — growing is fine.
                    if not obj.fits(rec):
                        continue
                    gap = obj.gap_to(rec)
                    if best_pair is None or gap < best_pair[0]:
                        best_pair = (gap, rec, obj)
            if best_pair is None:
                # No suitable existing object for any pending tensor:
                # open a new object for the largest pending tensor, then
                # resume pairing (remaining tensors may now fit it).
                pending.sort(key=lambda r: (-r.size, r.first_op, r.tensor_id))
                rec = pending.pop(0)
                obj = _create_object(asn, rec)
                obj.assign(rec)
                asn.assignment[rec.tensor_id] = obj.object_id
            else:
                _, rec, obj = best_pair
                obj.assign(rec)
                asn.assignment[rec.tensor_id] = obj.object_id
                pending.remove(rec)
    return asn


STRATEGIES: dict[str, Callable[[Sequence[TensorUsageRecord]], SharedObjectsAssignment]] = {
    "greedy_by_size": greedy_by_size,
    "greedy_by_size_improved": greedy_by_size_improved,
    "greedy_by_breadth": greedy_by_breadth,
}
