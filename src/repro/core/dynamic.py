"""Dynamic tensor sizes — the paper's §7 protocol, implemented.

    "For such cases, the algorithms need to be run multiple times saving
    information about allocation from all runs in one place. The first run
    will allocate only those tensors whose sizes are known at the
    beginning, and the second run will allocate those tensors whose sizes
    become known after calculation of the first dynamic tensor, etc."

``IncrementalPlanner`` keeps one shared arena across planning *stages*:
stage 0 plans the statically-known records; each later ``extend()`` call
plans newly-known records with every earlier placement FIXED, using the
same best-fit-gap rule as Greedy-by-Size (records within a stage are
taken size-descending). The arena only ever grows; earlier offsets are
never moved (an inference engine cannot relocate live buffers).

Typical use (RNN / dynamic-length decoding): ``extend()`` once per shape
resolution point, then materialize a single ``Arena`` of ``total_size``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.interval_set import BestFitArena
from repro.core.offsets import OffsetAssignment
from repro.core.records import TensorUsageRecord, naive_consumption


@dataclasses.dataclass
class IncrementalPlanner:
    _arena: BestFitArena = dataclasses.field(default_factory=BestFitArena)
    _allocated: list[TensorUsageRecord] = dataclasses.field(default_factory=list)
    n_stages: int = 0

    @property
    def offsets(self) -> dict[int, int]:
        return self._arena.offsets

    @property
    def total_size(self) -> int:
        return self._arena.total

    def extend(self, records: Sequence[TensorUsageRecord]) -> None:
        """Plan a newly-known batch of records against the fixed layout."""
        self.n_stages += 1
        order = sorted(records, key=lambda r: (-r.size, r.first_op, r.tensor_id))
        for rec in order:
            if rec.tensor_id in self._arena.offsets:
                raise ValueError(f"tensor {rec.tensor_id} already planned")
            self._arena.place(rec)
            self._allocated.append(rec)

    def as_assignment(self) -> OffsetAssignment:
        return OffsetAssignment(
            f"incremental[{self.n_stages} stages]",
            dict(self.offsets),
            self.total_size,
        )

    @property
    def records(self) -> list[TensorUsageRecord]:
        return list(self._allocated)

    def overhead_vs_oneshot(self) -> float:
        """How much the staging cost vs planning everything at once."""
        from repro.core.offsets import greedy_by_size_offsets

        oneshot = greedy_by_size_offsets(self._allocated).total_size
        return self.total_size / max(oneshot, 1)
