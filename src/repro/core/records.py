"""Tensor usage records, operator profiles and lower bounds (paper §3–§5.1).

The paper's vocabulary, verbatim:

* **Tensor usage interval** of intermediate tensor ``t``:
  ``{first_op_t, last_op_t}`` — indices of the first and last operator (in
  the fixed topological execution order) that use ``t`` as input or output.
* **Tensor usage record**: ``{first_op_t, last_op_t, size_t}`` with
  ``size_t`` the aligned size in bytes.
* **Operator profile** of operator ``op``: all records whose interval
  contains ``op``.
* **Operator breadth**: sum of tensor sizes in its profile.
* **i-th positional maximum**: max over operators of the i-th largest
  tensor size in each profile.

Lower bounds:
* Shared Objects LB = sum of positional maximums (paper §4.1).
* Offset Calculation LB = max operator breadth (paper §5.1).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

DEFAULT_ALIGNMENT = 64  # bytes; TFLite's default, matches the paper's tables


def align(size: int, alignment: int = DEFAULT_ALIGNMENT) -> int:
    """Round ``size`` up to a multiple of ``alignment``."""
    if alignment <= 0:
        raise ValueError(f"alignment must be positive, got {alignment}")
    return -(-size // alignment) * alignment


@dataclasses.dataclass(frozen=True, order=True)
class TensorUsageRecord:
    """One intermediate tensor's lifetime + aligned byte size.

    ``tensor_id`` identifies the tensor in the source graph. Ordering
    (via ``order=True``) is only used for deterministic tie-breaking.
    """

    first_op: int
    last_op: int
    size: int
    tensor_id: int = 0

    def __post_init__(self) -> None:
        if self.first_op < 0 or self.last_op < self.first_op:
            raise ValueError(
                f"invalid usage interval [{self.first_op}, {self.last_op}]"
            )
        if self.size <= 0:
            raise ValueError(f"tensor size must be positive, got {self.size}")

    def overlaps(self, other: "TensorUsageRecord") -> bool:
        """True iff the two usage intervals intersect (closed intervals)."""
        return max(self.first_op, other.first_op) <= min(
            self.last_op, other.last_op
        )


def records_overlap(a: TensorUsageRecord, b: TensorUsageRecord) -> bool:
    return a.overlaps(b)


def num_operators(records: Sequence[TensorUsageRecord]) -> int:
    return 0 if not records else 1 + max(r.last_op for r in records)


def operator_profiles(
    records: Sequence[TensorUsageRecord],
) -> list[list[TensorUsageRecord]]:
    """profiles[i] = all records live at operator i, sorted by size desc.

    Sorting in non-increasing size order is how the paper defines the
    profiles used for positional maximums (Fig. 2b).
    """
    n_ops = num_operators(records)
    profiles: list[list[TensorUsageRecord]] = [[] for _ in range(n_ops)]
    for r in records:
        for op in range(r.first_op, r.last_op + 1):
            profiles[op].append(r)
    for p in profiles:
        p.sort(key=lambda r: (-r.size, r.tensor_id))
    return profiles


def operator_breadths(records: Sequence[TensorUsageRecord]) -> list[int]:
    """breadths[i] = sum of live tensor sizes at operator i.

    Event sweep (difference array + prefix sum): O(n + n_ops) instead of
    walking every record's full interval.
    """
    n_ops = num_operators(records)
    delta = [0] * (n_ops + 1)
    for r in records:
        delta[r.first_op] += r.size
        delta[r.last_op + 1] -= r.size
    breadths = [0] * n_ops
    acc = 0
    for i in range(n_ops):
        acc += delta[i]
        breadths[i] = acc
    return breadths


def positional_maximums(records: Sequence[TensorUsageRecord]) -> list[int]:
    """pm[i] = max over operator profiles of the i-th largest live size."""
    profiles = operator_profiles(records)
    depth = max((len(p) for p in profiles), default=0)
    out = []
    for i in range(depth):
        out.append(max(p[i].size for p in profiles if len(p) > i))
    return out


def shared_objects_lower_bound(records: Sequence[TensorUsageRecord]) -> int:
    """Paper §4.1: sum of positional maximums."""
    return sum(positional_maximums(records))


def offsets_lower_bound(records: Sequence[TensorUsageRecord]) -> int:
    """Paper §5.1: maximum operator breadth."""
    return max(operator_breadths(records), default=0)


def naive_consumption(records: Sequence[TensorUsageRecord]) -> int:
    """The paper's 'Naive' baseline: every intermediate co-resident."""
    return sum(r.size for r in records)


def make_records(
    triples: Iterable[tuple[int, int, int]],
) -> list[TensorUsageRecord]:
    """Convenience: build records from (first_op, last_op, size) triples."""
    return [
        TensorUsageRecord(first_op=f, last_op=l, size=s, tensor_id=i)
        for i, (f, l, s) in enumerate(triples)
    ]
