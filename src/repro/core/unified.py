"""Unified planning facade: one ``plan()`` over both of the paper's halves.

The paper poses two memory-planning problems that this repo used to solve
through disjoint code paths with incompatible inputs and outputs:

* **intra-step activation sharing** — Offset Calculation / Shared Objects
  over tensor usage records of one decode step (§4–§5, ``core/planner``);
* **cross-step shared-objects state** — per-slot KV caches and decode
  buffers reused across requests, §4 applied *above* the XLA level where
  slots are the shared objects and requests are the tensors
  (``core/shared_objects``, audited by the engine's slot log).

This module joins them under one API:

* :class:`PlanSpec` — everything a planning request is made of: the
  activation graph (or raw usage records), the cross-step
  :class:`StateRecord` set, the strategy/search knobs, and the bucket
  identity (config, ``n_slots``, ``max_len``);
* :func:`plan` — ``repro.core.plan(spec) -> UnifiedPlan``: plans the
  activation half (optionally through the memory-aware order/fusion
  search), lays out the cross-step state half, and returns both under one
  fingerprint and one ``total_size``;
* :class:`StatePlan` — the slot/KV shared-objects layout with concrete
  byte offsets: ``n_slots`` symmetric slot regions, each packing the
  per-slot share of every state leaf (size-descending, aligned), so a
  serving process can account for — and materialize — the cross-step
  arena without touching a model;
* :class:`PlanSession` — the single plan *source* an
  :class:`~repro.runtime.engine.InferenceEngine` consumes: a bundle
  manifest (``from_manifest``, with nearest-bucket selection), one bundle
  (``from_bundle``), or a spec planned on demand (``from_spec``).

``planner.plan_records``/``planner.plan_graph`` are thin wrappers over
:func:`plan`; the strategy implementations themselves still live in
``core/planner``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from pathlib import Path
from typing import TYPE_CHECKING, Any, Sequence

from repro.core import plan_io
from repro.core.records import DEFAULT_ALIGNMENT, TensorUsageRecord, align

if TYPE_CHECKING:  # keep this module importable without jax
    from repro.configs.base import ArchConfig
    from repro.core.artifact import PlanBundle
    from repro.core.graph import Graph
    from repro.core.planner import MemoryPlan
    from repro.core.fusion_search import FusionSearchResult
    from repro.core.order_search import OrderSearchResult
    from repro.runtime.arena import ArenaLayout

# Instrumentation: total state-plan constructions this process. A
# bundle-served engine must not lay out the cross-step state either —
# tests snapshot this next to planner.PLAN_CALLS / tracer.TRACE_CALLS.
STATE_PLAN_CALLS = 0

STATE_STRATEGY = "slots_as_shared_objects"


# ------------------------------------------------------- cross-step state


@dataclasses.dataclass(frozen=True)
class StateRecord:
    """One cross-step state tensor (a cache-pytree leaf): its identity and
    full (all-slot) byte size. The per-slot share is ``nbytes / n_slots``
    — every leaf carries the slot batch dimension, so the division is
    exact (checked by :func:`plan_state`)."""

    path: str  # pytree key path, e.g. "['period'][0]['kv'][1]"
    shape: tuple[int, ...]
    dtype: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class StateLeaf:
    """A :class:`StateRecord` placed inside one slot region: aligned
    per-slot byte size + concrete offset within the slot."""

    path: str
    shape: tuple[int, ...]
    dtype: str
    slot_nbytes: int  # aligned per-slot bytes
    offset: int  # byte offset within a slot region


@dataclasses.dataclass(frozen=True)
class LeafView:
    """One (slot, leaf) cell of the state arena, fully addressed: where
    its bytes live (``offset``), how many are payload (``used_nbytes``,
    the unaligned per-slot share) and how many are reserved
    (``slot_nbytes``, the aligned bounds-contract size). This is THE leaf
    addressing unit shared by every arena implementation — the numpy
    :class:`~repro.runtime.arena.Arena`, the jax
    :class:`~repro.runtime.arena.DeviceArena`, and the residency
    pack/unpack views are all built from :meth:`StatePlan.leaf_view_spec`.
    """

    tensor_id: int  # dense: slot * n_leaves + leaf_index
    slot: int
    leaf_index: int
    path: str
    dtype: str
    offset: int  # absolute byte offset in the state buffer
    used_nbytes: int  # payload bytes of the per-slot share (unaligned)
    slot_nbytes: int  # planned slot bytes (aligned; bounds enforcement)


@dataclasses.dataclass
class StatePlan:
    """Slot/KV shared-objects layout with concrete offsets (paper §4 at
    the request level). ``n_slots`` identical slot regions of
    ``slot_stride`` bytes; leaf ``l`` of slot ``s`` lives at
    ``s * slot_stride + leaves[l].offset``. Slots are the shared objects:
    an object's size is the full per-slot state, and request→slot
    assignment happens at serving time (the engine's slot log is the
    §4-style audit, see :func:`repro.core.shared_objects.from_slot_log`).
    """

    n_slots: int
    max_len: int
    alignment: int
    leaves: list[StateLeaf]
    slot_stride: int
    total_size: int
    strategy: str = STATE_STRATEGY

    @property
    def bytes_per_slot(self) -> int:
        return self.slot_stride

    def offset_of(self, slot: int, path: str) -> int:
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} outside [0, {self.n_slots})")
        for leaf in self.leaves:
            if leaf.path == path:
                return slot * self.slot_stride + leaf.offset
        raise KeyError(f"no state leaf at path {path!r}")

    def flat_entries(self) -> list[tuple[int, int, StateLeaf, int]]:
        """(tensor_id, slot, leaf, absolute_offset) tuple view over
        :meth:`leaf_view_spec` — same cells, legacy tuple shape."""
        return [
            (v.tensor_id, v.slot, self.leaves[v.leaf_index], v.offset)
            for v in self.leaf_view_spec()
        ]

    def leaf_view_spec(self) -> "list[LeafView]":
        """The leaf addressing API: one :class:`LeafView` per (slot, leaf)
        cell, with absolute offsets and both the payload and the planned
        (aligned) byte sizes. Every state arena — host numpy, device jax,
        and the residency views the engine decodes through — materializes
        from this one spec, so they cannot disagree on where a leaf's
        bytes live."""
        import numpy as np

        views: list[LeafView] = []
        n_leaves = len(self.leaves)
        for slot in range(self.n_slots):
            base = slot * self.slot_stride
            for i, leaf in enumerate(self.leaves):
                nbytes = math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                views.append(
                    LeafView(
                        tensor_id=slot * n_leaves + i,
                        slot=slot,
                        leaf_index=i,
                        path=leaf.path,
                        dtype=leaf.dtype,
                        offset=base + leaf.offset,
                        used_nbytes=nbytes // self.n_slots,
                        slot_nbytes=leaf.slot_nbytes,
                    )
                )
        return views

    def summary(self) -> str:
        return (
            f"state[{self.strategy}]: {self.total_size / 2**20:.3f} MiB "
            f"({self.n_slots} slots x {self.slot_stride / 2**20:.3f} MiB, "
            f"{len(self.leaves)} leaves, len {self.max_len})"
        )


def state_records_from_pytree(tree: Any, *, n_slots: int) -> list[StateRecord]:
    """Derive :class:`StateRecord`\\ s from a cache pytree — concrete jax
    arrays, numpy arrays, or ``jax.eval_shape`` ShapeDtypeStructs (the
    compile path never materializes a cache)."""
    import jax  # runtime-only dependency; planning itself stays jax-free
    import numpy as np

    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    records = []
    for path, leaf in leaves:
        dt = np.dtype(leaf.dtype)
        shape = tuple(int(d) for d in leaf.shape)
        records.append(
            StateRecord(
                path=jax.tree_util.keystr(path),
                shape=shape,
                dtype=dt.name,
                nbytes=math.prod(shape) * dt.itemsize,
            )
        )
    del n_slots  # divisibility is checked where the layout is built
    return records


def plan_state(
    records: Sequence[StateRecord],
    *,
    n_slots: int,
    max_len: int,
    alignment: int = DEFAULT_ALIGNMENT,
) -> StatePlan:
    """Lay out the cross-step state: per-slot shares packed
    size-descending (deterministic: ties break on path), each aligned, in
    ``n_slots`` symmetric regions. Objective as in §4 — total size of all
    shared objects — is ``n_slots * slot_stride`` by symmetry."""
    global STATE_PLAN_CALLS
    STATE_PLAN_CALLS += 1
    placed: list[StateLeaf] = []
    offset = 0
    for rec in sorted(records, key=lambda r: (-r.nbytes, r.path)):
        if rec.nbytes % n_slots:
            raise ValueError(
                f"state leaf {rec.path!r}: {rec.nbytes} B not divisible by "
                f"{n_slots} slots — every cross-step leaf must carry the "
                f"slot batch dimension"
            )
        slot_nbytes = align(rec.nbytes // n_slots, alignment)
        placed.append(
            StateLeaf(
                path=rec.path,
                shape=rec.shape,
                dtype=rec.dtype,
                slot_nbytes=slot_nbytes,
                offset=offset,
            )
        )
        offset += slot_nbytes
    stride = align(offset, alignment)
    return StatePlan(
        n_slots=n_slots,
        max_len=max_len,
        alignment=alignment,
        leaves=placed,
        slot_stride=stride,
        total_size=n_slots * stride,
    )


def state_plan_to_obj(sp: StatePlan) -> dict:
    return {
        "n_slots": sp.n_slots,
        "max_len": sp.max_len,
        "alignment": sp.alignment,
        "slot_stride": sp.slot_stride,
        "total_size": sp.total_size,
        "strategy": sp.strategy,
        "leaves": [
            [l.path, list(l.shape), l.dtype, l.slot_nbytes, l.offset]
            for l in sp.leaves
        ],
    }


def state_plan_from_obj(obj: dict) -> StatePlan:
    return StatePlan(
        n_slots=obj["n_slots"],
        max_len=obj["max_len"],
        alignment=obj["alignment"],
        leaves=[
            StateLeaf(
                path=p, shape=tuple(shape), dtype=dt, slot_nbytes=nb, offset=off
            )
            for p, shape, dt, nb, off in obj["leaves"]
        ],
        slot_stride=obj["slot_stride"],
        total_size=obj["total_size"],
        strategy=obj["strategy"],
    )


# ------------------------------------------------------------ spec + plan


@dataclasses.dataclass
class PlanSpec:
    """One planning request, covering both halves.

    Activation input is the ``graph`` (preferred — enables ``search``) or
    raw ``records``; the cross-step half is ``state_records`` (omit for an
    activation-only plan). ``cfg``/``n_slots``/``max_len`` are the bucket
    identity: with all three set the plan's fingerprint is the bundle's
    config-level :func:`~repro.core.artifact.decode_fingerprint`, so a
    spec-planned :class:`UnifiedPlan` and a compiled bundle for the same
    bucket carry the same key."""

    graph: "Graph | None" = None
    records: Sequence[TensorUsageRecord] | None = None
    state_records: Sequence[StateRecord] | None = None
    # bucket identity
    cfg: "ArchConfig | None" = None
    n_slots: int | None = None
    max_len: int | None = None
    # serve-loop identity (artifact.serve_fingerprint payload): block size
    # + sampling knobs when the bucket targets the scan-block decode path;
    # None = the default single-wave greedy host loop
    serve_params: dict | None = None
    # strategy / search knobs
    mode: str = "offsets"
    strategy: str = "auto"
    alignment: int = DEFAULT_ALIGNMENT
    search: bool = False
    search_iters: int = 300
    fusion_rounds: int = 40
    # plan-cache control
    cache: "plan_io.PlanCache | None" = None
    use_cache: bool = True
    graph_name: str = "records"


@dataclasses.dataclass
class SearchOutcome:
    """Search-path by-products that serving artifacts don't carry whole:
    the pre-search plan and the full order/fusion results."""

    greedy_plan: "MemoryPlan"
    order: "OrderSearchResult"
    fusion: "FusionSearchResult"


@dataclasses.dataclass
class UnifiedPlan:
    """Both halves of a serving bucket's memory plan under one fingerprint
    and one ``total_size``. ``activation`` may be None for a state-only
    spec (and vice versa)."""

    activation: "MemoryPlan | None"
    state: StatePlan | None
    fingerprint: str
    # searched-order / fusion provenance for the activation half (same
    # semantics as PlanBundle.order / .fusion_groups)
    order: list[int] | None = None
    fusion_groups: list[list[int]] | None = None
    provenance: dict = dataclasses.field(default_factory=dict)
    # search by-products; never serialized (bundles keep provenance only)
    search: SearchOutcome | None = None

    @property
    def total_size(self) -> int:
        total = 0
        if self.activation is not None:
            total += self.activation.total_size
        if self.state is not None:
            total += self.state.total_size
        return total

    def arena_layouts(self) -> "tuple[ArenaLayout | None, ArenaLayout | None]":
        """Materialization view: (activation layout, state layout) — both
        arenas from this one object."""
        from repro.runtime.arena import ArenaLayout

        return (
            ArenaLayout.from_plan(self.activation)
            if self.activation is not None
            else None,
            ArenaLayout.from_state_plan(self.state)
            if self.state is not None
            else None,
        )

    def summary(self) -> str:
        lines = []
        if self.activation is not None:
            lines.append(self.activation.summary())
        if self.state is not None:
            lines.append(self.state.summary())
        lines.append(
            f"unified footprint: {self.total_size / 2**20:.3f} MiB "
            f"[{self.fingerprint[:12]}]"
        )
        return "\n".join(lines)


def _spec_fingerprint(spec: PlanSpec, records, state_records) -> str:
    """Content fingerprint for bucket-less specs: everything the unified
    output depends on. Bucketed specs use the config-level
    ``decode_fingerprint`` instead (shared with compiled bundles)."""
    payload = {
        "planner_revision": plan_io.PLANNER_REVISION,
        "mode": spec.mode,
        "strategy": spec.strategy,
        "search": spec.search,
        "records": plan_io.canonical_records(records) if records else None,
        "state": [
            [r.path, list(r.shape), r.dtype, r.nbytes]
            for r in (state_records or [])
        ],
        "n_slots": spec.n_slots,
        "max_len": spec.max_len,
    }
    if spec.serve_params:
        payload["serve_params"] = spec.serve_params
    text = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(text.encode()).hexdigest()


def plan(spec: PlanSpec) -> UnifiedPlan:
    """THE planning entry point: activation half (with optional
    order/fusion search) + cross-step state half, one fingerprint, one
    total. Every other planner API is a wrapper over this."""
    from repro.core import planner

    records = None
    if spec.records is not None:
        records = list(spec.records)
    elif spec.graph is not None:
        records = spec.graph.usage_records(spec.alignment)
    if records is None and spec.state_records is None:
        raise ValueError(
            "empty PlanSpec: provide an activation graph/records, "
            "state_records, or both"
        )

    activation: "MemoryPlan | None" = None
    order: list[int] | None = None
    groups: list[list[int]] | None = None
    outcome: SearchOutcome | None = None
    provenance: dict = {}
    if records is not None:
        graph_name = spec.graph.name if spec.graph is not None else spec.graph_name
        activation = planner._plan_records_impl(
            records,
            mode=spec.mode,
            strategy=spec.strategy,
            graph_name=graph_name,
            cache=spec.cache,
            use_cache=spec.use_cache,
        )
        if spec.search:
            if spec.graph is None:
                raise ValueError("search=True needs a graph, not raw records")
            from repro.core.fusion_search import fusion_search
            from repro.core.order_search import search_order

            search_cache = (
                spec.cache if spec.cache is not None else plan_io.PlanCache()
            )
            order_res = search_order(
                spec.graph, iters=spec.search_iters, seed=0,
                strategy=spec.strategy, cache=search_cache,
            )
            fusion_res = fusion_search(
                spec.graph, strategy=spec.strategy,
                max_rounds=spec.fusion_rounds, cache=search_cache,
            )
            outcome = SearchOutcome(
                greedy_plan=activation, order=order_res, fusion=fusion_res
            )
            # both searches honor the never-worse contract; take the smaller
            if fusion_res.plan.total_size < activation.total_size and (
                fusion_res.plan.total_size <= order_res.plan.total_size
            ):
                activation = fusion_res.plan
                groups = [list(g) for g in fusion_res.groups]
            elif order_res.plan.total_size < activation.total_size:
                activation = order_res.plan
                order = list(order_res.order)
            provenance["search_stats"] = {
                **order_res.provenance(),
                **fusion_res.provenance(),
                "order_iters": spec.search_iters,
                "fusion_rounds": spec.fusion_rounds,
            }
        provenance.update(
            {
                "strategy_requested": spec.strategy,
                "search": spec.search,
                "records": len(records),
                "greedy_total_bytes": (
                    outcome.greedy_plan.total_size
                    if outcome is not None
                    else activation.total_size
                ),
                "searched_total_bytes": (
                    min(
                        outcome.order.plan.total_size,
                        outcome.fusion.plan.total_size,
                    )
                    if outcome is not None
                    else None
                ),
            }
        )
        if spec.graph is not None:
            provenance["graph_ops"] = len(spec.graph.ops)

    state: StatePlan | None = None
    if spec.state_records is not None:
        if spec.n_slots is None or spec.max_len is None:
            raise ValueError("state_records need n_slots and max_len")
        state = plan_state(
            spec.state_records,
            n_slots=spec.n_slots,
            max_len=spec.max_len,
            alignment=spec.alignment,
        )
        provenance["state_total_bytes"] = state.total_size
        provenance["state_leaves"] = len(state.leaves)

    if (
        spec.cfg is not None
        and spec.n_slots is not None
        and spec.max_len is not None
    ):
        from repro.core.artifact import decode_fingerprint

        fingerprint = decode_fingerprint(
            spec.cfg, n_slots=spec.n_slots, max_len=spec.max_len,
            serve_params=spec.serve_params,
        )
    else:
        fingerprint = _spec_fingerprint(spec, records, spec.state_records)

    return UnifiedPlan(
        activation=activation,
        state=state,
        fingerprint=fingerprint,
        order=order,
        fusion_groups=groups,
        provenance=provenance,
        search=outcome,
    )


# ---------------------------------------------------------------- session


@dataclasses.dataclass
class Resolution:
    """What a :class:`PlanSession` hands the engine: the unified plan (or
    None — trace-and-plan fallback), the backing bundle when there is one,
    the effective serving bucket (``max_len`` and ``n_slots`` may both be
    >= requested when nearest-bucket selection picked a longer or
    wider-pool compiled bucket), a one-line warning for the report, and
    the spec knobs the fallback path should honor."""

    unified: UnifiedPlan | None
    bundle: "PlanBundle | None"
    source: str  # "bundle" | "spec" | "unresolved"
    warning: str | None
    max_len: int
    n_slots: int = 0  # 0 = the requested slot count
    spec: PlanSpec | None = None


class PlanSession:
    """The one plan source an engine serves from.

    ``from_manifest(dir)`` — compiled-artifact serving with bucket
    auto-selection: exact bucket first, else the admissible compiled
    bucket (``max_len >= requested`` and ``n_slots >= requested``, same
    arch/dtype) with the smallest unified footprint (pass
    ``nearest=False`` for exact-only). ``from_bundle`` — one bundle file
    or object. ``from_spec`` — plan on demand from a :class:`PlanSpec`
    (pre-searched graphs, pinned strategies); an empty spec defers to the
    engine's own trace. ``verify_graph=True`` asks the engine to check the
    bundle's structural graph fingerprint against a fresh trace (trades
    the zero-trace cold start for a model-code-drift check)."""

    def __init__(
        self,
        *,
        manifest_dir: str | Path | None = None,
        bundle: "PlanBundle | str | Path | None" = None,
        spec: PlanSpec | None = None,
        nearest: bool = True,
        verify_graph: bool = False,
    ):
        sources = [manifest_dir is not None, bundle is not None, spec is not None]
        if sum(sources) != 1:
            raise ValueError(
                "PlanSession takes exactly one source: manifest_dir, "
                "bundle, or spec"
            )
        self.manifest_dir = Path(manifest_dir) if manifest_dir else None
        self.bundle = bundle
        self.spec = spec
        self.nearest = nearest
        self.verify_graph = verify_graph

    @classmethod
    def from_manifest(
        cls, directory: str | Path, *, nearest: bool = True,
        verify_graph: bool = False,
    ) -> "PlanSession":
        return cls(
            manifest_dir=directory, nearest=nearest, verify_graph=verify_graph
        )

    @classmethod
    def from_bundle(
        cls, bundle: "PlanBundle | str | Path", *, verify_graph: bool = False
    ) -> "PlanSession":
        return cls(bundle=bundle, verify_graph=verify_graph)

    @classmethod
    def from_spec(cls, spec: PlanSpec) -> "PlanSession":
        return cls(spec=spec)

    def resolve(
        self, cfg: "ArchConfig", *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        """``serve_params`` is the engine's serve-loop fingerprint payload
        (``artifact.serve_fingerprint``) — None for the default greedy
        host loop; bundles compiled for a different serving configuration
        fail the fingerprint check and fall back."""
        if self.spec is not None:
            return self._resolve_spec(
                cfg, n_slots=n_slots, max_len=max_len,
                serve_params=serve_params,
            )
        return self._resolve_bundle(
            cfg, n_slots=n_slots, max_len=max_len, serve_params=serve_params
        )

    def _resolve_spec(
        self, cfg, *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        spec = dataclasses.replace(
            self.spec, cfg=cfg, n_slots=n_slots, max_len=max_len,
            serve_params=(
                serve_params if serve_params is not None
                else self.spec.serve_params
            ),
        )
        if spec.graph is None and spec.records is None:
            # knobs only — the engine traces, then plans with these knobs
            return Resolution(
                unified=None, bundle=None, source="spec", warning=None,
                max_len=max_len, n_slots=n_slots, spec=spec,
            )
        return Resolution(
            unified=plan(spec), bundle=None, source="spec", warning=None,
            max_len=max_len, n_slots=n_slots, spec=spec,
        )

    def _resolve_bundle(
        self, cfg, *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        from repro.core import artifact

        nearest = self.nearest and self.manifest_dir is not None
        source = self.bundle if self.bundle is not None else self.manifest_dir
        try:
            bundle = artifact.resolve_bundle(
                source, cfg, n_slots=n_slots, max_len=max_len,
                nearest=nearest,
            )
        except Exception as e:
            # a bad artifact degrades to plan-at-construction, never
            # crashes serving (whatever a corrupt or adversarially
            # malformed document raises)
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=f"plan bundle unusable ({e}); "
                        f"planned at construction instead",
                max_len=max_len, n_slots=n_slots,
            )
        # Nearest-bucket mode verifies the bundle against ITS OWN bucket
        # (serving max_len >= requested — and, since the slot pool is the
        # §4 shared-objects set, n_slots >= requested — is the point of
        # auto-selection); strict mode (single bundles, exact-only
        # manifests) keeps the requested bucket as the expectation.
        if nearest and (bundle.max_len < max_len or bundle.n_slots < n_slots):
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=(
                    f"plan bundle compiled for slots={bundle.n_slots} "
                    f"len={bundle.max_len} < requested slots={n_slots} "
                    f"len={max_len}; planned at construction instead"
                ),
                max_len=max_len, n_slots=n_slots,
            )
        verify_len = bundle.max_len if nearest else max_len
        verify_slots = bundle.n_slots if nearest else n_slots
        expect = artifact.decode_fingerprint(
            cfg, n_slots=verify_slots, max_len=verify_len,
            serve_params=serve_params,
        )
        if bundle.fingerprint != expect:
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=(
                    f"plan bundle fingerprint mismatch (bundle "
                    f"{str(bundle.fingerprint)[:12]}, engine {expect[:12]}); "
                    f"planned at construction instead"
                ),
                max_len=max_len, n_slots=n_slots,
            )
        return Resolution(
            unified=artifact.unified_from_bundle(bundle),
            bundle=bundle,
            source="bundle",
            warning=None,
            max_len=max(bundle.max_len, max_len) if nearest else max_len,
            n_slots=max(bundle.n_slots, n_slots) if nearest else n_slots,
        )
