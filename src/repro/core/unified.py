"""Unified planning facade: one ``plan()`` over both of the paper's halves.

The paper poses two memory-planning problems that this repo used to solve
through disjoint code paths with incompatible inputs and outputs:

* **intra-step activation sharing** — Offset Calculation / Shared Objects
  over tensor usage records of one decode step (§4–§5, ``core/planner``);
* **cross-step shared-objects state** — per-slot KV caches and decode
  buffers reused across requests, §4 applied *above* the XLA level where
  slots are the shared objects and requests are the tensors
  (``core/shared_objects``, audited by the engine's slot log).

This module joins them under one API:

* :class:`PlanSpec` — everything a planning request is made of: the
  activation graph (or raw usage records), the cross-step
  :class:`StateRecord` set, the strategy/search knobs, and the bucket
  identity (config, ``n_slots``, ``max_len``);
* :func:`plan` — ``repro.core.plan(spec) -> UnifiedPlan``: plans the
  activation half (optionally through the memory-aware order/fusion
  search), lays out the cross-step state half, and returns both under one
  fingerprint and one ``total_size``;
* :class:`StatePlan` — the slot/KV shared-objects layout with concrete
  byte offsets: ``n_slots`` symmetric slot regions, each packing the
  per-slot share of every state leaf (size-descending, aligned), so a
  serving process can account for — and materialize — the cross-step
  arena without touching a model;
* :class:`PlanSession` — the single plan *source* an
  :class:`~repro.runtime.engine.InferenceEngine` consumes: a bundle
  manifest (``from_manifest``, with nearest-bucket selection), one bundle
  (``from_bundle``), or a spec planned on demand (``from_spec``).

``planner.plan_records``/``planner.plan_graph`` are thin wrappers over
:func:`plan`; the strategy implementations themselves still live in
``core/planner``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from pathlib import Path
from typing import TYPE_CHECKING, Any, Sequence

from repro.core import plan_io
from repro.core.interval_set import BestFitArena
from repro.core.records import DEFAULT_ALIGNMENT, TensorUsageRecord, align

if TYPE_CHECKING:  # keep this module importable without jax
    from repro.configs.base import ArchConfig
    from repro.core.artifact import PlanBundle
    from repro.core.graph import Graph
    from repro.core.planner import MemoryPlan
    from repro.core.fusion_search import FusionSearchResult
    from repro.core.order_search import OrderSearchResult
    from repro.runtime.arena import ArenaLayout

# Instrumentation: total state-plan constructions this process. A
# bundle-served engine must not lay out the cross-step state either —
# tests snapshot this next to planner.PLAN_CALLS / tracer.TRACE_CALLS.
STATE_PLAN_CALLS = 0

STATE_STRATEGY = "slots_as_shared_objects"
PAGED_STATE_STRATEGY = "paged_shared_objects"


# ------------------------------------------------------- cross-step state


@dataclasses.dataclass(frozen=True)
class StateRecord:
    """One cross-step state tensor (a cache-pytree leaf): its identity and
    full (all-slot) byte size. The per-slot share is ``nbytes / n_slots``
    — every leaf carries the slot batch dimension, so the division is
    exact (checked by :func:`plan_state`)."""

    path: str  # pytree key path, e.g. "['period'][0]['kv'][1]"
    shape: tuple[int, ...]
    dtype: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class StateLeaf:
    """A :class:`StateRecord` placed inside one slot region: aligned
    per-slot byte size + concrete offset within the slot."""

    path: str
    shape: tuple[int, ...]
    dtype: str
    slot_nbytes: int  # aligned per-slot bytes
    offset: int  # byte offset within a slot region


@dataclasses.dataclass(frozen=True)
class LeafView:
    """One (slot, leaf) cell of the state arena, fully addressed: where
    its bytes live (``offset``), how many are payload (``used_nbytes``,
    the unaligned per-slot share) and how many are reserved
    (``slot_nbytes``, the aligned bounds-contract size). This is THE leaf
    addressing unit shared by every arena implementation — the numpy
    :class:`~repro.runtime.arena.Arena`, the jax
    :class:`~repro.runtime.arena.DeviceArena`, and the residency
    pack/unpack views are all built from :meth:`StatePlan.leaf_view_spec`.
    """

    tensor_id: int  # dense: slot * n_leaves + leaf_index
    slot: int
    leaf_index: int
    path: str
    dtype: str
    offset: int  # absolute byte offset in the state buffer
    used_nbytes: int  # payload bytes of the per-slot share (unaligned)
    slot_nbytes: int  # planned slot bytes (aligned; bounds enforcement)


@dataclasses.dataclass
class StatePlan:
    """Slot/KV shared-objects layout with concrete offsets (paper §4 at
    the request level). ``n_slots`` identical slot regions of
    ``slot_stride`` bytes; leaf ``l`` of slot ``s`` lives at
    ``s * slot_stride + leaves[l].offset``. Slots are the shared objects:
    an object's size is the full per-slot state, and request→slot
    assignment happens at serving time (the engine's slot log is the
    §4-style audit, see :func:`repro.core.shared_objects.from_slot_log`).
    """

    n_slots: int
    max_len: int
    alignment: int
    leaves: list[StateLeaf]
    slot_stride: int
    total_size: int
    strategy: str = STATE_STRATEGY

    @property
    def bytes_per_slot(self) -> int:
        return self.slot_stride

    def offset_of(self, slot: int, path: str) -> int:
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} outside [0, {self.n_slots})")
        for leaf in self.leaves:
            if leaf.path == path:
                return slot * self.slot_stride + leaf.offset
        raise KeyError(f"no state leaf at path {path!r}")

    def flat_entries(self) -> list[tuple[int, int, StateLeaf, int]]:
        """(tensor_id, slot, leaf, absolute_offset) tuple view over
        :meth:`leaf_view_spec` — same cells, legacy tuple shape."""
        return [
            (v.tensor_id, v.slot, self.leaves[v.leaf_index], v.offset)
            for v in self.leaf_view_spec()
        ]

    def leaf_view_spec(self) -> "list[LeafView]":
        """The leaf addressing API: one :class:`LeafView` per (slot, leaf)
        cell, with absolute offsets and both the payload and the planned
        (aligned) byte sizes. Every state arena — host numpy, device jax,
        and the residency views the engine decodes through — materializes
        from this one spec, so they cannot disagree on where a leaf's
        bytes live."""
        import numpy as np

        views: list[LeafView] = []
        n_leaves = len(self.leaves)
        for slot in range(self.n_slots):
            base = slot * self.slot_stride
            for i, leaf in enumerate(self.leaves):
                nbytes = math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                views.append(
                    LeafView(
                        tensor_id=slot * n_leaves + i,
                        slot=slot,
                        leaf_index=i,
                        path=leaf.path,
                        dtype=leaf.dtype,
                        offset=base + leaf.offset,
                        used_nbytes=nbytes // self.n_slots,
                        slot_nbytes=leaf.slot_nbytes,
                    )
                )
        return views

    def summary(self) -> str:
        return (
            f"state[{self.strategy}]: {self.total_size / 2**20:.3f} MiB "
            f"({self.n_slots} slots x {self.slot_stride / 2**20:.3f} MiB, "
            f"{len(self.leaves)} leaves, len {self.max_len})"
        )


def state_records_from_pytree(tree: Any, *, n_slots: int) -> list[StateRecord]:
    """Derive :class:`StateRecord`\\ s from a cache pytree — concrete jax
    arrays, numpy arrays, or ``jax.eval_shape`` ShapeDtypeStructs (the
    compile path never materializes a cache)."""
    import jax  # runtime-only dependency; planning itself stays jax-free
    import numpy as np

    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    records = []
    for path, leaf in leaves:
        dt = np.dtype(leaf.dtype)
        shape = tuple(int(d) for d in leaf.shape)
        records.append(
            StateRecord(
                path=jax.tree_util.keystr(path),
                shape=shape,
                dtype=dt.name,
                nbytes=math.prod(shape) * dt.itemsize,
            )
        )
    del n_slots  # divisibility is checked where the layout is built
    return records


def plan_state(
    records: Sequence[StateRecord],
    *,
    n_slots: int,
    max_len: int,
    alignment: int = DEFAULT_ALIGNMENT,
) -> StatePlan:
    """Lay out the cross-step state: per-slot shares packed
    size-descending (deterministic: ties break on path), each aligned, in
    ``n_slots`` symmetric regions. Objective as in §4 — total size of all
    shared objects — is ``n_slots * slot_stride`` by symmetry."""
    global STATE_PLAN_CALLS
    STATE_PLAN_CALLS += 1
    placed: list[StateLeaf] = []
    offset = 0
    for rec in sorted(records, key=lambda r: (-r.nbytes, r.path)):
        if rec.nbytes % n_slots:
            raise ValueError(
                f"state leaf {rec.path!r}: {rec.nbytes} B not divisible by "
                f"{n_slots} slots — every cross-step leaf must carry the "
                f"slot batch dimension"
            )
        slot_nbytes = align(rec.nbytes // n_slots, alignment)
        placed.append(
            StateLeaf(
                path=rec.path,
                shape=rec.shape,
                dtype=rec.dtype,
                slot_nbytes=slot_nbytes,
                offset=offset,
            )
        )
        offset += slot_nbytes
    stride = align(offset, alignment)
    return StatePlan(
        n_slots=n_slots,
        max_len=max_len,
        alignment=alignment,
        leaves=placed,
        slot_stride=stride,
        total_size=n_slots * stride,
    )


@dataclasses.dataclass
class PagedStatePlan(StatePlan):
    """Page-granular cross-step state layout (ROADMAP open item 2): the
    *logical* layout is exactly the symmetric :class:`StatePlan` —
    ``n_slots`` regions of ``slot_stride`` bytes, same leaves, same
    ``total_size`` — but physical storage is a pool of ``n_pages_pool``
    fixed ``page_size``-byte pages plus one reserved all-zero *null page*
    at physical index 0. A per-slot page table (``pages_per_slot`` int32
    entries, physical page indices; 0 = unmapped → null page) maps each
    logical page of the slot region onto the pool, so resident state
    scales with *live* tokens: a slot at cache length ``L`` only needs
    the pages intersecting its live byte spans (:meth:`pages_needed`).

    ``token_spans`` records, per leaf (aligned with ``leaves``), how the
    per-slot byte range decomposes along the token axis:
    ``(n_chunks, n_rows, row_nbytes)`` — rows ``>= L`` of every chunk are
    dead at length ``L`` — or ``None`` for leaves that are fully live at
    any length (length-independent SSM state, sliding-window caches).

    ``total_size`` stays the logical ``n_slots * slot_stride`` (it is the
    §4 objective the symmetric certifiers and arena layouts reason
    about); the device buffer a paged backend allocates is
    :attr:`phys_total_size`.
    """

    page_size: int = 0
    n_pages_pool: int = 0
    # physical byte offset of each pool page (page i+1 — the null page is
    # implicit at offset 0), as carved by the interval engine
    page_offsets: list[int] = dataclasses.field(default_factory=list)
    token_spans: list[tuple[int, int, int] | None] = dataclasses.field(
        default_factory=list
    )

    @property
    def pages_per_slot(self) -> int:
        return -(-self.slot_stride // self.page_size)

    @property
    def n_pages_total(self) -> int:
        return self.n_pages_pool + 1

    @property
    def phys_total_size(self) -> int:
        return self.n_pages_total * self.page_size

    def live_spans(self, length: int) -> list[tuple[int, int]]:
        """Byte spans within one slot region that are live at cache
        length ``length`` (leaf payloads only; alignment padding is dead
        on both the symmetric and the paged path)."""
        import numpy as np

        spans: list[tuple[int, int]] = []
        for leaf, span in zip(self.leaves, self.token_spans):
            used = (
                math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                // self.n_slots
            )
            if span is None:
                spans.append((leaf.offset, leaf.offset + used))
                continue
            n_chunks, n_rows, row_nbytes = span
            live = min(max(length, 0), n_rows) * row_nbytes
            if live == 0:
                continue
            for k in range(n_chunks):
                base = leaf.offset + k * n_rows * row_nbytes
                spans.append((base, base + live))
        return spans

    def pages_needed(self, length: int) -> tuple[int, ...]:
        """Logical page indices (within ``[0, pages_per_slot)``) a slot
        must have mapped to serve a request at cache length ``length``."""
        page = self.page_size
        need: set[int] = set()
        for a, b in self.live_spans(length):
            need.update(range(a // page, (b - 1) // page + 1))
        return tuple(sorted(need))

    def live_bytes(self, length: int) -> int:
        """Physical pool bytes one slot holds at cache length ``length``."""
        return len(self.pages_needed(length)) * self.page_size

    def summary(self) -> str:
        return (
            f"state[{self.strategy}]: {self.total_size / 2**20:.3f} MiB "
            f"logical ({self.n_slots} slots x "
            f"{self.slot_stride / 2**20:.3f} MiB), pool "
            f"{self.n_pages_pool} x {self.page_size} B pages "
            f"({self.phys_total_size / 2**20:.3f} MiB physical, "
            f"{len(self.leaves)} leaves, len {self.max_len})"
        )


def detect_state_axes(
    init_cache, *, n_slots: int, max_len: int
) -> dict[str, tuple[int, int | None]]:
    """Shape-differencing probe for the paged planner: evaluate
    ``init_cache`` (shape level — no arrays are materialized) at the
    bucket shape, at an alternate cache length, and at an alternate slot
    count, and identify each leaf's slot-batch axis and token axis as the
    unique axis that tracks the varied parameter. Returns
    ``path -> (slot_axis, token_axis | None)`` in full-shape axes;
    ``None`` marks a leaf whose extent does not follow ``max_len``
    (length-independent SSM state, sliding-window caches) — such leaves
    are conservatively treated as fully live by the paged plan."""
    import jax

    def shapes(ns: int, ml: int) -> dict[str, tuple[int, ...]]:
        tree = jax.eval_shape(lambda: init_cache(ns, ml))
        leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
        return {
            jax.tree_util.keystr(p): tuple(int(d) for d in leaf.shape)
            for p, leaf in leaves
        }

    alt_len = max_len + 8
    alt_slots = n_slots + 1
    base = shapes(n_slots, max_len)
    by_len = shapes(n_slots, alt_len)
    by_slots = shapes(alt_slots, max_len)
    axes: dict[str, tuple[int, int | None]] = {}
    for path, shape in base.items():
        s_shape = by_slots.get(path)
        l_shape = by_len.get(path)
        if (
            s_shape is None or l_shape is None
            or len(s_shape) != len(shape) or len(l_shape) != len(shape)
        ):
            raise ValueError(
                f"state leaf {path!r}: cache structure changes with the "
                f"bucket shape — cannot derive a paged layout"
            )
        slot_ax = [
            i for i, (a, b) in enumerate(zip(shape, s_shape)) if a != b
        ]
        if (
            len(slot_ax) != 1
            or shape[slot_ax[0]] != n_slots
            or s_shape[slot_ax[0]] != alt_slots
        ):
            raise ValueError(
                f"state leaf {path!r}: no unambiguous slot batch axis "
                f"({shape} vs {s_shape} at {alt_slots} slots)"
            )
        tok_ax = [
            i for i, (a, b) in enumerate(zip(shape, l_shape)) if a != b
        ]
        token: int | None = None
        if (
            len(tok_ax) == 1
            and shape[tok_ax[0]] == max_len
            and l_shape[tok_ax[0]] == alt_len
        ):
            token = tok_ax[0]
        axes[path] = (slot_ax[0], token)
    return axes


def plan_paged_state(
    records: Sequence[StateRecord],
    *,
    n_slots: int,
    max_len: int,
    page_size: int,
    page_pool: int | None = None,
    axes: dict[str, tuple[int, int | None]] | None = None,
    alignment: int = DEFAULT_ALIGNMENT,
) -> PagedStatePlan:
    """Lay out the cross-step state at page granularity: the symmetric
    per-slot leaf packing of :func:`plan_state` becomes the *logical*
    layout, the physical pool is carved into ``page_pool`` fixed-size
    pages (default ``n_slots * pages_per_slot`` — enough to map every
    slot fully, so the default pool can never refuse an admission the
    symmetric plan would accept) by the interval engine
    (:class:`~repro.core.interval_set.BestFitArena`: every page is a
    whole-serving-lifetime record, so best-fit packs them end to end
    after the reserved null page at offset 0), and per-leaf token spans
    from ``axes`` (see :func:`detect_state_axes`) record which bytes are
    live at a given cache length."""
    import numpy as np

    if page_size <= 0:
        raise ValueError(f"page_size must be positive, got {page_size}")
    base = plan_state(
        records, n_slots=n_slots, max_len=max_len, alignment=alignment
    )
    token_spans: list[tuple[int, int, int] | None] = []
    for leaf in base.leaves:
        slot_ax, tok_ax = (axes or {}).get(leaf.path, (0, None))
        if tok_ax is None:
            token_spans.append(None)
            continue
        per_slot_shape = tuple(
            d for i, d in enumerate(leaf.shape) if i != slot_ax
        )
        tok = tok_ax - (1 if slot_ax < tok_ax else 0)
        n_chunks = math.prod(per_slot_shape[:tok]) if tok else 1
        n_rows = per_slot_shape[tok]
        row_nbytes = (
            math.prod(per_slot_shape[tok + 1:])
            * np.dtype(leaf.dtype).itemsize
        )
        token_spans.append((int(n_chunks), int(n_rows), int(row_nbytes)))

    pages_per_slot = -(-base.slot_stride // page_size)
    n_pool = (
        page_pool if page_pool is not None else n_slots * pages_per_slot
    )
    if n_pool < 1:
        raise ValueError(
            f"page pool must hold at least one page, got {n_pool}"
        )
    arena = BestFitArena()
    # null page first: physical offset 0 is the reserved all-zero page
    arena.place(
        TensorUsageRecord(first_op=0, last_op=0, size=page_size, tensor_id=0)
    )
    page_offsets = [
        arena.place(
            TensorUsageRecord(
                first_op=0, last_op=0, size=page_size, tensor_id=i + 1
            )
        )
        for i in range(n_pool)
    ]
    phys_total = (n_pool + 1) * page_size
    seen: set[int] = {0}
    for i, off in enumerate(page_offsets):
        if off % page_size or off in seen or off + page_size > phys_total:
            raise ValueError(
                f"page carving produced an unusable offset {off} for pool "
                f"page {i} (page_size {page_size}, pool {n_pool})"
            )
        seen.add(off)
    return PagedStatePlan(
        n_slots=n_slots,
        max_len=max_len,
        alignment=alignment,
        leaves=base.leaves,
        slot_stride=base.slot_stride,
        total_size=base.total_size,
        strategy=PAGED_STATE_STRATEGY,
        page_size=page_size,
        n_pages_pool=n_pool,
        page_offsets=page_offsets,
        token_spans=token_spans,
    )


def state_plan_to_obj(sp: StatePlan) -> dict:
    obj = {
        "n_slots": sp.n_slots,
        "max_len": sp.max_len,
        "alignment": sp.alignment,
        "slot_stride": sp.slot_stride,
        "total_size": sp.total_size,
        "strategy": sp.strategy,
        "leaves": [
            [l.path, list(l.shape), l.dtype, l.slot_nbytes, l.offset]
            for l in sp.leaves
        ],
    }
    if isinstance(sp, PagedStatePlan):
        obj["page_size"] = sp.page_size
        obj["n_pages_pool"] = sp.n_pages_pool
        obj["page_offsets"] = list(sp.page_offsets)
        obj["token_spans"] = [
            list(s) if s is not None else None for s in sp.token_spans
        ]
    return obj


def state_plan_from_obj(obj: dict) -> StatePlan:
    leaves = [
        StateLeaf(
            path=p, shape=tuple(shape), dtype=dt, slot_nbytes=nb, offset=off
        )
        for p, shape, dt, nb, off in obj["leaves"]
    ]
    if "page_size" in obj:
        return PagedStatePlan(
            n_slots=obj["n_slots"],
            max_len=obj["max_len"],
            alignment=obj["alignment"],
            leaves=leaves,
            slot_stride=obj["slot_stride"],
            total_size=obj["total_size"],
            strategy=obj["strategy"],
            page_size=obj["page_size"],
            n_pages_pool=obj["n_pages_pool"],
            page_offsets=list(obj["page_offsets"]),
            token_spans=[
                tuple(s) if s is not None else None
                for s in obj["token_spans"]
            ],
        )
    return StatePlan(
        n_slots=obj["n_slots"],
        max_len=obj["max_len"],
        alignment=obj["alignment"],
        leaves=leaves,
        slot_stride=obj["slot_stride"],
        total_size=obj["total_size"],
        strategy=obj["strategy"],
    )


# ------------------------------------------------------------ spec + plan


@dataclasses.dataclass
class PlanSpec:
    """One planning request, covering both halves.

    Activation input is the ``graph`` (preferred — enables ``search``) or
    raw ``records``; the cross-step half is ``state_records`` (omit for an
    activation-only plan). ``cfg``/``n_slots``/``max_len`` are the bucket
    identity: with all three set the plan's fingerprint is the bundle's
    config-level :func:`~repro.core.artifact.decode_fingerprint`, so a
    spec-planned :class:`UnifiedPlan` and a compiled bundle for the same
    bucket carry the same key."""

    graph: "Graph | None" = None
    records: Sequence[TensorUsageRecord] | None = None
    state_records: Sequence[StateRecord] | None = None
    # prefill half (optional): the full-sequence forward graph at
    # ``prefill_len`` tokens — long activation lifetimes, the regime where
    # the paper's strategies diverge most. Planned with the same strategy
    # portfolio as the decode half (no order/fusion search — the search
    # knobs target the decode graph); ``prefill_len`` joins the bucketed
    # fingerprint (None-canonicalized, so decode-only specs are unchanged)
    prefill_graph: "Graph | None" = None
    prefill_len: int | None = None
    # bucket identity
    cfg: "ArchConfig | None" = None
    n_slots: int | None = None
    max_len: int | None = None
    # serve-loop identity (artifact.serve_fingerprint payload): block size
    # + sampling knobs when the bucket targets the scan-block decode path,
    # page_size/page_pool when it targets the paged state backend;
    # None = the default single-wave greedy host loop
    serve_params: dict | None = None
    # paged state (None = symmetric max_len slot regions): fixed page size
    # in bytes, pool size in pages (None = n_slots * pages_per_slot), and
    # the per-leaf (slot_axis, token_axis) map from detect_state_axes
    page_size: int | None = None
    page_pool: int | None = None
    state_token_axes: dict | None = None
    # strategy / search knobs
    mode: str = "offsets"
    strategy: str = "auto"
    alignment: int = DEFAULT_ALIGNMENT
    search: bool = False
    search_iters: int = 300
    fusion_rounds: int = 40
    # plan-cache control
    cache: "plan_io.PlanCache | None" = None
    use_cache: bool = True
    graph_name: str = "records"


@dataclasses.dataclass
class SearchOutcome:
    """Search-path by-products that serving artifacts don't carry whole:
    the pre-search plan and the full order/fusion results."""

    greedy_plan: "MemoryPlan"
    order: "OrderSearchResult"
    fusion: "FusionSearchResult"


@dataclasses.dataclass
class UnifiedPlan:
    """Both halves of a serving bucket's memory plan under one fingerprint
    and one ``total_size``. ``activation`` may be None for a state-only
    spec (and vice versa)."""

    activation: "MemoryPlan | None"
    state: StatePlan | None
    fingerprint: str
    # searched-order / fusion provenance for the activation half (same
    # semantics as PlanBundle.order / .fusion_groups)
    order: list[int] | None = None
    fusion_groups: list[list[int]] | None = None
    provenance: dict = dataclasses.field(default_factory=dict)
    # search by-products; never serialized (bundles keep provenance only)
    search: SearchOutcome | None = None
    # planned prefill activation arena (PlanSpec.prefill_graph) — never
    # summed into total_size: prefill and decode are temporally disjoint,
    # so the prefill arena aliases the decode arena's address space
    prefill: "MemoryPlan | None" = None

    @property
    def total_size(self) -> int:
        total = 0
        if self.activation is not None:
            total += self.activation.total_size
        if self.state is not None:
            total += self.state.total_size
        return total

    @property
    def peak_activation_size(self) -> int:
        """Peak transient-arena demand across both phases (decode step vs
        full-sequence prefill, whichever arena is larger)."""
        act = self.activation.total_size if self.activation else 0
        pre = self.prefill.total_size if self.prefill else 0
        return max(act, pre)

    def arena_layouts(self) -> "tuple[ArenaLayout | None, ArenaLayout | None]":
        """Materialization view: (activation layout, state layout) — both
        arenas from this one object."""
        from repro.runtime.arena import ArenaLayout

        return (
            ArenaLayout.from_plan(self.activation)
            if self.activation is not None
            else None,
            ArenaLayout.from_state_plan(self.state)
            if self.state is not None
            else None,
        )

    def summary(self) -> str:
        lines = []
        if self.activation is not None:
            lines.append(self.activation.summary())
        if self.state is not None:
            lines.append(self.state.summary())
        if self.prefill is not None:
            lines.append(f"prefill {self.prefill.summary()}")
        lines.append(
            f"unified footprint: {self.total_size / 2**20:.3f} MiB "
            f"[{self.fingerprint[:12]}]"
        )
        return "\n".join(lines)


def _spec_fingerprint(spec: PlanSpec, records, state_records) -> str:
    """Content fingerprint for bucket-less specs: everything the unified
    output depends on. Bucketed specs use the config-level
    ``decode_fingerprint`` instead (shared with compiled bundles)."""
    payload = {
        "planner_revision": plan_io.PLANNER_REVISION,
        "mode": spec.mode,
        "strategy": spec.strategy,
        "search": spec.search,
        "records": plan_io.canonical_records(records) if records else None,
        "state": [
            [r.path, list(r.shape), r.dtype, r.nbytes]
            for r in (state_records or [])
        ],
        "n_slots": spec.n_slots,
        "max_len": spec.max_len,
    }
    if spec.serve_params:
        payload["serve_params"] = spec.serve_params
    if spec.page_size:
        payload["page_size"] = spec.page_size
        payload["page_pool"] = spec.page_pool
    if spec.prefill_len:
        payload["prefill_len"] = spec.prefill_len
    text = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(text.encode()).hexdigest()


def plan(spec: PlanSpec) -> UnifiedPlan:
    """THE planning entry point: activation half (with optional
    order/fusion search) + cross-step state half, one fingerprint, one
    total. Every other planner API is a wrapper over this."""
    from repro.core import planner

    records = None
    if spec.records is not None:
        records = list(spec.records)
    elif spec.graph is not None:
        records = spec.graph.usage_records(spec.alignment)
    if records is None and spec.state_records is None:
        raise ValueError(
            "empty PlanSpec: provide an activation graph/records, "
            "state_records, or both"
        )

    activation: "MemoryPlan | None" = None
    order: list[int] | None = None
    groups: list[list[int]] | None = None
    outcome: SearchOutcome | None = None
    provenance: dict = {}
    if records is not None:
        graph_name = spec.graph.name if spec.graph is not None else spec.graph_name
        activation = planner._plan_records_impl(
            records,
            mode=spec.mode,
            strategy=spec.strategy,
            graph_name=graph_name,
            cache=spec.cache,
            use_cache=spec.use_cache,
        )
        if spec.search:
            if spec.graph is None:
                raise ValueError("search=True needs a graph, not raw records")
            from repro.core.fusion_search import fusion_search
            from repro.core.order_search import search_order

            search_cache = (
                spec.cache if spec.cache is not None else plan_io.PlanCache()
            )
            order_res = search_order(
                spec.graph, iters=spec.search_iters, seed=0,
                strategy=spec.strategy, cache=search_cache,
            )
            fusion_res = fusion_search(
                spec.graph, strategy=spec.strategy,
                max_rounds=spec.fusion_rounds, cache=search_cache,
            )
            outcome = SearchOutcome(
                greedy_plan=activation, order=order_res, fusion=fusion_res
            )
            # both searches honor the never-worse contract; take the smaller
            if fusion_res.plan.total_size < activation.total_size and (
                fusion_res.plan.total_size <= order_res.plan.total_size
            ):
                activation = fusion_res.plan
                groups = [list(g) for g in fusion_res.groups]
            elif order_res.plan.total_size < activation.total_size:
                activation = order_res.plan
                order = list(order_res.order)
            provenance["search_stats"] = {
                **order_res.provenance(),
                **fusion_res.provenance(),
                "order_iters": spec.search_iters,
                "fusion_rounds": spec.fusion_rounds,
            }
        provenance.update(
            {
                "strategy_requested": spec.strategy,
                "search": spec.search,
                "records": len(records),
                "greedy_total_bytes": (
                    outcome.greedy_plan.total_size
                    if outcome is not None
                    else activation.total_size
                ),
                "searched_total_bytes": (
                    min(
                        outcome.order.plan.total_size,
                        outcome.fusion.plan.total_size,
                    )
                    if outcome is not None
                    else None
                ),
            }
        )
        if spec.graph is not None:
            provenance["graph_ops"] = len(spec.graph.ops)

    prefill: "MemoryPlan | None" = None
    if spec.prefill_graph is not None:
        prefill = planner._plan_records_impl(
            spec.prefill_graph.usage_records(spec.alignment),
            mode=spec.mode,
            strategy=spec.strategy,
            graph_name=spec.prefill_graph.name,
            cache=spec.cache,
            use_cache=spec.use_cache,
        )
        provenance["prefill_total_bytes"] = prefill.total_size
        provenance["prefill_records"] = len(prefill.records)
        if spec.prefill_len:
            provenance["prefill_len"] = spec.prefill_len

    state: StatePlan | None = None
    if spec.state_records is not None:
        if spec.n_slots is None or spec.max_len is None:
            raise ValueError("state_records need n_slots and max_len")
        if spec.page_size:
            state = plan_paged_state(
                spec.state_records,
                n_slots=spec.n_slots,
                max_len=spec.max_len,
                page_size=spec.page_size,
                page_pool=spec.page_pool,
                axes=spec.state_token_axes,
                alignment=spec.alignment,
            )
            provenance["page_size"] = state.page_size
            provenance["page_pool"] = state.n_pages_pool
        else:
            state = plan_state(
                spec.state_records,
                n_slots=spec.n_slots,
                max_len=spec.max_len,
                alignment=spec.alignment,
            )
        provenance["state_total_bytes"] = state.total_size
        provenance["state_leaves"] = len(state.leaves)

    if (
        spec.cfg is not None
        and spec.n_slots is not None
        and spec.max_len is not None
    ):
        from repro.core.artifact import decode_fingerprint

        fingerprint = decode_fingerprint(
            spec.cfg, n_slots=spec.n_slots, max_len=spec.max_len,
            serve_params=spec.serve_params,
            prefill_len=spec.prefill_len,
        )
    else:
        fingerprint = _spec_fingerprint(spec, records, spec.state_records)

    return UnifiedPlan(
        activation=activation,
        state=state,
        fingerprint=fingerprint,
        order=order,
        fusion_groups=groups,
        provenance=provenance,
        search=outcome,
        prefill=prefill,
    )


# ---------------------------------------------------------------- session


@dataclasses.dataclass
class Resolution:
    """What a :class:`PlanSession` hands the engine: the unified plan (or
    None — trace-and-plan fallback), the backing bundle when there is one,
    the effective serving bucket (``max_len`` and ``n_slots`` may both be
    >= requested when nearest-bucket selection picked a longer or
    wider-pool compiled bucket), a one-line warning for the report, and
    the spec knobs the fallback path should honor."""

    unified: UnifiedPlan | None
    bundle: "PlanBundle | None"
    source: str  # "bundle" | "spec" | "unresolved"
    warning: str | None
    max_len: int
    n_slots: int = 0  # 0 = the requested slot count
    spec: PlanSpec | None = None


class PlanSession:
    """The one plan source an engine serves from.

    ``from_manifest(dir)`` — compiled-artifact serving with bucket
    auto-selection: exact bucket first, else the admissible compiled
    bucket (``max_len >= requested`` and ``n_slots >= requested``, same
    arch/dtype) with the smallest unified footprint (pass
    ``nearest=False`` for exact-only). ``from_bundle`` — one bundle file
    or object. ``from_spec`` — plan on demand from a :class:`PlanSpec`
    (pre-searched graphs, pinned strategies); an empty spec defers to the
    engine's own trace. ``verify_graph=True`` asks the engine to check the
    bundle's structural graph fingerprint against a fresh trace (trades
    the zero-trace cold start for a model-code-drift check)."""

    def __init__(
        self,
        *,
        manifest_dir: str | Path | None = None,
        bundle: "PlanBundle | str | Path | None" = None,
        spec: PlanSpec | None = None,
        nearest: bool = True,
        verify_graph: bool = False,
    ):
        sources = [manifest_dir is not None, bundle is not None, spec is not None]
        if sum(sources) != 1:
            raise ValueError(
                "PlanSession takes exactly one source: manifest_dir, "
                "bundle, or spec"
            )
        self.manifest_dir = Path(manifest_dir) if manifest_dir else None
        self.bundle = bundle
        self.spec = spec
        self.nearest = nearest
        self.verify_graph = verify_graph

    @classmethod
    def from_manifest(
        cls, directory: str | Path, *, nearest: bool = True,
        verify_graph: bool = False,
    ) -> "PlanSession":
        return cls(
            manifest_dir=directory, nearest=nearest, verify_graph=verify_graph
        )

    @classmethod
    def from_bundle(
        cls, bundle: "PlanBundle | str | Path", *, verify_graph: bool = False
    ) -> "PlanSession":
        return cls(bundle=bundle, verify_graph=verify_graph)

    @classmethod
    def from_spec(cls, spec: PlanSpec) -> "PlanSession":
        return cls(spec=spec)

    def resolve(
        self, cfg: "ArchConfig", *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        """``serve_params`` is the engine's serve-loop fingerprint payload
        (``artifact.serve_fingerprint``) — None for the default greedy
        host loop; bundles compiled for a different serving configuration
        fail the fingerprint check and fall back."""
        if self.spec is not None:
            return self._resolve_spec(
                cfg, n_slots=n_slots, max_len=max_len,
                serve_params=serve_params,
            )
        return self._resolve_bundle(
            cfg, n_slots=n_slots, max_len=max_len, serve_params=serve_params
        )

    def _resolve_spec(
        self, cfg, *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        spec = dataclasses.replace(
            self.spec, cfg=cfg, n_slots=n_slots, max_len=max_len,
            serve_params=(
                serve_params if serve_params is not None
                else self.spec.serve_params
            ),
        )
        if spec.graph is None and spec.records is None:
            # knobs only — the engine traces, then plans with these knobs
            return Resolution(
                unified=None, bundle=None, source="spec", warning=None,
                max_len=max_len, n_slots=n_slots, spec=spec,
            )
        return Resolution(
            unified=plan(spec), bundle=None, source="spec", warning=None,
            max_len=max_len, n_slots=n_slots, spec=spec,
        )

    def _resolve_bundle(
        self, cfg, *, n_slots: int, max_len: int,
        serve_params: dict | None = None,
    ) -> Resolution:
        from repro.core import artifact

        nearest = self.nearest and self.manifest_dir is not None
        source = self.bundle if self.bundle is not None else self.manifest_dir
        # paged engines resolve within their own |page{P} bucket family
        page_size = (serve_params or {}).get("page_size")
        try:
            bundle = artifact.resolve_bundle(
                source, cfg, n_slots=n_slots, max_len=max_len,
                nearest=nearest, page_size=page_size,
            )
        except Exception as e:
            # a bad artifact degrades to plan-at-construction, never
            # crashes serving (whatever a corrupt or adversarially
            # malformed document raises)
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=f"plan bundle unusable ({e}); "
                        f"planned at construction instead",
                max_len=max_len, n_slots=n_slots,
            )
        # Nearest-bucket mode verifies the bundle against ITS OWN bucket
        # (serving max_len >= requested — and, since the slot pool is the
        # §4 shared-objects set, n_slots >= requested — is the point of
        # auto-selection); strict mode (single bundles, exact-only
        # manifests) keeps the requested bucket as the expectation.
        if nearest and (bundle.max_len < max_len or bundle.n_slots < n_slots):
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=(
                    f"plan bundle compiled for slots={bundle.n_slots} "
                    f"len={bundle.max_len} < requested slots={n_slots} "
                    f"len={max_len}; planned at construction instead"
                ),
                max_len=max_len, n_slots=n_slots,
            )
        verify_len = bundle.max_len if nearest else max_len
        verify_slots = bundle.n_slots if nearest else n_slots
        # a prefill-carrying bundle verifies against its OWN prefill_len
        # (the prefill plan is inert extra metadata on the decode path,
        # exactly like a longer max_len under nearest selection); v3-shim
        # and decode-only bundles carry 0 → None-canonicalized away
        expect = artifact.decode_fingerprint(
            cfg, n_slots=verify_slots, max_len=verify_len,
            serve_params=serve_params,
            prefill_len=bundle.prefill_len or None,
        )
        if bundle.fingerprint != expect:
            return Resolution(
                unified=None, bundle=None, source="unresolved",
                warning=(
                    f"plan bundle fingerprint mismatch (bundle "
                    f"{str(bundle.fingerprint)[:12]}, engine {expect[:12]}); "
                    f"planned at construction instead"
                ),
                max_len=max_len, n_slots=n_slots,
            )
        return Resolution(
            unified=artifact.unified_from_bundle(bundle),
            bundle=bundle,
            source="bundle",
            warning=None,
            max_len=max(bundle.max_len, max_len) if nearest else max_len,
            n_slots=max(bundle.n_slots, n_slots) if nearest else n_slots,
        )
