"""Static analysis for plans, compiled decode programs, and bundles.

The paper's value proposition is that intentionally aliased buffers are
*safe*: two tensors may share bytes only when their usage intervals are
disjoint (§3–§4). This package is the independent correctness tooling
behind that claim — planner-independent certifiers and lints that run
ahead of time, so every future planner or serving change is checked
statically instead of trusted dynamically:

* :mod:`~repro.analysis.soundness` — the plan soundness certifier: an
  O(n log n) sweep-line re-derivation of liveness + arena disjointness
  (plus StatePlan bounds/alignment/disjointness) that shares zero code
  with ``core/interval_set`` or the planners, differential-matched
  against the O(n²) oracle in ``core/validate``;
* :mod:`~repro.analysis.decode_lint` — static inspection of the lowered
  decode step / scan block: donation aliasing, host transfers, and
  whole-state-buffer copies, ahead of time instead of via runtime
  counters;
* :mod:`~repro.analysis.bundle_lint` — audits a published
  ``BundleManifest``: fingerprint coherence, stale revisions, format
  drift, content addressing, bucket coverage gaps;
* :mod:`~repro.analysis.counters` — one registry over the process-wide
  instrumentation counters (TRACE_CALLS / PLAN_CALLS / STATE_PLAN_CALLS /
  HOST_SYNCS) with a snapshot/capture API;
* ``python -m repro.analysis.lint`` — the CLI over all three passes;
  ``launch/compile.py`` runs the soundness + bundle passes as a
  default-on pre-publish gate (``--no-lint`` to skip).
"""

from repro.analysis.findings import Finding, LintGateError, Report

__all__ = ["Finding", "LintGateError", "Report"]
