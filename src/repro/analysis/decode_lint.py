"""Static lint of the compiled decode step and scan block.

The serving invariants — the residency buffer's donation really aliases
input to output, decode never round-trips through the host, the scan
block is a single rolled loop — were previously only observable at
runtime (HOST_SYNCS deltas, ``live_bytes`` checks). This pass proves
them ahead of time from the compiled executable's HLO text:

* **donation aliasing** — the ``u8[total_size]`` state parameter must
  appear in the module's ``input_output_alias`` table; a silently
  dropped donation doubles peak state memory and breaks the
  planned-layout-is-live-layout contract (error);
* **host transfers** — no outfeed/infeed/send/recv, no host memory
  space (``S(5)``) shapes, no host-placement custom-calls (error);
* **state-buffer copies/converts** — plain ``copy``/``convert`` ops the
  size of the whole state buffer. On the CPU backend the scan body is
  known to emit a bounded number of full-buffer copies around its
  nested scatter loops even with donation intact, so these report as
  warnings with their location, not errors;
* **scan shape** — the block must lower to one ``while`` with the
  expected known trip count; a missing loop means XLA unrolled (and
  rematerialized) the body, a wrong count means the block traced at the
  wrong length (error).

Programs are lowered shape-level (``jax.eval_shape`` for params; no
weights are materialized) through the *same* impl factories the serving
backend jits (``runtime/residency.resident_decode_impl`` & co.), so the
lint inspects the real decode program, not a stand-in.

:func:`lint_executables` applies the same checks to a v3 bundle's
AOT-serialized executables *after* deserialization — proving the
donation aliasing (and the absence of host transfers) survived the
serialize→bundle→deserialize round trip, which is the publish gate's
last step before a pack ships.
"""

from __future__ import annotations

import dataclasses
import re

from repro.analysis.findings import Finding, Report

PASS = "decode_lint"

_HOST_OPCODES = {
    "outfeed", "infeed", "send", "recv", "send-done", "recv-done",
}
# custom-call targets that move data to host memory
_HOST_CALL_RE = re.compile(r"MoveToHost|PinToHost|annotate_device_placement")
_HOST_SPACE_RE = re.compile(r"S\(5\)")
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(may-alias|must-alias)\)"
)
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _finding(code, message, where="", severity="error") -> Finding:
    return Finding(
        pass_name=PASS, code=code, message=message, where=where,
        severity=severity,
    )


def _called_name(inst) -> str | None:
    m = re.search(r"calls=(%[\w.\-]+)", inst.attrs)
    return m.group(1) if m else None


def parse_alias_table(hlo_text: str) -> list[tuple[tuple[int, ...], int, str]]:
    """The module-level ``input_output_alias`` table:
    [(output index, parameter number, kind)]."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias=")
    depth = 0
    j = i
    while j < len(hlo_text):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    block = hlo_text[i : j + 1]
    out = []
    for m in _ALIAS_ENTRY_RE.finditer(block):
        idx = tuple(
            int(x) for x in m.group(1).replace(" ", "").split(",") if x
        )
        out.append((idx, int(m.group(2)), m.group(3)))
    return out


@dataclasses.dataclass
class DecodeProgram:
    """One lowered+compiled decode program ready for linting."""

    label: str  # e.g. "qwen3-0.6b:step" / "qwen3-0.6b:block8"
    hlo: str  # compiled.as_text()
    state_nbytes: int  # StatePlan.total_size — identifies the buffer
    expect_trip: int | None = None  # scan length for block programs


def lint_program(prog: DecodeProgram) -> list[Finding]:
    """All static checks over one compiled decode program's HLO."""
    from repro.launch.hlo_analysis import _type_bytes, parse_hlo

    findings: list[Finding] = []
    comps, entry = parse_hlo(prog.hlo)
    if entry is None:
        return [_finding("hlo-unparseable", "no entry computation found",
                         prog.label)]

    # --- the state buffer parameter and its donation
    state_params = [
        int(inst.raw_operands)
        for inst in comps[entry].instructions
        if inst.opcode == "parameter"
        and inst.result_type.startswith("u8")
        and _type_bytes(inst.result_type) == prog.state_nbytes
    ]
    if not state_params:
        findings.append(
            _finding(
                "state-param-missing",
                f"no u8[{prog.state_nbytes}] parameter in the entry "
                f"computation — the state buffer is not an input of the "
                f"compiled program",
                prog.label,
            )
        )
    aliased = {param for _idx, param, _kind in parse_alias_table(prog.hlo)}
    for param in state_params:
        if param not in aliased:
            findings.append(
                _finding(
                    "state-not-donated",
                    f"state buffer (parameter {param}, "
                    f"{prog.state_nbytes} B) absent from the "
                    f"input_output_alias table: donation did not alias, "
                    f"decode double-buffers the whole state",
                    prog.label,
                )
            )

    # --- host transfers + whole-buffer copies/converts, everywhere.
    # Copies/converts inside fusion bodies stay in registers/VMEM (see
    # hlo_analysis byte accounting) — only un-fused ones materialize, so
    # only those are scanned; while bodies/conds are not exempt.
    fusion_bodies = {
        _called_name(inst)
        for comp in comps.values()
        for inst in comp.instructions
        if inst.opcode == "fusion"
    }
    copy_sites: list[str] = []
    for comp in comps.values():
        for inst in comp.instructions:
            where = f"{prog.label}:{comp.name}{inst.name}"
            if inst.opcode in _HOST_OPCODES:
                findings.append(
                    _finding(
                        "host-transfer",
                        f"{inst.opcode} in compiled decode — device/host "
                        f"round-trip inside the hot path",
                        where,
                    )
                )
            elif inst.opcode == "custom-call" and _HOST_CALL_RE.search(
                inst.attrs
            ):
                findings.append(
                    _finding(
                        "host-transfer",
                        "host-placement custom-call in compiled decode",
                        where,
                    )
                )
            elif _HOST_SPACE_RE.search(inst.result_type):
                findings.append(
                    _finding(
                        "host-transfer",
                        f"host memory space shape {inst.result_type}",
                        where,
                    )
                )
            if (
                inst.opcode in ("copy", "convert")
                and comp.name not in fusion_bodies
                and _type_bytes(inst.result_type) == prog.state_nbytes
            ):
                copy_sites.append(f"{comp.name}{inst.name}[{inst.opcode}]")
    if copy_sites:
        findings.append(
            _finding(
                "state-buffer-copy",
                f"{len(copy_sites)} whole-state-buffer copy/convert op(s): "
                f"{', '.join(copy_sites[:4])}"
                f"{'...' if len(copy_sites) > 4 else ''} — known bounded "
                f"CPU-backend artifact around the scan body's scatter "
                f"loops; on an accelerator this should be zero",
                prog.label,
                severity="warning",
            )
        )

    # --- scan shape (block programs only)
    if prog.expect_trip is not None:
        from repro.launch.hlo_analysis import _trip_from_literals

        trips: list[int | None] = []
        for comp in comps.values():
            for inst in comp.instructions:
                if inst.opcode != "while":
                    continue
                m = _TRIP_RE.search(inst.attrs)
                if m:
                    trips.append(int(m.group(1)))
                    continue
                cond = re.search(r"condition=(%[\w.\-]+)", inst.attrs)
                trips.append(
                    _trip_from_literals(comps[cond.group(1)], comps)
                    if cond and cond.group(1) in comps
                    else None
                )
        if not trips:
            findings.append(
                _finding(
                    "scan-unrolled",
                    f"no while loop in the compiled block — XLA unrolled "
                    f"(rematerialized) the {prog.expect_trip}-wave scan "
                    f"body",
                    prog.label,
                )
            )
        elif prog.expect_trip not in [t for t in trips if t is not None]:
            known = sorted({t for t in trips if t is not None})
            if known:
                findings.append(
                    _finding(
                        "scan-trip-mismatch",
                        f"no while loop runs the expected {prog.expect_trip} "
                        f"waves (known trip counts: {known})",
                        prog.label,
                    )
                )
            else:
                findings.append(
                    _finding(
                        "scan-trip-unknown",
                        "while loop trip count is not statically known",
                        prog.label,
                        severity="warning",
                    )
                )
    return findings


# ------------------------------------------------------- lowering drivers


def lower_decode_programs(
    arch: str,
    *,
    n_slots: int = 2,
    max_len: int = 32,
    block: int | None = 8,
    greedy: bool = True,
) -> list[DecodeProgram]:
    """Lower+compile the decode step (and, with ``block``, the scan
    block) for ``arch``'s reduced config, shape-level: params come from
    ``jax.eval_shape`` and the state buffer is an aval — no weights, no
    cache, no device state is materialized. The impl functions are the
    same ones ``ResidentState`` jits, with the same donation."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_reduced
    from repro.core.unified import plan_state, state_records_from_pytree
    from repro.models.api import Model
    from repro.runtime.residency import (
        BLOCK_DONATE,
        DECODE_DONATE,
        StateResidency,
        resident_block_impl,
        resident_decode_impl,
    )
    from repro.runtime.sampling import SamplingParams, TokenSampler

    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    caches = jax.eval_shape(lambda: model.init_cache(n_slots, max_len))
    sp = plan_state(
        state_records_from_pytree(caches, n_slots=n_slots),
        n_slots=n_slots,
        max_len=max_len,
    )
    resid = StateResidency(sp, caches, n_slots=n_slots)
    params_aval = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    buf_aval = jax.ShapeDtypeStruct((sp.total_size,), jnp.uint8)
    tok_aval = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
    vec_i32 = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    vec_bool = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)
    keys_aval = jax.ShapeDtypeStruct((n_slots, 2), jnp.uint32)
    eos_aval = jax.ShapeDtypeStruct((), jnp.int32)

    programs = [
        DecodeProgram(
            label=f"{arch}:step",
            hlo=jax.jit(
                resident_decode_impl(model, resid),
                donate_argnums=DECODE_DONATE,
            )
            .lower(params_aval, tok_aval, buf_aval, vec_i32, vec_bool)
            .compile()
            .as_text(),
            state_nbytes=sp.total_size,
        )
    ]

    if block is not None:
        sampler = TokenSampler(
            SamplingParams(greedy=greedy), max_len=max_len
        )
        programs.append(
            DecodeProgram(
                label=f"{arch}:block{block}",
                hlo=jax.jit(
                    resident_block_impl(model, resid, sampler, block),
                    donate_argnums=BLOCK_DONATE,
                )
                .lower(params_aval, buf_aval, tok_aval, vec_i32, vec_bool,
                       vec_bool, vec_i32, keys_aval, eos_aval)
                .compile()
                .as_text(),
                state_nbytes=sp.total_size,
                expect_trip=block,
            )
        )
    return programs


_BLOCK_ENTRY_RE = re.compile(r"(?:resident|paged)_block_(\d+)")


def lint_executables(bundle) -> list[Finding]:
    """Audit a v3 bundle's AOT executables AFTER deserialization: every
    entry must load, and the residency-backend entries must still carry
    the state-buffer donation aliasing (plus the host-transfer and scan
    checks of :func:`lint_program`) — proving serialization preserved
    the properties the publish gate certified on the live ``Compiled``.
    Presence/key-coherence checks that need no jax live in
    ``bundle_lint``; this pass loads executables, so it runs only where
    the pack's platform matches (the compile gate, same-platform
    audits)."""
    pack = getattr(bundle, "executables", None)
    if pack is None:
        return []
    from repro.runtime.aot import deserialize_compiled

    findings: list[Finding] = []
    sp = bundle.state_plan
    # Paged buckets donate the *physical* pool buffer (null page + pool
    # pages), not the logical symmetric region — lint against that size.
    state_nbytes = 0
    if sp is not None:
        state_nbytes = (
            sp.phys_total_size
            if getattr(sp, "page_size", None) is not None
            else sp.total_size
        )
    for name, entry in sorted(pack.entries.items()):
        label = f"{bundle.arch}:{name}"
        try:
            hlo = deserialize_compiled(entry.payload).as_text()
        except Exception as e:
            findings.append(
                _finding(
                    "executable-load-failed",
                    f"AOT executable failed to deserialize on its own "
                    f"platform ({type(e).__name__}: {e})",
                    label,
                )
            )
            continue
        if not name.startswith(("resident_", "paged_")):
            continue  # pytree entries have no donated state buffer
        m = _BLOCK_ENTRY_RE.fullmatch(name)
        findings.extend(
            lint_program(
                DecodeProgram(
                    label=label,
                    hlo=hlo,
                    state_nbytes=state_nbytes,
                    expect_trip=int(m.group(1)) if m else None,
                )
            )
        )
    return findings


def lint_arch(
    arch: str,
    *,
    n_slots: int = 2,
    max_len: int = 32,
    block: int | None = 8,
    greedy: bool = True,
) -> Report:
    """Lower and lint every decode program for one architecture."""
    report = Report()
    for prog in lower_decode_programs(
        arch, n_slots=n_slots, max_len=max_len, block=block, greedy=greedy
    ):
        report.extend(lint_program(prog), checked=prog.label)
    return report


__all__ = [
    "DecodeProgram",
    "lint_arch",
    "lint_executables",
    "lint_program",
    "lower_decode_programs",
    "parse_alias_table",
]
