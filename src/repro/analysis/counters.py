"""One registry over the process-wide instrumentation counters.

The repo instruments its hot paths with module-global counters
(``tracer.TRACE_CALLS``, ``planner.PLAN_CALLS``,
``unified.STATE_PLAN_CALLS``, ``engine.HOST_SYNCS``,
``residency.COMPILE_CALLS``) that tests, CI and
benches snapshot/delta to pin caching and sync behaviour. Before this
module each call site hand-rolled the same
``t0, p0, s0 = tracer.TRACE_CALLS, planner.PLAN_CALLS, ...`` boilerplate;
here they are one named registry:

    from repro.analysis import counters

    with counters.capture() as cap:
        engine.generate(...)
    assert cap.delta("trace_calls") == 0
    assert cap.delta("host_syncs") == 1

Counters are looked up lazily by (module, attribute) so importing this
module does not drag in jax via ``repro.runtime.engine``.
"""

from __future__ import annotations

import contextlib
import importlib
from typing import Iterator

# name -> (module, attribute) holding an int module-global
REGISTRY: dict[str, tuple[str, str]] = {
    "trace_calls": ("repro.trace.jaxpr_liveness", "TRACE_CALLS"),
    "plan_calls": ("repro.core.planner", "PLAN_CALLS"),
    "state_plan_calls": ("repro.core.unified", "STATE_PLAN_CALLS"),
    "host_syncs": ("repro.runtime.engine", "HOST_SYNCS"),
    "compile_calls": ("repro.runtime.residency", "COMPILE_CALLS"),
}


def _module(name: str):
    mod_name, _ = REGISTRY[name]
    return importlib.import_module(mod_name)


def read(name: str) -> int:
    """Current value of one registered counter."""
    mod_name, attr = REGISTRY[name]
    return getattr(importlib.import_module(mod_name), attr)


def snapshot(names: tuple[str, ...] | None = None) -> dict[str, int]:
    """Read every (or the named) registered counters at once."""
    return {n: read(n) for n in (names or tuple(REGISTRY))}


def reset(names: tuple[str, ...] | None = None) -> None:
    """Zero the named counters (all by default)."""
    for n in names or tuple(REGISTRY):
        _, attr = REGISTRY[n]
        setattr(_module(n), attr, 0)


class Capture:
    """Deltas of the registered counters since ``capture()`` entry."""

    def __init__(self, names: tuple[str, ...]):
        self.names = names
        self.start = snapshot(names)

    def delta(self, name: str) -> int:
        return read(name) - self.start[name]

    def deltas(self) -> dict[str, int]:
        return {n: self.delta(n) for n in self.names}


@contextlib.contextmanager
def capture(*names: str) -> Iterator[Capture]:
    """Snapshot counters on entry; ``cap.delta(name)`` reads live deltas.

    With no arguments captures every registered counter. Does not reset
    the underlying globals — deltas are relative to entry, so captures
    nest safely.
    """
    yield Capture(names or tuple(REGISTRY))
