"""Audit published plan bundles and their manifest index.

A :class:`~repro.core.artifact.BundleManifest` directory is the serving
fleet's source of truth — a stale or incoherent entry silently degrades
every engine that resolves through it (wrong plan, or a fingerprint miss
that falls back to plan-at-construction on every cold start). This pass
re-derives what the index claims:

* **content addressing** — ``bundle-<sha16>.json`` must be named by the
  sha256 of its canonical encoding (error);
* **index coherence** — every bucket entry's file exists, loads, and its
  ``fingerprint`` / ``total_size`` / ``unified_total`` match the bundle
  document; the bucket key's shape fields match the bundle's own (error);
* **fingerprint freshness** — the stored fingerprint is recomputed from
  the current config registry + ``PIPELINE_REVISION`` +
  ``PLANNER_REVISION``; a mismatch means the bundle predates a pipeline
  or planner rev (or the config changed) and will be refused at serving
  time — recompile (error);
* **format drift** — v1 documents still load but carry no state plan
  and can never match a current engine's fingerprint; v2 documents load
  and serve but carry no AOT executables, so every cold start pays the
  lazy decode compile (both warnings); unknown newer versions are
  errors;
* **executable coherence** — a v3 bundle's AOT pack must record its
  platform + jax-version key, its entry payloads must match their
  stored sha256/nbytes, and the entry set must be complete for the
  bucket's serve configuration (a missing entry silently lazy-compiles
  that one function, quietly breaking the zero-compile guarantee). All
  jax-free; the deserialize-and-relint audit (donation aliasing
  preserved through serialization) is
  ``decode_lint.lint_executables``'s job at publish time;
* **bucket coverage gaps** — within one (arch, layers, width, dtype)
  family the sweep grid should be the full cross product of its observed
  slot counts and cache lengths; holes mean some serving shapes fall
  back while their neighbors are compiled (warning).

Plan *soundness* (offsets/state collisions) is
:func:`repro.analysis.soundness.certify_bundle`'s job; the CLI and the
publish gate run both.
"""

from __future__ import annotations

import hashlib
import json
import warnings
from pathlib import Path

from repro.analysis.findings import Finding, Report

PASS = "bundle_lint"


def _finding(code, message, where="", severity="error") -> Finding:
    return Finding(
        pass_name=PASS, code=code, message=message, where=where,
        severity=severity,
    )


def _config_candidates(bundle):
    """Current configs that could have produced this bundle: the full and
    reduced variants of its arch (they share ``cfg.name``), with the
    bundle's dtype applied (sweeps compile dtype variants)."""
    import dataclasses

    from repro.configs.base import get_config, get_reduced

    out = []
    for getter in (get_config, get_reduced):
        try:
            cfg = getter(bundle.arch)
        except (KeyError, ValueError):
            continue
        if cfg.dtype != bundle.dtype:
            cfg = dataclasses.replace(cfg, dtype=bundle.dtype)
        if (cfg.n_layers, cfg.d_model) == (bundle.n_layers, bundle.d_model):
            out.append(cfg)
    return out


def lint_bundle(
    bundle, *, label: str = "", serve_params: dict | None = None
) -> list[Finding]:
    """Coherence checks on one loaded bundle: current-revision
    fingerprint freshness and internal shape consistency."""
    from repro.core.artifact import decode_fingerprint

    findings: list[Finding] = []
    where = label or f"{bundle.arch}|slots{bundle.n_slots}|len{bundle.max_len}"

    if serve_params is None:
        serve_params = (bundle.provenance or {}).get("serve_params")
    candidates = _config_candidates(bundle)
    if not candidates:
        findings.append(
            _finding(
                "unknown-config",
                f"no current config named {bundle.arch!r} with "
                f"L{bundle.n_layers}/d{bundle.d_model} — freshness "
                f"unverifiable (foreign or renamed architecture)",
                where,
                severity="warning",
            )
        )
    elif not any(
        decode_fingerprint(
            cfg,
            n_slots=bundle.n_slots,
            max_len=bundle.max_len,
            serve_params=serve_params,
            prefill_len=bundle.prefill_len or None,
        )
        == bundle.fingerprint
        for cfg in candidates
    ):
        findings.append(
            _finding(
                "fingerprint-stale",
                "stored fingerprint does not match a recomputation from "
                "the current config + PIPELINE/PLANNER revisions — the "
                "bundle predates a revision bump or config change and "
                "every engine resolving it will fall back; recompile",
                where,
            )
        )

    if bundle.state_plan is None:
        findings.append(
            _finding(
                "no-state-plan",
                "bundle carries no cross-step state plan (format v1 shim) "
                "— serving engines must re-plan the state half",
                where,
                severity="warning",
            )
        )
    elif bundle.state_plan.n_slots != bundle.n_slots:
        findings.append(
            _finding(
                "state-slots-mismatch",
                f"state plan lays out {bundle.state_plan.n_slots} slots, "
                f"bundle bucket says {bundle.n_slots}",
                where,
            )
        )
    if (
        bundle.state_plan is not None
        and bundle.state_plan.max_len != bundle.max_len
    ):
        findings.append(
            _finding(
                "state-len-mismatch",
                f"state plan is for cache length "
                f"{bundle.state_plan.max_len}, bundle bucket says "
                f"{bundle.max_len}",
                where,
            )
        )
    plan_page = getattr(bundle.state_plan, "page_size", None)
    serve_page = (serve_params or {}).get("page_size")
    if bundle.state_plan is not None and plan_page != serve_page:
        findings.append(
            _finding(
                "paged-meta-mismatch",
                f"serve config says page_size={serve_page}, state plan "
                f"carries page_size={plan_page} — a paged engine "
                f"resolving this bucket would bind the wrong backend",
                where,
            )
        )

    # v4 prefill coherence: the bucket's prefill_len and the carried
    # prefill plan must agree — a plan without its length (or vice versa)
    # means the fingerprint and the bucket key disagree about what was
    # compiled
    if bool(bundle.prefill_len) != (bundle.prefill_plan is not None):
        findings.append(
            _finding(
                "prefill-meta-mismatch",
                f"bundle says prefill_len={bundle.prefill_len} but "
                f"{'carries no' if bundle.prefill_plan is None else 'carries a'} "
                f"prefill plan — prefill metadata and payload disagree",
                where,
            )
        )

    pack = bundle.executables
    if pack is not None:
        from repro.core.artifact import expected_executable_entries

        if not pack.platform or not pack.jax_version:
            findings.append(
                _finding(
                    "executable-key-missing",
                    f"AOT pack records platform={pack.platform!r} "
                    f"jax_version={pack.jax_version!r} — without both "
                    f"keys a serving process cannot refuse a stale or "
                    f"cross-platform executable",
                    where,
                )
            )
        block = int((serve_params or {}).get("block_size", 1))
        missing = sorted(
            set(expected_executable_entries(block, paged=bool(serve_page)))
            - set(pack.entries)
        )
        if missing:
            findings.append(
                _finding(
                    "executable-missing",
                    f"AOT pack is incomplete for this bucket's serve "
                    f"configuration: missing {missing} — those functions "
                    f"would silently lazy-compile at serving time; "
                    f"recompile",
                    where,
                )
            )
        for name, entry in sorted(pack.entries.items()):
            if (
                hashlib.sha256(entry.payload).hexdigest() != entry.sha256
                or entry.nbytes != len(entry.payload)
            ):
                findings.append(
                    _finding(
                        "executable-corrupt",
                        f"AOT executable {name!r} payload does not match "
                        f"its stored sha256/nbytes — corrupted or edited "
                        f"in place",
                        where,
                    )
                )
    return findings


def lint_bundle_file(path: str | Path, *, label: str = "") -> list[Finding]:
    """One ``bundle-*.json`` on disk: format version, content address,
    then :func:`lint_bundle` on the loaded document."""
    from repro.core.artifact import (
        BUNDLE_FORMAT_VERSION,
        bundle_from_obj,
        bundle_to_json,
    )

    path = Path(path)
    where = label or path.name
    try:
        obj = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [
            _finding(
                "unreadable-bundle",
                f"cannot read bundle document: {e}",
                where,
            )
        ]
    version = obj.get("format_version") if isinstance(obj, dict) else None
    if version == 1:
        findings = [
            _finding(
                "format-drift",
                "format v1 document (activation half only) — cannot match "
                "a current engine's fingerprint; recompile",
                where,
                severity="warning",
            )
        ]
    elif version == 2:
        findings = [
            _finding(
                "format-drift",
                "format v2 document (no AOT executables) — still serves, "
                "but every cold start pays the lazy decode compile; "
                "recompile for zero-compile cold start",
                where,
                severity="warning",
            )
        ]
    elif version == 3:
        findings = [
            _finding(
                "format-drift",
                "format v3 document (no planned prefill arena) — still "
                "serves with zero compiles; recompile with --prefill-len "
                "to carry the full-sequence prefill plan",
                where,
                severity="warning",
            )
        ]
    elif version != BUNDLE_FORMAT_VERSION:
        return [
            _finding(
                "format-unknown",
                f"unsupported format version {version!r} (this build reads "
                f"1-{BUNDLE_FORMAT_VERSION})",
                where,
            )
        ]
    else:
        findings = []
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            bundle = bundle_from_obj(obj)
    except Exception as e:
        findings.append(
            _finding("unreadable-bundle", f"document does not load: {e}",
                     where)
        )
        return findings

    # content address: the filename commits to the canonical bytes
    if version == BUNDLE_FORMAT_VERSION and path.name.startswith("bundle-"):
        sha = hashlib.sha256(bundle_to_json(bundle).encode()).hexdigest()
        want = f"bundle-{sha[:16]}.json"
        if path.name != want:
            findings.append(
                _finding(
                    "content-address-mismatch",
                    f"file is named {path.name} but its canonical content "
                    f"hashes to {want} — edited in place or corrupted",
                    where,
                )
            )
    findings.extend(lint_bundle(bundle, label=where))
    return findings


def _coverage_gaps(keys: list[str]) -> list[Finding]:
    """Within each (arch, layers, width, dtype) family, report missing
    cells of the observed slots × max_len grid."""
    from repro.core.artifact import parse_bucket_key

    families: dict[tuple, set[tuple[int, int]]] = {}
    for key in keys:
        got = parse_bucket_key(key)
        if got is None:
            continue
        # paged/symmetric and prefill/decode-only buckets are separate
        # families: their grids are swept (and served) independently
        fam = (
            got["arch"], got["n_layers"], got["d_model"], got["dtype"],
            got.get("page_size"), got.get("prefill_len"),
        )
        families.setdefault(fam, set()).add((got["n_slots"], got["max_len"]))
    findings = []
    for fam, cells in sorted(
        families.items(), key=lambda kv: tuple(map(str, kv[0]))
    ):
        slots = sorted({s for s, _ in cells})
        lens = sorted({l for _, l in cells})
        missing = [
            (s, l) for s in slots for l in lens if (s, l) not in cells
        ]
        if missing:
            page = f"|page{fam[4]}" if fam[4] else ""
            findings.append(
                _finding(
                    "coverage-gap",
                    f"sweep grid incomplete: compiled slots {slots} x "
                    f"lens {lens} but missing "
                    f"{['slots%d|len%d' % m for m in missing]}",
                    f"{fam[0]}|L{fam[1]}|d{fam[2]}|{fam[3]}{page}",
                    severity="warning",
                )
            )
    return findings


def lint_manifest(directory: str | Path) -> Report:
    """Audit a whole manifest directory: the index against the bundle
    files, every reachable bundle document, and the sweep coverage."""
    from repro.core.artifact import (
        BundleManifest,
        bundle_bucket_key,
        load_bundle,
    )

    report = Report()
    directory = Path(directory)
    manifest = BundleManifest(directory)
    try:
        buckets = manifest.buckets()
    except Exception as e:
        return report.extend(
            [_finding("index-unreadable", f"manifest index unusable: {e}",
                      str(directory))],
            checked=str(directory),
        )

    seen_files: set[str] = set()
    for key, entry in sorted(buckets.items()):
        fname = entry.get("file", "")
        fpath = directory / fname
        if not fpath.is_file():
            report.extend(
                [_finding("missing-file",
                          f"index points at {fname} which does not exist",
                          key)],
                checked=key,
            )
            continue
        findings = []
        if fname not in seen_files:
            seen_files.add(fname)
            findings += lint_bundle_file(fpath, label=fname)
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                bundle = load_bundle(fpath)
        except Exception:
            report.extend(findings, checked=key)
            continue  # unreadable: already reported by lint_bundle_file
        if entry.get("fingerprint") != bundle.fingerprint:
            findings.append(
                _finding(
                    "index-fingerprint-mismatch",
                    f"index fingerprint {str(entry.get('fingerprint'))[:12]} "
                    f"!= bundle {bundle.fingerprint[:12]}",
                    key,
                )
            )
        if entry.get("total_size") != bundle.plan.total_size:
            findings.append(
                _finding(
                    "index-total-mismatch",
                    f"index total_size {entry.get('total_size')} != plan "
                    f"{bundle.plan.total_size}",
                    key,
                )
            )
        if (
            "unified_total" in entry
            and entry["unified_total"] != bundle.total_size
        ):
            findings.append(
                _finding(
                    "index-total-mismatch",
                    f"index unified_total {entry['unified_total']} != "
                    f"bundle {bundle.total_size}",
                    key,
                )
            )
        canonical = bundle_bucket_key(bundle)
        if canonical is not None and canonical != key:
            findings.append(
                _finding(
                    "bucket-key-mismatch",
                    f"index key does not match the bundle's own shape "
                    f"fields ({canonical})",
                    key,
                )
            )
        report.extend(findings, checked=key)

    report.extend(_coverage_gaps(list(buckets)), checked="coverage")
    return report


__all__ = [
    "lint_bundle",
    "lint_bundle_file",
    "lint_manifest",
]
