"""Findings model shared by every analysis pass.

A pass returns a flat list of :class:`Finding`; drivers collect them into
a :class:`Report`. Severity semantics:

* ``error``   — the artifact is unsound or would misbehave (memory
  collision, missing donation, stale fingerprint). Gates refuse on these.
* ``warning`` — suspicious but survivable (bucket coverage gap, known
  backend copy artifact, deprecated format). Gates refuse on these only
  under ``--strict``.
"""

from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning")


class LintGateError(RuntimeError):
    """A gate (pre-publish in ``launch/compile.py``, optional engine
    startup) refused an artifact over error-severity findings. Carries
    the full :class:`Report` so callers can render or serialize it."""

    def __init__(self, report: "Report", context: str = ""):
        self.report = report
        prefix = f"{context}: " if context else ""
        super().__init__(
            f"{prefix}{len(report.errors)} error-severity finding(s)\n"
            + report.render()
        )


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect surfaced by a pass.

    ``where`` locates the artifact (tensor ids, bucket key, HLO op name);
    ``code`` is the stable machine-readable check identifier the mutation
    harness and CI asserts key on.
    """

    pass_name: str  # "soundness" | "decode_lint" | "bundle_lint"
    code: str  # e.g. "arena-collision", "state-not-donated"
    message: str
    where: str = ""
    severity: str = "error"

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    def render(self) -> str:
        loc = f" @ {self.where}" if self.where else ""
        return (
            f"{self.severity.upper()} {self.pass_name}[{self.code}]{loc}: "
            f"{self.message}"
        )

    def to_obj(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """Findings from one or more passes over one or more artifacts."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    # pass/target labels that ran to completion (also when clean), so a
    # zero-findings report still shows WHAT was checked
    checked: list[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: list[Finding], *, checked: str | None = None):
        self.findings.extend(findings)
        if checked is not None:
            self.checked.append(checked)
        return self

    def merge(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        self.checked.extend(other.checked)
        return self

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def ok(self, *, strict: bool = False) -> bool:
        return not (self.findings if strict else self.errors)

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(
            f"{len(self.checked)} target(s) checked: "
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s)"
        )
        return "\n".join(lines)

    def to_obj(self) -> dict:
        return {
            "findings": [f.to_obj() for f in self.findings],
            "checked": list(self.checked),
            "errors": len(self.errors),
            "warnings": len(self.warnings),
        }
