"""Plan soundness certifier — the fast, independent twin of ``core/validate``.

Re-derives the paper's safety constraint from first principles: two
tensors may share bytes only if their usage intervals are disjoint
(arXiv 2001.03288 §3–§4). Where ``core/validate`` proves it by an O(n²)
pairwise sweep, this module proves it with an O(n log n) time/address
sweep-line, so it scales to the full-graph sizes ROADMAP item 4 targets
(a 50k-record plan certifies in well under 5 s).

Independence is the point: this file shares **zero code** with
``core/interval_set.py`` or the planners. Liveness, breadths, positional
maximums and the disjointness proof are all re-derived locally — a bug in
a planner (or in the shared interval machinery every planner sits on)
cannot hide behind a matching bug here. ``tests/test_analysis_soundness``
differential-matches every verdict against the oracle across the
220-graph corpus, and ``tests/test_analysis_mutation`` proves seeded
corruptions are caught.

Sweep-line argument (offsets): walk operator time; keep the address
intervals of live tensors in a sorted structure that is pairwise
disjoint. A tensor leaving at ``last_op`` is removed at ``last_op + 1``
*before* arrivals at that step (closed usage intervals). When a tensor
arrives, only its would-be neighbors in address order can overlap it —
for a pairwise-disjoint set sorted by start, starts and ends sort
together, so any member starting at or below the newcomer ends at or
below the predecessor, and any member starting above begins at or above
the successor. One predecessor check + one successor check per arrival.

Every certifier returns a list of :class:`~repro.analysis.findings.Finding`
(empty = certified) instead of raising, so callers can aggregate across
buckets and report all defects at once.
"""

from __future__ import annotations

import math
from bisect import bisect_left, bisect_right
from typing import TYPE_CHECKING, Sequence

from repro.analysis.findings import Finding

if TYPE_CHECKING:  # structural types only; no planner code is executed
    from repro.core.artifact import PlanBundle
    from repro.core.planner import MemoryPlan
    from repro.core.records import TensorUsageRecord
    from repro.core.shared_objects import SharedObjectsAssignment
    from repro.core.unified import StatePlan, UnifiedPlan

PASS = "soundness"


def _leaf_nbytes(leaf) -> int:
    """Byte size of one state-plan leaf. JSON round-tripped plans carry
    dtype NAMES, and plain numpy does not know the ml_dtypes families
    (``bfloat16``, ``float8_*``) the full-scale configs run in."""
    import numpy as np

    try:
        itemsize = np.dtype(leaf.dtype).itemsize
    except TypeError:
        import ml_dtypes

        itemsize = np.dtype(getattr(ml_dtypes, str(leaf.dtype))).itemsize
    return math.prod(leaf.shape) * itemsize


def _finding(code: str, message: str, where: str = "") -> Finding:
    return Finding(pass_name=PASS, code=code, message=message, where=where)


# --------------------------------------------------------------- sweep set


class _SweepSet:
    """Sorted set of disjoint address intervals, chunked for O(√-ish)
    inserts without external deps.

    Items are ``(offset, end, tensor_id)`` tuples in natural tuple order.
    A flat ``bisect.insort`` list degrades to O(n) memmove per insert when
    tens of thousands of tensors are simultaneously live; splitting into
    bounded chunks (≤ ``2 * CHUNK``) keeps every insert's shift local
    while lookups stay one bisect over chunk heads + one inside a chunk.
    """

    CHUNK = 512

    def __init__(self) -> None:
        self._chunks: list[list[tuple[int, int, int]]] = []
        self._heads: list[tuple[int, int, int]] = []  # _chunks[i][0], cached

    def __len__(self) -> int:
        return sum(len(c) for c in self._chunks)

    def _chunk_index(self, item: tuple[int, int, int]) -> int:
        ci = bisect_right(self._heads, item) - 1
        return 0 if ci < 0 else ci

    def add(self, item: tuple[int, int, int]) -> tuple[
        tuple[int, int, int] | None, tuple[int, int, int] | None
    ]:
        """Insert ``item``; return its (predecessor, successor) so the
        caller can run the two disjointness checks."""
        if not self._chunks:
            self._chunks.append([item])
            self._heads.append(item)
            return None, None
        ci = self._chunk_index(item)
        chunk = self._chunks[ci]
        pos = bisect_left(chunk, item)
        if pos > 0:
            pred = chunk[pos - 1]
        elif ci > 0:
            pred = self._chunks[ci - 1][-1]
        else:
            pred = None
        if pos < len(chunk):
            succ = chunk[pos]
        elif ci + 1 < len(self._chunks):
            succ = self._chunks[ci + 1][0]
        else:
            succ = None
        chunk.insert(pos, item)
        if pos == 0:
            self._heads[ci] = item
        if len(chunk) > 2 * self.CHUNK:
            mid = len(chunk) // 2
            self._chunks[ci : ci + 1] = [chunk[:mid], chunk[mid:]]
            self._heads[ci : ci + 1] = [chunk[0], chunk[mid]]
        return pred, succ

    def remove(self, item: tuple[int, int, int]) -> None:
        if not self._chunks:
            raise KeyError(f"interval not present: {item}")
        ci = self._chunk_index(item)
        chunk = self._chunks[ci]
        pos = bisect_left(chunk, item)
        if pos >= len(chunk) or chunk[pos] != item:
            raise KeyError(f"interval not present: {item}")
        chunk.pop(pos)
        if not chunk:
            del self._chunks[ci]
            del self._heads[ci]
        elif pos == 0:
            self._heads[ci] = chunk[0]


# ------------------------------------------------------- offsets certifier


def certify_offsets(
    records: Sequence["TensorUsageRecord"],
    offsets: dict[int, int],
    total_size: int,
    *,
    label: str = "offsets",
) -> list[Finding]:
    """Certify a flat-arena offset plan: coverage, bounds, and — via the
    sweep-line — that no two simultaneously-live tensors overlap in the
    arena. Mirrors every constraint ``core/validate.check_offsets``
    asserts, with independently re-derived liveness and lower bound."""
    findings: list[Finding] = []
    ids = {r.tensor_id for r in records}
    if set(offsets) != ids:
        findings.append(
            _finding(
                "coverage",
                f"offsets cover {len(offsets)} of {len(ids)} tensors "
                f"(missing {sorted(ids - set(offsets))[:5]}, "
                f"extra {sorted(set(offsets) - ids)[:5]})",
                label,
            )
        )
        return findings  # per-tensor checks below need full coverage

    # events: (time, kind) — removals (kind 0) at last_op + 1 run before
    # additions (kind 1) at the same step: closed usage intervals
    events: list[tuple[int, int, "TensorUsageRecord"]] = []
    naive = 0
    for r in records:
        off = offsets[r.tensor_id]
        if off < 0:
            findings.append(
                _finding(
                    "negative-offset",
                    f"tensor {r.tensor_id} at offset {off} < 0",
                    label,
                )
            )
        if off + r.size > total_size:
            findings.append(
                _finding(
                    "arena-spill",
                    f"tensor {r.tensor_id} spans [{off}, {off + r.size}) past "
                    f"arena end {total_size}",
                    label,
                )
            )
        naive += r.size
        events.append((r.first_op, 1, r))
        events.append((r.last_op + 1, 0, r))
    events.sort(key=lambda e: (e[0], e[1], e[2].tensor_id))

    active = _SweepSet()
    breadth = 0
    lower_bound = 0
    reported: set[tuple[int, int]] = set()
    for _t, kind, rec in events:
        interval = (offsets[rec.tensor_id], offsets[rec.tensor_id] + rec.size,
                    rec.tensor_id)
        if kind == 0:
            active.remove(interval)
            breadth -= rec.size
            continue
        pred, succ = active.add(interval)
        breadth += rec.size
        lower_bound = max(lower_bound, breadth)
        for other in (pred, succ):
            if other is None:
                continue
            o_off, o_end, o_id = other
            if o_off < interval[1] and interval[0] < o_end:
                pair = (min(o_id, rec.tensor_id), max(o_id, rec.tensor_id))
                if pair not in reported:
                    reported.add(pair)
                    findings.append(
                        _finding(
                            "arena-collision",
                            f"simultaneously-live tensors "
                            f"{rec.tensor_id}@[{interval[0]}, {interval[1]}) "
                            f"and {o_id}@[{o_off}, {o_end}) share bytes",
                            label,
                        )
                    )

    if not lower_bound <= total_size <= naive:
        findings.append(
            _finding(
                "bounds",
                f"total {total_size} outside [{lower_bound}, {naive}] "
                f"(max operator breadth, naive sum)",
                label,
            )
        )
    return findings


# ------------------------------------------------ shared-objects certifier


def _positional_maximums_sum(records: Sequence["TensorUsageRecord"]) -> int:
    """Paper §4.1's lower bound, re-derived locally: at every operator,
    rank the live sizes in non-increasing order; the bound is the sum over
    ranks of the maximum size seen at that rank."""
    n_ops = 0 if not records else 1 + max(r.last_op for r in records)
    profiles: list[list[int]] = [[] for _ in range(n_ops)]
    for r in records:
        for op in range(r.first_op, r.last_op + 1):
            profiles[op].append(r.size)
    maxima: list[int] = []
    for sizes in profiles:
        sizes.sort(reverse=True)
        for rank, size in enumerate(sizes):
            if rank == len(maxima):
                maxima.append(size)
            elif size > maxima[rank]:
                maxima[rank] = size
    return sum(maxima)


def certify_shared_objects(
    records: Sequence["TensorUsageRecord"],
    asn: "SharedObjectsAssignment",
    *,
    label: str = "shared-objects",
) -> list[Finding]:
    """Certify a shared-objects plan: coverage, per-object interval
    disjointness (sorted scan instead of the oracle's pairwise loop),
    exact object sizing, and the §4.1 bound."""
    findings: list[Finding] = []
    by_id = {r.tensor_id: r for r in records}
    if set(asn.assignment) != set(by_id):
        findings.append(
            _finding(
                "coverage",
                f"assignment covers {len(asn.assignment)} of "
                f"{len(by_id)} tensors",
                label,
            )
        )
        return findings

    # intra-object disjointness: sort each object's intervals by first_op;
    # a collision is exactly "next starts before the running max last ends"
    members: dict[int, list[tuple[int, int, int]]] = {}
    max_assigned: dict[int, int] = {}
    for tid, oid in asn.assignment.items():
        r = by_id[tid]
        members.setdefault(oid, []).append((r.first_op, r.last_op, tid))
        if r.size > max_assigned.get(oid, 0):
            max_assigned[oid] = r.size
    for oid, intervals in members.items():
        intervals.sort()
        running_last = -1
        running_tid = -1
        for first, last, tid in intervals:
            if first <= running_last:
                findings.append(
                    _finding(
                        "object-collision",
                        f"tensors {running_tid} and {tid} overlap in time "
                        f"but share object {oid}",
                        label,
                    )
                )
            if last > running_last:
                running_last, running_tid = last, tid

    for obj in asn.objects:
        want = max_assigned.get(obj.object_id, obj.size)
        if obj.size != want:
            findings.append(
                _finding(
                    "object-size-mismatch",
                    f"object {obj.object_id} sized {obj.size} but its "
                    f"largest assigned tensor is {want}",
                    label,
                )
            )

    lb = _positional_maximums_sum(records)
    naive = sum(r.size for r in records)
    if not lb <= asn.total_size <= naive:
        findings.append(
            _finding(
                "bounds",
                f"total {asn.total_size} outside [{lb}, {naive}] "
                f"(positional maximums, naive sum)",
                label,
            )
        )
    return findings


# ----------------------------------------------------- state-plan certifier


def certify_state_plan(
    sp: "StatePlan", *, label: str = "state"
) -> list[Finding]:
    """Certify the cross-step state layout: per-leaf alignment/sizing,
    in-slot disjointness (sorted scan), slot-stride containment, and the
    symmetric total. Leaf sizes are re-derived from shape × dtype, so a
    corrupted ``slot_nbytes`` cannot self-certify."""
    import numpy as np

    findings: list[Finding] = []
    if sp.alignment <= 0:
        findings.append(
            _finding("state-alignment", f"alignment {sp.alignment} <= 0", label)
        )
        return findings
    if sp.total_size != sp.n_slots * sp.slot_stride:
        findings.append(
            _finding(
                "state-total-mismatch",
                f"total {sp.total_size} != {sp.n_slots} slots x "
                f"{sp.slot_stride} stride",
                label,
            )
        )
    if sp.slot_stride % sp.alignment:
        findings.append(
            _finding(
                "state-stride-unaligned",
                f"slot stride {sp.slot_stride} not a multiple of "
                f"{sp.alignment}",
                label,
            )
        )
    spans: list[tuple[int, int, str]] = []
    for leaf in sp.leaves:
        where = f"{label}:{leaf.path}"
        nbytes = _leaf_nbytes(leaf)
        if nbytes % sp.n_slots:
            findings.append(
                _finding(
                    "state-indivisible",
                    f"{nbytes} B not divisible across {sp.n_slots} slots",
                    where,
                )
            )
            continue
        per_slot = nbytes // sp.n_slots
        want = -(-per_slot // sp.alignment) * sp.alignment
        if leaf.slot_nbytes != want:
            findings.append(
                _finding(
                    "state-leaf-size",
                    f"slot_nbytes {leaf.slot_nbytes} != aligned per-slot "
                    f"payload {want} ({per_slot} B)",
                    where,
                )
            )
        if leaf.offset < 0 or leaf.offset % sp.alignment:
            findings.append(
                _finding(
                    "state-leaf-unaligned",
                    f"offset {leaf.offset} not {sp.alignment}-aligned "
                    f"and non-negative",
                    where,
                )
            )
        if leaf.offset + max(leaf.slot_nbytes, per_slot) > sp.slot_stride:
            findings.append(
                _finding(
                    "state-leaf-spill",
                    f"leaf [{leaf.offset}, "
                    f"{leaf.offset + max(leaf.slot_nbytes, per_slot)}) spills "
                    f"past slot stride {sp.slot_stride}",
                    where,
                )
            )
        spans.append(
            (leaf.offset, leaf.offset + max(leaf.slot_nbytes, per_slot, 1),
             leaf.path)
        )
    spans.sort()
    for (a_off, a_end, a_path), (b_off, _b_end, b_path) in zip(
        spans, spans[1:]
    ):
        if b_off < a_end:
            findings.append(
                _finding(
                    "state-leaf-collision",
                    f"leaves {a_path!r} and {b_path!r} overlap within the "
                    f"slot ([{a_off}, {a_end}) vs offset {b_off})",
                    label,
                )
            )
    if getattr(sp, "page_size", None) is not None:
        findings += _certify_paged_state(sp, label=label)
    return findings


def _certify_paged_state(sp, *, label: str) -> list[Finding]:
    """The paged extras over :func:`certify_state_plan`'s symmetric
    checks (duck-typed — any plan carrying ``page_size`` qualifies, so a
    deserialized bundle certifies without importing planner classes):
    the physical pool really is ``n_pages_pool`` disjoint, page-aligned,
    in-bounds pages; the token spans re-derive to each leaf's per-slot
    payload; a pool too small to map even one full slot is flagged."""
    import numpy as np

    findings: list[Finding] = []
    if sp.page_size <= 0:
        findings.append(
            _finding(
                "paged-page-size", f"page size {sp.page_size} <= 0", label
            )
        )
        return findings  # every pool check below divides by it
    if sp.n_pages_pool < 1:
        findings.append(
            _finding(
                "paged-pool-empty",
                f"page pool holds {sp.n_pages_pool} pages — no request "
                f"can ever be admitted",
                label,
            )
        )
    pages_per_slot = -(-sp.slot_stride // sp.page_size)
    if 0 < sp.n_pages_pool < pages_per_slot:
        findings.append(
            Finding(
                pass_name=PASS,
                code="paged-pool-short",
                message=(
                    f"pool of {sp.n_pages_pool} pages cannot map one "
                    f"full slot ({pages_per_slot} pages/slot) — "
                    f"max_len requests will be refused"
                ),
                where=label,
                severity="warning",
            )
        )
    if len(sp.page_offsets) != sp.n_pages_pool:
        findings.append(
            _finding(
                "paged-pool-empty",
                f"{len(sp.page_offsets)} page offsets for a pool of "
                f"{sp.n_pages_pool}",
                label,
            )
        )
    phys_total = (sp.n_pages_pool + 1) * sp.page_size
    seen: dict[int, int] = {0: -1}  # offset -> pool index (null page = -1)
    for i, off in enumerate(sp.page_offsets):
        if off < 0 or off % sp.page_size:
            findings.append(
                _finding(
                    "paged-page-unaligned",
                    f"pool page {i} at offset {off} not page-aligned and "
                    f"non-negative",
                    label,
                )
            )
        if off + sp.page_size > phys_total:
            findings.append(
                _finding(
                    "paged-page-spill",
                    f"pool page {i} spans [{off}, {off + sp.page_size}) "
                    f"past physical end {phys_total}",
                    label,
                )
            )
        if off in seen:
            other = "the null page" if seen[off] < 0 else f"page {seen[off]}"
            findings.append(
                _finding(
                    "paged-page-collision",
                    f"pool page {i} at offset {off} collides with {other}",
                    label,
                )
            )
        else:
            seen[off] = i
    if len(sp.token_spans) != len(sp.leaves):
        findings.append(
            _finding(
                "paged-span-size",
                f"{len(sp.token_spans)} token spans for {len(sp.leaves)} "
                f"leaves",
                label,
            )
        )
        return findings
    for leaf, span in zip(sp.leaves, sp.token_spans):
        if span is None:
            continue
        where = f"{label}:{leaf.path}"
        nbytes = _leaf_nbytes(leaf)
        if nbytes % max(sp.n_slots, 1):
            continue  # already reported as state-indivisible
        n_chunks, n_rows, row_nbytes = span
        if n_chunks * n_rows * row_nbytes != nbytes // sp.n_slots:
            findings.append(
                _finding(
                    "paged-span-size",
                    f"token span {span} covers "
                    f"{n_chunks * n_rows * row_nbytes} B, leaf carries "
                    f"{nbytes // sp.n_slots} B/slot",
                    where,
                )
            )
    return findings


# ---------------------------------------------------------------- drivers


def certify_plan(plan: "MemoryPlan", *, label: str | None = None) -> list[Finding]:
    """Certify one activation :class:`MemoryPlan` (offsets + optional
    shared-objects provenance)."""
    where = label or f"{plan.graph_name}[{plan.strategy}]"
    findings = certify_offsets(
        plan.records, plan.offsets, plan.total_size, label=where
    )
    if plan.shared_objects is not None:
        findings += certify_shared_objects(
            plan.records, plan.shared_objects, label=where
        )
    return findings


def certify_unified(
    up: "UnifiedPlan", *, label: str = "unified"
) -> list[Finding]:
    """Certify every half of a :class:`UnifiedPlan` (activation, state,
    and — when planned — the prefill activation arena)."""
    findings: list[Finding] = []
    if up.activation is not None:
        findings += certify_plan(up.activation, label=f"{label}:activation")
    if up.state is not None:
        findings += certify_state_plan(up.state, label=f"{label}:state")
    if up.prefill is not None:
        findings += certify_plan(up.prefill, label=f"{label}:prefill")
    return findings


def certify_bundle(
    bundle: "PlanBundle", *, label: str | None = None
) -> list[Finding]:
    """Certify a published :class:`PlanBundle`: its activation plan,
    (v2) its state plan, and (v4) its prefill plan. Manifest-level
    coherence is :mod:`repro.analysis.bundle_lint`'s job."""
    where = label or (
        f"{bundle.arch}|slots{bundle.n_slots}|len{bundle.max_len}"
    )
    findings = certify_plan(bundle.plan, label=where)
    if bundle.state_plan is not None:
        findings += certify_state_plan(
            bundle.state_plan, label=f"{where}:state"
        )
    if bundle.prefill_plan is not None:
        findings += certify_plan(
            bundle.prefill_plan, label=f"{where}:prefill"
        )
    return findings


__all__ = [
    "certify_offsets",
    "certify_shared_objects",
    "certify_state_plan",
    "certify_plan",
    "certify_unified",
    "certify_bundle",
]
