"""``python -m repro.analysis.lint`` — one CLI over all three passes.

Subcommands::

    bundles PATH             audit a manifest dir (or one bundle file):
                             bundle_lint coherence + soundness certification
                             of every reachable plan
    decode ARCH [ARCH...]    lower + lint the compiled decode step and scan
                             block for each architecture (reduced configs)
    all --manifest PATH --archs A,B
                             both of the above in one run

Exit codes: ``0`` clean, ``1`` findings (errors; warnings too under
``--strict``), ``2`` usage or internal failure. ``--json`` emits the
machine-readable report on stdout instead of rendered lines.
"""

from __future__ import annotations

import argparse
import json
import sys
import warnings
from pathlib import Path

from repro.analysis.findings import Report


def _lint_bundles_path(path: Path) -> Report:
    from repro.analysis import bundle_lint, soundness
    from repro.core.artifact import BundleManifest, load_bundle

    if path.is_dir():
        report = bundle_lint.lint_manifest(path)
        seen: set[str] = set()
        try:
            buckets = BundleManifest(path).buckets()
        except Exception:
            return report  # index-unreadable already reported
        for key, entry in sorted(buckets.items()):
            fname = entry.get("file", "")
            if fname in seen:
                continue
            seen.add(fname)
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DeprecationWarning)
                    bundle = load_bundle(path / fname)
            except Exception:
                continue  # unreadable: bundle_lint reported it
            report.extend(
                soundness.certify_bundle(bundle, label=key),
                checked=f"soundness:{key}",
            )
        return report
    report = Report()
    report.extend(bundle_lint.lint_bundle_file(path), checked=str(path))
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            bundle = load_bundle(path)
    except Exception:
        return report
    report.extend(
        soundness.certify_bundle(bundle), checked=f"soundness:{path.name}"
    )
    return report


def _lint_decode(
    archs: list[str], *, n_slots: int, max_len: int, block: int | None,
    greedy: bool,
) -> Report:
    from repro.analysis import decode_lint

    report = Report()
    for arch in archs:
        report.merge(
            decode_lint.lint_arch(
                arch, n_slots=n_slots, max_len=max_len, block=block,
                greedy=greedy,
            )
        )
    return report


def _emit(report: Report, args) -> int:
    if args.json:
        print(json.dumps(report.to_obj(), indent=1, sort_keys=True))
    else:
        print(report.render())
    return 0 if report.ok(strict=args.strict) else 1


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static analysis over plan bundles and compiled decode",
    )
    parser.add_argument(
        "--strict", action="store_true",
        help="fail (exit 1) on warnings, not just errors",
    )
    parser.add_argument(
        "--json", action="store_true",
        help="emit the machine-readable findings report",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_b = sub.add_parser(
        "bundles", help="audit a manifest directory or one bundle file"
    )
    p_b.add_argument("path", type=Path)

    def add_decode_opts(p):
        p.add_argument("--slots", type=int, default=2)
        p.add_argument("--max-len", type=int, default=32)
        p.add_argument(
            "--block", type=int, default=8,
            help="scan-block length to lint (0 = step only)",
        )
        p.add_argument(
            "--sampled", action="store_true",
            help="lint the sampled (non-greedy) serving graph",
        )

    p_d = sub.add_parser(
        "decode", help="lint the compiled decode step + scan block"
    )
    p_d.add_argument("archs", nargs="+")
    add_decode_opts(p_d)

    p_a = sub.add_parser("all", help="bundles + decode in one run")
    p_a.add_argument("--manifest", type=Path, required=True)
    p_a.add_argument(
        "--archs", default="",
        help="comma-separated architectures for the decode pass",
    )
    add_decode_opts(p_a)

    args = parser.parse_args(argv)
    try:
        if args.cmd == "bundles":
            return _emit(_lint_bundles_path(args.path), args)
        block = None if getattr(args, "block", 0) == 0 else args.block
        if args.cmd == "decode":
            report = _lint_decode(
                args.archs, n_slots=args.slots, max_len=args.max_len,
                block=block, greedy=not args.sampled,
            )
            return _emit(report, args)
        report = _lint_bundles_path(args.manifest)
        archs = [a for a in args.archs.split(",") if a]
        if archs:
            report.merge(
                _lint_decode(
                    archs, n_slots=args.slots, max_len=args.max_len,
                    block=block, greedy=not args.sampled,
                )
            )
        return _emit(report, args)
    except Exception as e:  # usage/internal failure, not a finding
        print(f"lint failed: {type(e).__name__}: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
