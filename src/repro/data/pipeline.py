"""Synthetic token pipeline: deterministic, seekable, host-sharded.

A real deployment would read tokenized shards; for the reproduction the
pipeline synthesizes a stationary Zipf-ish token stream deterministically
from (seed, step, host), which is enough for the training loop, the
serving driver, and throughput benchmarks — and it is seekable, so
checkpoint-resume is exact.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Deterministic synthetic batches; ``batch_at(step)`` is random access."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide by n_hosts")
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.n_hosts
        # Zipf-ish stationary distribution over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id])
        )
        tokens = rng.choice(
            self.cfg.vocab, size=(self.per_host, self.cfg.seq_len + 1), p=self._probs
        ).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
