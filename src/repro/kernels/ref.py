"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(
    q: jax.Array,  # (B, KV, G, D)
    k_cache: jax.Array,  # (B, T, KV, D)
    v_cache: jax.Array,  # (B, T, KV, D)
    lengths: jax.Array,  # (B,)
) -> jax.Array:
    B, KV, G, D = q.shape
    T = k_cache.shape[1]
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum(
        "bkgd,btkd->bkgt",
        q.astype(jnp.float32) * scale,
        k_cache.astype(jnp.float32),
    )
    mask = jnp.arange(T)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_chunk_ref(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) fp32 (already softplus'd)
    dA: jax.Array,  # (B, L, H) fp32 (dt * A, negative)
    Bm: jax.Array,  # (B, L, H, N) — B projected, broadcast to heads
    Cm: jax.Array,  # (B, L, H, N)
    state: jax.Array,  # (B, H, P, N) incoming inter-chunk state
) -> tuple[jax.Array, jax.Array]:
    """One SSD chunk: returns (y (B,L,H,P), new_state (B,H,P,N))."""
    L = x.shape[1]
    cum = jnp.cumsum(dA, axis=1)  # (B,L,H)
    total = cum[:, -1]  # (B,H)
    seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Lq,Lk,H)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    qk = jnp.einsum("blhn,bmhn->blmh", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    W = qk * decay * dt[:, None, :, :]
    y_intra = jnp.einsum("blmh,bmhp->blhp", W, x.astype(jnp.float32))
    y_inter = jnp.einsum(
        "blhn,bhpn->blhp",
        Cm.astype(jnp.float32) * jnp.exp(cum)[..., None],
        state.astype(jnp.float32),
    )
    rem = jnp.exp(total[:, None, :] - cum) * dt  # (B,L,H)
    dBx = jnp.einsum(
        "blhn,blhp->bhpn", Bm.astype(jnp.float32) * rem[..., None],
        x.astype(jnp.float32),
    )
    new_state = state.astype(jnp.float32) * jnp.exp(total)[..., None, None] + dBx
    return (y_intra + y_inter).astype(x.dtype), new_state.astype(state.dtype)
