"""Pallas TPU kernel: one Mamba2 SSD chunk (within-chunk + state update).

Grid (B, H): each program owns one (batch, head) pair and computes the
full L×L decay-weighted attention-like term plus the inter-chunk state
contribution in VMEM. L is the SSD chunk length (≤256), P = head dim,
N = state dim — the (L,L) weight tile, (L,P) x tile and (P,N) state tile
all fit VMEM simultaneously (≈ (256² + 256·64 + 64·128)·4B ≈ 0.3 MiB +
double-buffering), MXU-aligned at 128 where it matters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, s_ref, y_ref, ns_ref):
    x = x_ref[0, :, 0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0]  # (L,)
    dA = dA_ref[0, :, 0]  # (L,)
    Bm = b_ref[0, :, 0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)  # (L, N)
    state = s_ref[0, 0].astype(jnp.float32)  # (P, N)

    L = x.shape[0]
    cum = jnp.cumsum(dA)  # (L,)
    total = cum[-1]
    seg = cum[:, None] - cum[None, :]  # (Lq, Lk)
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    seg = jnp.where(row >= col, seg, -jnp.inf)
    decay = jnp.exp(seg)
    qk = Cm @ Bm.T  # (Lq, Lk)
    W = qk * decay * dt[None, :]
    y_intra = W @ x  # (L, P)
    y_inter = (Cm * jnp.exp(cum)[:, None]) @ state.T  # (L, P)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    rem = jnp.exp(total - cum) * dt  # (L,)
    dBx = x.T @ (Bm * rem[:, None])  # (P, N)
    ns_ref[0, 0] = (state * jnp.exp(total) + dBx).astype(ns_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) fp32
    dA: jax.Array,  # (B, L, H) fp32
    Bm: jax.Array,  # (B, L, H, N)
    Cm: jax.Array,  # (B, L, H, N)
    state: jax.Array,  # (B, H, P, N)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    grid = (B, H)
    y, ns = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, L, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, L, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), state.dtype),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt, dA, Bm, Cm, state)
    return y, ns
