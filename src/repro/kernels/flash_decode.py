"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

The serving hot-spot: one query token per sequence attends to a length-T
cache. HBM traffic is dominated by streaming K/V once; the kernel tiles
the cache into VMEM blocks of ``block_t`` positions and keeps an online
softmax (m, l, acc) in VMEM scratch — the scratch buffers are the
Shared-Objects view at the VMEM level: the same tiles are reused across
all T/block_t grid steps (cf. paper §4; the tile working set is the
positional maximum of the kernel's tensor usage records).

Layout: q (B, KV, G, D) — G = H/KV query heads per KV head; cache
(B, T, KV, D); lengths (B,) valid entries per row. Grid (B, KV, nT) with
the T axis sequential ('arbitrary') so scratch carries across tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
             *, block_t: int, scale: float):
    b = pl.program_id(0)
    t_idx = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (Tt, D)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (Tt, D)

    s = q @ k.T  # (G, Tt)
    length = lengths_ref[b]
    positions = t_idx * block_t + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1
    )
    s = jnp.where(positions < length, s, NEG_INF)

    m_prev = m_ref[...]  # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (G, Tt)
    correction = jnp.exp(m_prev - m_new)  # (G, 1)
    l_new = l_ref[...] * correction + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(t_idx == n_t - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def flash_decode(
    q: jax.Array,  # (B, KV, G, D)
    k_cache: jax.Array,  # (B, T, KV, D)
    v_cache: jax.Array,  # (B, T, KV, D)
    lengths: jax.Array,  # (B,) int32 — valid cache entries per row
    *,
    block_t: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, KV, G, D = q.shape
    T = k_cache.shape[1]
    block_t = min(block_t, T)
    n_t = -(-T // block_t)
    if T % block_t:
        pad = n_t * block_t - T
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / (D ** 0.5)
    grid = (B, KV, n_t)
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps get the prefetched scalar ref as a trailing arg
                pl.BlockSpec((1, 1, G, D), lambda b, h, t, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, block_t, 1, D), lambda b, h, t, lens: (b, t, h, 0)),
                pl.BlockSpec((1, block_t, 1, D), lambda b, h, t, lens: (b, t, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
    return out
