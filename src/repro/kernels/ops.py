"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True in this CPU container (the kernels TARGET
TPU; interpret mode executes the kernel body in Python for validation).
On a real TPU runtime set ``interpret=False``.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ssd_chunk import ssd_chunk

__all__ = ["flash_decode", "ssd_chunk", "flash_decode_auto"]


def flash_decode_auto(q, k_cache, v_cache, lengths, **kw):
    """Pick block_t so a K/V tile pair stays within ~4 MiB of VMEM."""
    D = q.shape[-1]
    budget = 4 * 2**20
    per_pos = 2 * D * k_cache.dtype.itemsize
    block_t = max(128, min(2048, budget // per_pos // 128 * 128))
    return flash_decode(q, k_cache, v_cache, lengths, block_t=block_t, **kw)
