"""VMEM budgeting for the Pallas kernels via the paper's planner.

A Pallas grid step is a micro-scale instance of the paper's problem: the
kernel's tiles (q block, double-buffered K/V blocks, online-softmax
scratch) are tensors with usage intervals over the pipeline stages; VMEM
(~16 MiB/core on v5e) is the arena. ``plan_flash_decode_vmem`` builds the
usage records for one grid step (with the next step's K/V prefetch
overlapping — the double buffer), runs Offset Calculation, and returns
the planned VMEM footprint. ``ops.flash_decode_auto`` block sizing is
checked against this in tests/test_vmem_plan.py.
"""

from __future__ import annotations

import dataclasses

from repro.core.planner import MemoryPlan, plan_records
from repro.core.records import TensorUsageRecord

VMEM_BYTES = 16 * 2**20  # v5e per-core VMEM

# What a FUSED kernel's internalized tensors may occupy. A fused kernel is
# not alone in VMEM: the compiler keeps pipeline state resident — the
# double-buffered operand tiles and fp32 accumulators this module plans
# (see ``plan_flash_decode_vmem``: the largest paper-shape step plans well
# under 4 MiB). Reserving that headroom makes fusion legality reflect the
# actual TPU VMEM model instead of pretending the whole core is scratch.
VMEM_PIPELINE_RESERVE_BYTES = 4 * 2**20


def fusion_scratch_budget(
    vmem_bytes: int = VMEM_BYTES,
    reserve_bytes: int = VMEM_PIPELINE_RESERVE_BYTES,
) -> int:
    """Kernel-local scratch available to fusion (``core/fusion_search``)."""
    return max(vmem_bytes - reserve_bytes, 0)


@dataclasses.dataclass
class KernelVmemPlan:
    plan: MemoryPlan
    fits: bool
    budget: int = VMEM_BYTES

    def summary(self) -> str:
        return (
            f"{self.plan.graph_name}: {self.plan.total_size / 2**10:.0f} KiB "
            f"of {self.budget / 2**20:.0f} MiB VMEM "
            f"({'fits' if self.fits else 'OVER BUDGET'}; "
            f"naive co-residency {self.plan.naive_size / 2**10:.0f} KiB"
            f"{'; cached plan' if self.plan.cache_hit else ''})"
        )


def plan_flash_decode_vmem(
    *, G: int, D: int, block_t: int, dtype_bytes: int = 2
) -> KernelVmemPlan:
    """One flash_decode grid step as tensor usage records.

    Pipeline stages (ops): 0 load k/v tile i | 1 compute scores |
    2 softmax-update | 3 accumulate | 4 prefetch tile i+1 (overlaps 1-3).
    Persistent scratch (q, m, l, acc) lives across all stages.
    """
    recs = []
    tid = 0

    def add(first, last, nbytes):
        nonlocal tid
        recs.append(TensorUsageRecord(first, last, max(nbytes, 1), tensor_id=tid))
        tid += 1

    q = G * D * dtype_bytes
    kv_tile = block_t * D * dtype_bytes
    scores = G * block_t * 4  # fp32
    stats = G * 1 * 4  # m and l
    acc = G * D * 4

    add(0, 4, q)            # q tile (persistent for the row)
    add(0, 1, kv_tile)      # k tile i — retires after the score matmul
    add(0, 3, kv_tile)      # v tile i — needed through accumulation
    add(1, 2, scores)       # score tile (fp32)
    add(2, 3, scores)       # exp(p) tile
    add(0, 4, stats)        # running max m
    add(0, 4, stats)        # running sum l
    add(0, 4, acc)          # output accumulator
    add(1, 4, kv_tile)      # k tile i+1 (double buffer: overlaps compute)
    add(2, 4, kv_tile)      # v tile i+1
    plan = plan_records(
        recs, mode="offsets", strategy="greedy_by_size",
        graph_name=f"flash_decode[G={G},D={D},block_t={block_t}]",
    )
    return KernelVmemPlan(plan=plan, fits=plan.total_size <= VMEM_BYTES)
