"""Extract the paper's tensor usage records from any JAX computation.

``trace_graph(fn, *args)`` traces ``fn`` to a jaxpr and converts it to a
:class:`repro.core.graph.Graph`:

* each jaxpr equation (in program order — the fixed topological sort the
  paper assumes) becomes one operator;
* each intermediate ``Var`` becomes a tensor whose byte size comes from its
  abstract value (shape × dtype);
* jaxpr ``invars``/``constvars`` (inputs, weights) and ``outvars`` (final
  outputs) are *boundary* tensors — exactly the paper's carve-out ("tensor
  #8 is not an intermediate tensor" in Fig. 1).

Higher-order equations (``scan``, ``cond``, ``while`` …) are treated as
single opaque operators — the inference-engine view where a fused region
executes atomically. ``pjit``/``closed_call``/``remat`` bodies are inlined
(``inline_nested=True``, default) since they are just function boundaries,
matching what the runtime executor and XLA actually materialize.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np
from jax.extend.core import Literal

from repro.core.graph import Graph, Op, TensorSpec

# Instrumentation: total graph extractions this process (bumped by every
# graph_from_jaxpr, which every trace_graph goes through). Tests snapshot
# it around engine construction to prove the plan-bundle serving path
# performs zero traces.
TRACE_CALLS = 0

_INLINE = {
    "pjit",
    "closed_call",
    "core_call",
    "remat",
    "checkpoint",
    "remat2",
    "custom_jvp_call",
    "custom_vjp_call",
}


def _aval_nbytes(aval) -> int:
    try:
        shape = aval.shape
        dtype = np.dtype(aval.dtype)
    except Exception:  # non-array avals (tokens, refs): treat as tiny
        return 1
    n = 1
    for s in shape:
        n *= int(s)
    return max(n * dtype.itemsize, 1)


def _sub_closed_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if sub is not None and hasattr(sub, "jaxpr"):
            return sub
    return None


class _Builder:
    def __init__(
        self,
        inline: frozenset[str] = frozenset(_INLINE),
        expand_scan: bool = True,
    ) -> None:
        self.tensors: dict[int, TensorSpec] = {}
        self.ops: list[Op] = []
        self.boundary: set[int] = set()
        self.inline = inline
        self.expand_scan = expand_scan
        # Var object -> tensor id, across ALL (inlined) jaxpr levels.
        # Aliases (inner outvar == outer outvar) map to the same id. The
        # arena executor keys its environment off this mapping.
        self.var_tid: dict[Any, int] = {}
        self._seen_subjaxprs: set[int] = set()
        self._next = 0

    def new_tensor(self, aval, name: str = "") -> int:
        i = self._next
        self._next += 1
        self.tensors[i] = TensorSpec(
            tensor_id=i,
            nbytes=_aval_nbytes(aval),
            name=name,
            shape=tuple(int(s) for s in getattr(aval, "shape", ())) or None,
            dtype=str(getattr(aval, "dtype", "")) or None,
        )
        return i

    def resolve(self, v) -> int | None:
        """Var -> tensor id; Vars never seen as a definition (e.g. free
        constvars) become boundary tensors. Literals have no tensor."""
        if isinstance(v, Literal):
            return None
        if v not in self.var_tid:
            self.var_tid[v] = self.new_tensor(v.aval, str(v))
            self.boundary.add(self.var_tid[v])
        return self.var_tid[v]

    def walk(self, jaxpr) -> None:
        """Emit ops for ``jaxpr``'s eqns into self.ops/self.var_tid.

        Var objects are unique across the whole jaxpr nest, so one global
        mapping suffices; aliases point multiple Vars at one tensor id.
        """
        for eqn in jaxpr.eqns:
            sub = _sub_closed_jaxpr(eqn)
            if (
                self.expand_scan
                and eqn.primitive.name == "scan"
                and sub is not None
                and id(sub.jaxpr) not in self._seen_subjaxprs
            ):
                # Model one loop iteration: a layer-wise inference engine
                # reuses the SAME body buffers every iteration, so the
                # body's intermediates appear once in the liveness graph
                # (their arena region is reused across iterations — the
                # paper's chain-reuse argument applied to the layer loop).
                # Body inputs (consts/carry/xs slices) are per-iteration
                # boundary tensors; the outer outvars are produced by a
                # synthetic `scan` op consuming the body results.
                inner = sub.jaxpr
                self._seen_subjaxprs.add(id(inner))
                for v in (*inner.constvars, *inner.invars):
                    if v not in self.var_tid:
                        self.var_tid[v] = self.new_tensor(v.aval, str(v))
                        self.boundary.add(self.var_tid[v])
                self.walk(inner)
                body_out = tuple(
                    self.var_tid[v]
                    for v in inner.outvars
                    if not isinstance(v, Literal) and v in self.var_tid
                )
                outs = []
                for v in eqn.outvars:
                    if type(v).__name__ == "DropVar":
                        continue
                    self.var_tid[v] = self.new_tensor(v.aval, str(v))
                    outs.append(self.var_tid[v])
                carries = tuple(
                    x for v in eqn.invars if (x := self.resolve(v)) is not None
                )
                self.ops.append(
                    Op(name="scan", inputs=body_out + carries, outputs=tuple(outs))
                )
                continue
            if (
                eqn.primitive.name in self.inline
                and sub is not None
                and id(sub.jaxpr) not in self._seen_subjaxprs
            ):
                inner = sub.jaxpr
                self._seen_subjaxprs.add(id(inner))
                for cv in inner.constvars:
                    self.var_tid[cv] = self.new_tensor(cv.aval, str(cv))
                    self.boundary.add(self.var_tid[cv])
                for iv, ov in zip(inner.invars, eqn.invars):
                    r = self.resolve(ov)
                    if r is None:  # literal arg: synthesize a boundary tensor
                        self.var_tid[iv] = self.new_tensor(iv.aval, str(iv))
                        self.boundary.add(self.var_tid[iv])
                    else:
                        self.var_tid[iv] = r
                self.walk(inner)
                for inner_ov, outer_ov in zip(inner.outvars, eqn.outvars):
                    if type(outer_ov).__name__ == "DropVar":
                        continue
                    if isinstance(inner_ov, Literal):
                        self.var_tid[outer_ov] = self.new_tensor(
                            outer_ov.aval, str(outer_ov)
                        )
                        self.boundary.add(self.var_tid[outer_ov])
                    else:
                        self.var_tid[outer_ov] = self.var_tid[inner_ov]
                continue
            ins = tuple(
                x for v in eqn.invars if (x := self.resolve(v)) is not None
            )
            outs = []
            for v in eqn.outvars:
                if type(v).__name__ == "DropVar":
                    continue
                self.var_tid[v] = self.new_tensor(v.aval, str(v))
                outs.append(self.var_tid[v])
            self.ops.append(
                Op(name=eqn.primitive.name, inputs=ins, outputs=tuple(outs))
            )


def graph_from_jaxpr(
    closed_jaxpr,
    name: str = "jaxpr",
    *,
    inline_nested: bool = True,
    expand_scan: bool = True,
) -> Graph:
    """Convert a ClosedJaxpr to a Graph. The returned Graph carries the
    Var->tensor-id mapping as ``graph.var_tid`` (used by the executor).

    ``expand_scan`` models each ``lax.scan`` as ONE iteration of its body
    (buffers reused across iterations, as a layer-wise engine executes)."""
    global TRACE_CALLS
    TRACE_CALLS += 1
    jaxpr = closed_jaxpr.jaxpr
    b = _Builder(
        frozenset(_INLINE) if inline_nested else frozenset(),
        expand_scan=expand_scan,
    )
    for v in (*jaxpr.constvars, *jaxpr.invars):
        b.var_tid[v] = b.new_tensor(v.aval, str(v))
        b.boundary.add(b.var_tid[v])
    b.walk(jaxpr)
    for v in jaxpr.outvars:
        if isinstance(v, Literal) or type(v).__name__ == "DropVar":
            continue
        if v in b.var_tid:
            b.boundary.add(b.var_tid[v])
    g = Graph(
        name=name, ops=b.ops, tensors=b.tensors, boundary_ids=frozenset(b.boundary)
    )
    g.var_tid = dict(b.var_tid)  # type: ignore[attr-defined]
    g.validate()
    return g


def trace_graph(
    fn: Callable,
    *args,
    name: str | None = None,
    inline_nested: bool = True,
    expand_scan: bool = True,
    **kwargs,
) -> Graph:
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return graph_from_jaxpr(
        closed,
        name=name or getattr(fn, "__name__", "fn"),
        inline_nested=inline_nested,
        expand_scan=expand_scan,
    )
