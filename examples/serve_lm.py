"""End-to-end serving driver (deliverable (b)): thin wrapper over
``repro.launch.serve`` — batched requests against a small model with the
paper's memory planner reporting the decode-step footprint.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
