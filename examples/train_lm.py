"""End-to-end training driver (deliverable (b)): train a reduced
architecture for a few hundred steps on the synthetic pipeline; loss must
drop well below ln(vocab).

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 200
"""

from repro.launch.train import main

if __name__ == "__main__":
    main()
