"""Quickstart: plan ANY JAX function's intermediate-tensor memory.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import plan_graph, plan_records
from repro.models.convnets import mobilenet_v1
from repro.runtime.executor import ArenaExecutor
from repro.trace.jaxpr_liveness import trace_graph


def my_model(x, w1, w2, w3):
    h = jax.nn.relu(x @ w1)
    h = jax.nn.relu(h @ w2)
    return jax.nn.softmax(h @ w3, axis=-1)


def main():
    # 1. The paper's planner on MobileNet v1 (paper Table 2 row 1)
    g = mobilenet_v1()
    plan = plan_graph(g, mode="offsets", strategy="greedy_by_size")
    print("MobileNet v1:", plan.summary())

    # 2. Any JAX function: trace -> usage records -> plan
    args = (jnp.ones((32, 256)), jnp.ones((256, 512)),
            jnp.ones((512, 512)), jnp.ones((512, 10)))
    graph = trace_graph(my_model, *args)
    plan = plan_graph(graph)
    print("my_model:", plan.summary())

    # 3. Execute with REAL buffer reuse: one flat arena, planned offsets
    ex = ArenaExecutor(my_model, *args)
    out = ex(*args)
    ref = my_model(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
    print(f"arena executor: {ex.stats.arena_bytes / 2**20:.3f} MiB arena vs "
          f"{ex.stats.naive_peak_bytes / 2**20:.3f} MiB naive "
          f"({ex.stats.reduction:.2f}x smaller), outputs match jit")


if __name__ == "__main__":
    main()
