"""Reproduce the paper's Tables 1 & 2 and print them side by side with
the published numbers (deliverable (b)/(d)).

Table 2 additionally carries a ``searched_order (ours)`` row per network
— the planned footprint after the memory-aware order/fusion search
(core/order_search, core/fusion_search), a column the paper leaves as
§7.1 future work; validate_paper_claims checks it never loses to the
fixed-order plan and strictly shrinks >= 3 of the 6 networks.

    PYTHONPATH=src:. python examples/paper_tables.py
"""

from benchmarks.tables import (
    table1_shared_objects,
    table2_offsets,
    validate_paper_claims,
)

if __name__ == "__main__":
    t1 = table1_shared_objects()
    t2 = table2_offsets()
    failures = validate_paper_claims(t1, t2)
    raise SystemExit(1 if failures else 0)
