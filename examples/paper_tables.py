"""Reproduce the paper's Tables 1 & 2 and print them side by side with
the published numbers (deliverable (b)/(d)).

    PYTHONPATH=src:. python examples/paper_tables.py
"""

from benchmarks.tables import (
    table1_shared_objects,
    table2_offsets,
    validate_paper_claims,
)

if __name__ == "__main__":
    t1 = table1_shared_objects()
    t2 = table2_offsets()
    failures = validate_paper_claims(t1, t2)
    raise SystemExit(1 if failures else 0)
