"""Paper §7: planning with dynamically-sized tensors, multi-pass.

Scenario: an encoder with static shapes feeds a decoder whose buffer
sizes only become known after the first dynamic tensor is computed
(RNN-style). Plan in stages against ONE arena, never moving live buffers.

    PYTHONPATH=src python examples/dynamic_shapes.py
"""

from repro.core.dynamic import IncrementalPlanner
from repro.core.records import TensorUsageRecord

MB = 2**20


def recs(triples, base_id):
    return [TensorUsageRecord(a, b, s, tensor_id=base_id + i)
            for i, (a, b, s) in enumerate(triples)]


def main():
    # stage 0: statically-known encoder intermediates
    inc = IncrementalPlanner()
    inc.extend(recs([(0, 1, 4 * MB), (1, 3, 2 * MB),
                     (2, 4, 2 * MB), (3, 5, 1 * MB)], base_id=0))
    print(f"stage 0 (static): arena = {inc.total_size / MB:.2f} MiB")

    # stage 1: decoder lengths resolved at run time -> sizes now known
    inc.extend(recs([(5, 7, 3 * MB), (6, 8, 1 * MB)], base_id=100))
    print(f"stage 1 (+decoder): arena = {inc.total_size / MB:.2f} MiB")

    # stage 2: a second resolution point (e.g. beam width growth)
    inc.extend(recs([(8, 9, 2 * MB)], base_id=200))
    print(f"stage 2 (+beams):   arena = {inc.total_size / MB:.2f} MiB")
    print(f"staging overhead vs one-shot plan: "
          f"{inc.overhead_vs_oneshot():.3f}x "
          f"(1.0 = staging cost nothing)")


if __name__ == "__main__":
    main()
