#!/usr/bin/env bash
# Tier-1 CI: full test suite, then the tracked planner-scaling benchmark.
#
#   ./scripts/ci.sh            # everything
#   SKIP_BENCH=1 ./scripts/ci.sh   # tests only
#
# BENCH_planner.json (n, wall-seconds per strategy fast vs oracle,
# total_size, speedup) is the committed perf trajectory — regenerate it
# here so planner regressions show up in review diffs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q

if [[ -z "${SKIP_BENCH:-}" ]]; then
    python benchmarks/planner_scaling.py --quick --out BENCH_planner.json
    # order/fusion search smoke: asserts footprint <= baseline on every
    # config and strictly smaller on >= 3 (BENCH_search.json is the
    # committed trajectory)
    python benchmarks/order_search_bench.py --quick --out BENCH_search.json
fi
