#!/usr/bin/env bash
# Tier-1 CI: full test suite, then the tracked benchmarks.
#
#   ./scripts/ci.sh            # everything
#   SKIP_BENCH=1 ./scripts/ci.sh   # tests only
#
# BENCH_planner.json / BENCH_search.json / BENCH_serve.json are the
# committed perf trajectories — regenerate them here so planner, search,
# and serving regressions show up in review diffs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# no bytecode in the tree: 8 .pyc files were accidentally committed once
if git ls-files | grep -qE '(^|/)__pycache__/|\.pyc$'; then
    echo "ERROR: tracked .pyc/__pycache__ files:" >&2
    git ls-files | grep -E '(^|/)__pycache__/|\.pyc$' >&2
    exit 1
fi

python -m pytest -q

# compile→artifact→serve round trip: AOT-compile a reduced arch, start the
# engine from the bundle, and assert — via the instrumentation counters —
# that serving performed zero jaxpr traces and zero planner calls
python - <<'PY'
import tempfile
import jax
import repro.core.planner as planner
import repro.trace.jaxpr_liveness as tracer
from repro.configs.base import get_reduced
from repro.launch.compile import compile_and_publish
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine

cfg = get_reduced("qwen3-0.6b")
with tempfile.TemporaryDirectory() as d:
    compile_and_publish(cfg, d, n_slots=2, max_len=48, command="scripts/ci.sh")
    params = Model.for_config(cfg).init(jax.random.PRNGKey(0))
    t0, p0 = tracer.TRACE_CALLS, planner.PLAN_CALLS
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=48, plan_bundle=d)
    assert eng.memory_report.plan_source == "bundle", eng.memory_report.bundle_warning
    assert tracer.TRACE_CALLS == t0, "bundle-served engine traced a jaxpr"
    assert planner.PLAN_CALLS == p0, "bundle-served engine invoked the planner"
    import numpy as np
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].tokens) == 3
print("compile→serve round trip: bundle-served, zero traces, zero plans")
PY

if [[ -z "${SKIP_BENCH:-}" ]]; then
    python benchmarks/planner_scaling.py --quick --out BENCH_planner.json
    # order/fusion search smoke: asserts footprint <= baseline on every
    # config and strictly smaller on >= 3 (BENCH_search.json is the
    # committed trajectory)
    python benchmarks/order_search_bench.py --quick --out BENCH_search.json
    # plan-artifact serving smoke: searched <= greedy on every arch,
    # bundle path does zero trace/plan work, cold-start numbers tracked
    python benchmarks/serve_bench.py --quick --out BENCH_serve.json
fi
