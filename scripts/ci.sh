#!/usr/bin/env bash
# Tier-1 CI: full test suite, then the tracked benchmarks.
#
#   ./scripts/ci.sh            # everything
#   SKIP_BENCH=1 ./scripts/ci.sh   # tests only
#
# BENCH_planner.json / BENCH_search.json / BENCH_serve.json /
# BENCH_throughput.json are the committed perf trajectories — regenerate
# them here so planner, search, serving, and decode-throughput
# regressions show up in review diffs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# no bytecode in the tree: 8 .pyc files were accidentally committed once.
# Flags tracked bytecode anywhere AND any tracked file inside a
# __pycache__ dir; a deliberate __pycache__/.gitkeep placeholder is the
# one ignored exception (the dir entry itself is not an artifact leak).
if git ls-files | grep -E '\.py[co]$|(^|/)__pycache__/' \
        | grep -vE '(^|/)__pycache__/\.gitkeep$' | grep -q .; then
    echo "ERROR: tracked bytecode artifacts:" >&2
    git ls-files | grep -E '\.py[co]$|(^|/)__pycache__/' \
        | grep -vE '(^|/)__pycache__/\.gitkeep$' >&2
    exit 1
fi

python -m pytest -q

# the legacy-wrapper shims must stay warning-clean at import time: only
# USING a deprecated kwarg / loading a v1 bundle may warn, importing the
# public modules may not
python -W error::DeprecationWarning - <<'PY'
import repro.core
import repro.core.artifact
import repro.core.planner
import repro.core.unified
import repro.launch.compile
import repro.launch.dryrun
import repro.launch.serve
import repro.runtime.arena
import repro.runtime.engine
import repro.runtime.executor
import repro.runtime.residency
print("import smoke: no DeprecationWarning on import")
PY

# compile→artifact→serve round trip on a fleet sweep: compile.py --all
# over two small archs into ONE temp manifest, then assert serve.py
# bucket auto-selection picks the nearest compiled bucket for a max_len
# with no exact match — with zero jaxpr traces, zero planner calls, and
# zero cross-step state layouts (both halves ship in the v2 bundle).
# State residency: the served engine's LIVE device state bytes must equal
# the bundled StatePlan.total_size exactly (one plan-backed allocation),
# and a REPRO_STATE_RESIDENCY=off rerun must emit identical tokens (the
# residency-on/off differential decode check).
python - <<'PY'
import os
import tempfile
import repro.core.planner as planner
import repro.core.unified as unified
import repro.trace.jaxpr_liveness as tracer
from repro.launch import serve
from repro.launch.compile import main as compile_main
import sys

with tempfile.TemporaryDirectory() as d:
    sys.argv = ["compile", "--all", "--archs", "qwen3-0.6b", "mamba2-2.7b",
                "--slots-list", "2", "--max-lens", "32", "64", "--out", d]
    compile_main()
    t0, p0, s0 = tracer.TRACE_CALLS, planner.PLAN_CALLS, unified.STATE_PLAN_CALLS
    argv = [
        "--arch", "qwen3-0.6b", "--requests", "2", "--prompt-len", "3",
        "--max-new", "2", "--slots", "2", "--max-len", "48",
        "--plan-bundle", d,
    ]
    stats = serve.run(argv)
    assert stats["plan_source"] == "bundle", stats["bundle_warning"]
    assert stats["requested_max_len"] == 48 and stats["effective_max_len"] == 64, stats
    assert tracer.TRACE_CALLS == t0, "auto-selected bundle traced a jaxpr"
    assert planner.PLAN_CALLS == p0, "auto-selected bundle invoked the planner"
    assert unified.STATE_PLAN_CALLS == s0, "auto-selected bundle laid out state"
    assert stats["tokens"] == 4
    # one state allocation: live device state bytes == StatePlan.total_size
    assert stats["state_residency"] is True, stats
    assert stats["state_live_bytes"] == stats["state_planned_bytes"], stats
    # residency-on/off differential: the XLA-allocated baseline must emit
    # the exact same tokens
    os.environ["REPRO_STATE_RESIDENCY"] = "off"
    try:
        baseline = serve.run(argv)
    finally:
        del os.environ["REPRO_STATE_RESIDENCY"]
    assert baseline["state_residency"] is False, baseline
    assert baseline["tokens_per_request"] == stats["tokens_per_request"], (
        "residency-on tokens diverged from the XLA-allocated baseline"
    )
print("compile --all → serve: nearest-bucket auto-selection, "
      "zero traces/plans/state layouts, live state == planned, "
      "residency differential clean")
PY

# scan-block serving: --block-size K must sync with the host EXACTLY once
# per scan block (the HOST_SYNCS counter — same discipline as the
# zero-trace/zero-plan asserts) and emit tokens byte-identical to the
# single-wave host loop.
python - <<'PY'
from repro.launch import serve

host = serve.run(["--requests", "3", "--prompt-len", "4", "--max-new", "6",
                  "--slots", "2", "--max-len", "64"])
block = serve.run(["--requests", "3", "--prompt-len", "4", "--max-new", "6",
                   "--slots", "2", "--max-len", "64", "--block-size", "4"])
assert block["host_syncs"] == block["blocks"], (
    f"{block['host_syncs']} host syncs over {block['blocks']} scan blocks — "
    f"the block path must sync exactly once per block"
)
assert block["host_syncs"] < host["host_syncs"], (host, block)
assert block["tokens_per_request"] == host["tokens_per_request"], (
    "greedy scan-block tokens diverged from the host loop"
)
assert block["slot_log"] == host["slot_log"]
print(f"scan-block serve: {block['host_syncs']} syncs over "
      f"{block['blocks']} blocks (host loop: {host['host_syncs']}), "
      f"greedy tokens + slot log identical")
PY

if [[ -z "${SKIP_BENCH:-}" ]]; then
    python benchmarks/planner_scaling.py --quick --out BENCH_planner.json
    # order/fusion search smoke: asserts footprint <= baseline on every
    # config and strictly smaller on >= 3 (BENCH_search.json is the
    # committed trajectory)
    python benchmarks/order_search_bench.py --quick --out BENCH_search.json
    # plan-artifact serving smoke: searched <= greedy on every arch,
    # bundle path does zero trace/plan work, cold-start numbers tracked
    python benchmarks/serve_bench.py --quick --out BENCH_serve.json
    # decode-throughput smoke: scan-block vs host loop — greedy byte-
    # identical, one host sync per block, block tokens/s > host tokens/s
    python benchmarks/throughput_bench.py --quick --out BENCH_throughput.json
fi
