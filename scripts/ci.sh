#!/usr/bin/env bash
# Tier-1 CI: full test suite, then the tracked benchmarks.
#
#   ./scripts/ci.sh            # everything
#   SKIP_BENCH=1 ./scripts/ci.sh   # tests only
#
# BENCH_planner.json / BENCH_search.json / BENCH_serve.json /
# BENCH_throughput.json are the committed perf trajectories — regenerate
# them here so planner, search, serving, and decode-throughput
# regressions show up in review diffs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# no bytecode in the tree: 8 .pyc files were accidentally committed once.
# Flags tracked bytecode anywhere AND any tracked file inside a
# __pycache__ dir; a deliberate __pycache__/.gitkeep placeholder is the
# one ignored exception (the dir entry itself is not an artifact leak).
if git ls-files | grep -E '\.py[co]$|(^|/)__pycache__/' \
        | grep -vE '(^|/)__pycache__/\.gitkeep$' | grep -q .; then
    echo "ERROR: tracked bytecode artifacts:" >&2
    git ls-files | grep -E '\.py[co]$|(^|/)__pycache__/' \
        | grep -vE '(^|/)__pycache__/\.gitkeep$' >&2
    exit 1
fi

# static lint/typecheck (repo tooling; gated so CI also runs on images
# that bake only the runtime deps — requirements-dev.txt lists both)
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks scripts
else
    echo "ruff not installed; skipping lint (pip install -r requirements-dev.txt)"
fi
if command -v mypy >/dev/null 2>&1; then
    mypy --ignore-missing-imports src/repro/core src/repro/analysis
else
    echo "mypy not installed; skipping typecheck (pip install -r requirements-dev.txt)"
fi

python -m pytest -q

# the legacy-wrapper shims must stay warning-clean at import time: only
# USING a deprecated kwarg / loading a v1 bundle may warn, importing the
# public modules may not
python -W error::DeprecationWarning - <<'PY'
import repro.analysis
import repro.analysis.bundle_lint
import repro.analysis.counters
import repro.analysis.decode_lint
import repro.analysis.lint
import repro.analysis.soundness
import repro.core
import repro.core.artifact
import repro.core.planner
import repro.core.unified
import repro.launch.compile
import repro.launch.dryrun
import repro.launch.serve
import repro.runtime.arena
import repro.runtime.engine
import repro.runtime.executor
import repro.runtime.residency
print("import smoke: no DeprecationWarning on import")
PY

# python -O smoke: under -O assert statements are stripped, so every
# checker must raise explicitly. Imports must work, and the core/validate
# oracle + the analysis certifier must both still flag a corrupt plan.
python -O - <<'PY'
import repro.analysis.lint
import repro.launch.compile
import repro.runtime.engine
from repro.analysis import soundness
from repro.core import validate
from repro.core.records import make_records

recs = make_records([(0, 2, 64), (1, 3, 64)])  # overlap in time...
offsets = {0: 0, 1: 0}                         # ...and in memory
try:
    validate.check_offsets(
        recs, type("A", (), {"strategy": "x", "offsets": offsets,
                             "total_size": 64})()
    )
except validate.PlanValidationError:
    pass
else:
    raise SystemExit("python -O silently disabled core/validate!")
findings = soundness.certify_offsets(recs, offsets, 64)
assert_ok = [f for f in findings if f.code == "arena-collision"]
if not assert_ok:
    raise SystemExit("python -O: certifier missed the collision!")
print("python -O smoke: checkers still raise with asserts stripped")
PY

# compile→artifact→serve round trip on a fleet sweep: compile.py --all
# over two small archs into ONE temp manifest (through the default-on
# pre-publish lint gate), then:
#   * repro.analysis.lint bundles over the manifest must come back with
#     ZERO findings (--strict: warnings fail too) — the committed
#     zero-findings baseline;
#   * the compiled decode step + scan block for both archs must pass the
#     static decode lint: donation aliased, zero host transfers;
#   * serve.py bucket auto-selection picks the nearest compiled bucket
#     for a max_len with no exact match — with zero jaxpr traces, zero
#     planner calls, zero cross-step state layouts, and zero XLA
#     compiles through every served token (plans AND AOT decode
#     executables ship in the v3 bundle).
# State residency: the served engine's LIVE device state bytes must equal
# the bundled StatePlan.total_size exactly (one plan-backed allocation),
# and a REPRO_STATE_RESIDENCY=off rerun must emit identical tokens (the
# residency-on/off differential decode check).
python - <<'PY'
import os
import tempfile
from repro.analysis import counters
from repro.analysis.lint import main as lint_main
from repro.launch import serve
from repro.launch.compile import main as compile_main
import sys

with tempfile.TemporaryDirectory() as d:
    sys.argv = ["compile", "--all", "--archs", "qwen3-0.6b", "mamba2-2.7b",
                "--slots-list", "2", "--max-lens", "32", "64", "--out", d]
    compile_main()
    rc = lint_main(["--strict", "bundles", d])
    assert rc == 0, f"bundle lint over the CI sweep manifest failed ({rc})"
    rc = lint_main(["decode", "qwen3-0.6b", "mamba2-2.7b",
                    "--slots", "2", "--max-len", "32", "--block", "4"])
    assert rc == 0, f"compiled-decode lint failed ({rc})"
    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls", "compile_calls"
    ) as cap:
        argv = [
            "--arch", "qwen3-0.6b", "--requests", "2", "--prompt-len", "3",
            "--max-new", "2", "--slots", "2", "--max-len", "48",
            "--plan-bundle", d,
        ]
        stats = serve.run(argv)
    assert stats["plan_source"] == "bundle", stats["bundle_warning"]
    assert stats["requested_max_len"] == 48 and stats["effective_max_len"] == 64, stats
    assert cap.delta("trace_calls") == 0, "auto-selected bundle traced a jaxpr"
    assert cap.delta("plan_calls") == 0, "auto-selected bundle invoked the planner"
    assert cap.delta("state_plan_calls") == 0, "auto-selected bundle laid out state"
    assert stats["aot_warning"] is None, stats["aot_warning"]
    assert stats["aot_executables"], "v3 bundle served without AOT executables"
    assert cap.delta("compile_calls") == 0, (
        "v3 bundle paid an XLA compile — zero-compile cold start broken"
    )
    assert stats["tokens"] == 4
    # one state allocation: live device state bytes == StatePlan.total_size
    assert stats["state_residency"] is True, stats
    assert stats["state_live_bytes"] == stats["state_planned_bytes"], stats
    # residency-on/off differential: the XLA-allocated baseline must emit
    # the exact same tokens
    os.environ["REPRO_STATE_RESIDENCY"] = "off"
    try:
        baseline = serve.run(argv)
    finally:
        del os.environ["REPRO_STATE_RESIDENCY"]
    assert baseline["state_residency"] is False, baseline
    assert baseline["tokens_per_request"] == stats["tokens_per_request"], (
        "residency-on tokens diverged from the XLA-allocated baseline"
    )
print("compile --all → lint → serve: zero-findings manifest, decode lint "
      "clean (donation aliased, no host transfers), nearest-bucket "
      "auto-selection with zero traces/plans/state layouts, live state == "
      "planned, residency differential clean")
PY

# scan-block serving: --block-size K must sync with the host EXACTLY once
# per scan block (the host_syncs counter — same discipline as the
# zero-trace/zero-plan asserts) and emit tokens byte-identical to the
# single-wave host loop.
python - <<'PY'
from repro.launch import serve

host = serve.run(["--requests", "3", "--prompt-len", "4", "--max-new", "6",
                  "--slots", "2", "--max-len", "64"])
block = serve.run(["--requests", "3", "--prompt-len", "4", "--max-new", "6",
                   "--slots", "2", "--max-len", "64", "--block-size", "4"])
assert block["host_syncs"] == block["blocks"], (
    f"{block['host_syncs']} host syncs over {block['blocks']} scan blocks — "
    f"the block path must sync exactly once per block"
)
assert block["host_syncs"] < host["host_syncs"], (host, block)
assert block["tokens_per_request"] == host["tokens_per_request"], (
    "greedy scan-block tokens diverged from the host loop"
)
assert block["slot_log"] == host["slot_log"]
print(f"scan-block serve: {block['host_syncs']} syncs over "
      f"{block['blocks']} blocks (host loop: {host['host_syncs']}), "
      f"greedy tokens + slot log identical")
PY

# paged state serving: a --page-size bucket compiles through the same
# pre-publish gate into a v3 manifest whose --strict lint baseline now
# covers the paged-* soundness codes + paged-meta-mismatch, serves with
# zero traces / plans / state layouts / XLA compiles, emits tokens
# identical to the symmetric host loop, and reports honest page
# economics: live state bytes == pages_live x page_size, peak pool
# usage strictly under the symmetric plan's constant footprint.
python - <<'PY'
import sys
import tempfile
from repro.analysis import counters
from repro.analysis.lint import main as lint_main
from repro.launch import serve
from repro.launch.compile import main as compile_main

with tempfile.TemporaryDirectory() as d:
    sys.argv = ["compile", "--arch", "qwen3-0.6b", "--slots", "2",
                "--max-len", "64", "--page-size", "1024", "--out", d]
    compile_main()
    rc = lint_main(["--strict", "bundles", d])
    assert rc == 0, f"paged bundle failed the --strict lint baseline ({rc})"
    argv = ["--arch", "qwen3-0.6b", "--requests", "3", "--prompt-len", "4",
            "--max-new", "4", "--slots", "2", "--max-len", "64"]
    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls", "compile_calls"
    ) as cap:
        paged = serve.run(argv + ["--page-size", "1024",
                                  "--plan-bundle", d])
    assert paged["plan_source"] == "bundle", paged["bundle_warning"]
    for c in ("trace_calls", "plan_calls", "state_plan_calls",
              "compile_calls"):
        assert cap.delta(c) == 0, f"paged bundle serve paid {c}"
    assert paged["page_size"] == 1024, paged
    # report honesty: live bytes ARE pages_live x page_size (drained
    # engine: both zero), and the peak never exceeded the pool
    assert paged["state_live_bytes"] == paged["state_pages_live"] * 1024, paged
    peak = paged["state_pages_live_peak"]
    assert 0 < peak <= paged["state_pages_total"], paged
    # the paged win: peak pool bytes strictly under the symmetric plan's
    # constant n_slots x slot_stride footprint at this fill
    assert peak * 1024 < paged["state_planned_bytes"], (
        f"paged peak {peak * 1024} B >= symmetric {paged['state_planned_bytes']} B"
    )
    assert paged["page_log"], "paged serve logged no page residencies"
    # byte-identity headline: same tokens as the symmetric host loop
    sym = serve.run(argv)
    assert paged["tokens_per_request"] == sym["tokens_per_request"], (
        "paged tokens diverged from the symmetric baseline"
    )
print(f"paged serve: --strict lint clean, zero traces/plans/compiles, "
      f"live bytes == pages_live x page_size, peak "
      f"{peak} pages < symmetric footprint, tokens identical")
PY

# planner scaling smoke: the full portfolio legs must plan a 50k-record
# graph inside a hard wall-clock ceiling (the pre-heap greedy-by-size
# -improved took ~67 s here; the pre-vectorized arena minutes), and the
# resulting plan must pass the soundness certifier — fast AND sound, not
# fast instead of sound.
python - <<'PY'
import sys
import time
sys.path.insert(0, "benchmarks")
from planner_scaling import synth_records
from repro.analysis import soundness
from repro.core.planner import plan_records

recs = synth_records(50_000)
t0 = time.perf_counter()
plan = plan_records(recs, mode="offsets", strategy="greedy_by_size",
                    graph_name="ci-smoke-50k")
improved = plan_records(recs, mode="shared_objects",
                        strategy="greedy_by_size_improved",
                        graph_name="ci-smoke-50k")
wall = time.perf_counter() - t0
CEILING_S = 30.0
assert wall < CEILING_S, (
    f"50k-record planning took {wall:.1f}s >= {CEILING_S}s ceiling — "
    "a fast path regressed to quadratic"
)
for p in (plan, improved):
    errors = [f for f in soundness.certify_plan(p) if f.severity == "error"]
    assert not errors, f"{p.strategy}: {[f.message for f in errors]}"
print(f"planner smoke: 50k records planned in {wall:.1f}s "
      f"(< {CEILING_S:.0f}s ceiling), offsets {plan.total_size} B / "
      f"shared-objects {improved.total_size} B, both certified sound")
PY

# prefill+decode round trip: a --prefill-len bucket compiles through the
# same pre-publish gate into a v4 bundle that carries a PLANNED prefill
# activation arena (certified by the --strict lint baseline alongside the
# decode plan), keys its bucket with the |pfN suffix, and still serves
# decode requests with zero traces / plans / state layouts / XLA
# compiles — prefill metadata is inert extra planning, never a serving
# cost.
python - <<'PY'
import json
import pathlib
import sys
import tempfile
from repro.analysis import counters
from repro.analysis.lint import main as lint_main
from repro.core import artifact
from repro.launch import serve
from repro.launch.compile import main as compile_main

with tempfile.TemporaryDirectory() as d:
    sys.argv = ["compile", "--arch", "qwen3-0.6b", "--slots", "2",
                "--max-len", "64", "--prefill-len", "32", "--out", d]
    compile_main()
    rc = lint_main(["--strict", "bundles", d])
    assert rc == 0, f"prefill bundle failed the --strict lint baseline ({rc})"
    manifest = json.loads((pathlib.Path(d) / "manifest.json").read_text())
    keys = list(manifest["buckets"])
    assert any(k.endswith("|pf32") for k in keys), keys
    bundle = artifact.load_bundle(
        pathlib.Path(d) / manifest["buckets"][keys[0]]["file"])
    assert bundle.prefill_len == 32 and bundle.prefill_plan is not None, (
        bundle.summary()
    )
    assert bundle.peak_activation_size >= bundle.plan.total_size
    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls", "compile_calls"
    ) as cap:
        stats = serve.run(["--arch", "qwen3-0.6b", "--requests", "2",
                           "--prompt-len", "3", "--max-new", "2",
                           "--slots", "2", "--max-len", "64",
                           "--plan-bundle", d])
    assert stats["plan_source"] == "bundle", stats["bundle_warning"]
    for c in ("trace_calls", "plan_calls", "state_plan_calls",
              "compile_calls"):
        assert cap.delta(c) == 0, f"prefill bundle serve paid {c}"
    assert stats["tokens"] == 4
print("prefill round trip: --prefill-len 32 bundle lints clean (strict), "
      "bucket keyed |pf32, planned prefill arena on board, decode serve "
      "zero traces/plans/state layouts/compiles")
PY

if [[ -z "${SKIP_BENCH:-}" ]]; then
    python benchmarks/planner_scaling.py --quick --out BENCH_planner.json
    # order/fusion search smoke: asserts footprint <= baseline on every
    # config and strictly smaller on >= 3 (BENCH_search.json is the
    # committed trajectory)
    python benchmarks/order_search_bench.py --quick --out BENCH_search.json
    # plan-artifact serving smoke: searched <= greedy on every arch,
    # bundle path does zero trace/plan work, cold-start numbers tracked
    python benchmarks/serve_bench.py --quick --out BENCH_serve.json
    # decode-throughput smoke: scan-block vs host loop — greedy byte-
    # identical, one host sync per block, block tokens/s > host tokens/s
    python benchmarks/throughput_bench.py --quick --out BENCH_throughput.json
fi
