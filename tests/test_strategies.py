"""Unit tests for the five paper strategies + prior-work baselines."""

import pytest

from repro.core import baselines, offsets, shared_objects
from repro.core.offsets import from_shared_objects
from repro.core.records import (
    make_records,
    naive_consumption,
    offsets_lower_bound,
    shared_objects_lower_bound,
)
from repro.core.validate import check_offsets, check_shared_objects

FIG = [
    (0, 1, 32),
    (1, 4, 28),
    (2, 3, 36),
    (3, 5, 16),
    (4, 5, 8),
    (5, 7, 64),
    (6, 7, 10),
]

CHAIN = [(i, i + 1, 100) for i in range(10)]  # simple chain: 2 buffers suffice

ALL_SO = {
    **shared_objects.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order,
    "min_cost_flow": baselines.min_cost_flow_assignment,
    "naive": baselines.naive_shared_objects,
}
ALL_OFF = {
    **offsets.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order_offsets,
    "strip_packing_bestfit": baselines.strip_packing_bestfit,
    "naive": baselines.naive_offsets,
}


@pytest.mark.parametrize("name,fn", sorted(ALL_SO.items()))
@pytest.mark.parametrize("triples", [FIG, CHAIN], ids=["fig", "chain"])
def test_shared_objects_valid(name, fn, triples):
    recs = make_records(triples)
    asn = fn(recs)
    check_shared_objects(recs, asn)


@pytest.mark.parametrize("name,fn", sorted(ALL_OFF.items()))
@pytest.mark.parametrize("triples", [FIG, CHAIN], ids=["fig", "chain"])
def test_offsets_valid(name, fn, triples):
    recs = make_records(triples)
    asn = fn(recs)
    check_offsets(recs, asn)


def test_chain_alternation():
    """A pure chain must plan to exactly 2 buffers of 100 (the paper's
    'alternating fashion' motivating example) for every real strategy."""
    recs = make_records(CHAIN)
    assert shared_objects_lower_bound(recs) == 200
    assert offsets_lower_bound(recs) == 200
    for name, fn in ALL_SO.items():
        if name == "naive":
            continue
        assert fn(recs).total_size == 200, name
    for name, fn in ALL_OFF.items():
        if name == "naive":
            continue
        assert fn(recs).total_size == 200, name


def test_greedy_by_size_offsets_hits_lb_on_fig():
    recs = make_records(FIG)
    asn = offsets.greedy_by_size_offsets(recs)
    check_offsets(recs, asn)
    # Hand-trace: t5@0, t2@0, t0@0, t1@36, t3@64, t6@64, t4@80 -> 88,
    # which equals the lower bound (max breadth at op5 = 16+8+64 = 88).
    assert asn.total_size == 88 == offsets_lower_bound(recs)


def test_shared_objects_known_totals_on_fig():
    recs = make_records(FIG)
    gbs = shared_objects.greedy_by_size(recs)
    gbb = shared_objects.greedy_by_breadth(recs)
    gbsi = shared_objects.greedy_by_size_improved(recs)
    for a in (gbs, gbb, gbsi):
        check_shared_objects(recs, a)
    # GBS: sizes desc 64,36,28,16,10,8 ->
    #   64(t5:5-7) obj0; 36(t2:2-3) fits obj0 (2-3 vs 5-7 disjoint) -> obj0
    #   28(t1:1-4) overlaps t2 -> obj1; 16(t3:3-5) overlaps both -> obj2
    #   10(t6:6-7) overlaps t5; fits obj1 (1-4) -> obj1
    #   8(t4:4-5) overlaps t1(obj1),t3(obj2),t5(obj0 5-7? 4-5 vs 5-7 overlap)
    #     -> new obj3 of 8.  t0(0-1,32): obj0 has 2-3,5-7 free at 0-1 -> obj0
    # total = 64 + 28 + 16 + 8 = 116
    assert gbs.total_size == 116
    # improved should never be worse than plain GBS (paper §4.4 claim)
    assert gbsi.total_size <= gbs.total_size


def test_from_shared_objects_conversion():
    recs = make_records(FIG)
    so = shared_objects.greedy_by_size(recs)
    off = from_shared_objects(so)
    check_offsets(recs, off)
    assert off.total_size == so.total_size


def test_mcf_simple_reuse():
    # two disjoint tensors must share one object under MCF
    recs = make_records([(0, 1, 50), (2, 3, 40)])
    asn = baselines.min_cost_flow_assignment(recs)
    check_shared_objects(recs, asn)
    assert asn.total_size == 50
    assert len({oid for oid in asn.assignment.values()}) == 1


def test_empty_and_single():
    assert naive_consumption([]) == 0
    for fn in ALL_SO.values():
        assert fn([]).total_size == 0
    for fn in ALL_OFF.values():
        assert fn([]).total_size == 0
    one = make_records([(0, 0, 64)])
    for fn in ALL_SO.values():
        assert fn(one).total_size == 64
    for fn in ALL_OFF.values():
        assert fn(one).total_size == 64
