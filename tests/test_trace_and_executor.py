"""jaxpr liveness extraction + arena executor end-to-end correctness.

The arena executor is the strongest validity test of the planner: every
intermediate lives at its planned offset in ONE buffer, so any liveness or
overlap bug corrupts the numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.planner import plan_graph
from repro.core.validate import check_offsets
from repro.runtime.executor import ArenaExecutor
from repro.trace.jaxpr_liveness import trace_graph


def mlp(x, w1, w2, w3):
    h = jnp.tanh(x @ w1)
    h = jnp.tanh(h @ w2)
    return h @ w3


def residual_net(x, w):
    # residual connections make sharing non-trivial (paper §1)
    for _ in range(4):
        x = x + jnp.tanh(x @ w)
    return x.sum()


def nested(x):
    @jax.jit
    def inner(y):
        return jnp.sin(y) * 2.0

    return inner(x) + inner(x * 2.0)


CASES = {
    "mlp": (
        mlp,
        (
            jnp.ones((8, 16)),
            jnp.ones((16, 32)),
            jnp.ones((32, 32)),
            jnp.ones((32, 4)),
        ),
    ),
    "residual": (residual_net, (jnp.ones((4, 8)), jnp.eye(8))),
    "nested_jit": (nested, (jnp.arange(12.0).reshape(3, 4),)),
    "softmax_chain": (
        lambda x: jax.nn.softmax(jax.nn.relu(x @ x.T) + 1.0, axis=-1).mean(),
        (jnp.arange(20.0).reshape(4, 5) / 10.0,),
    ),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_trace_produces_valid_plannable_graph(case):
    fn, args = CASES[case]
    g = trace_graph(fn, *args)
    assert len(g.ops) > 0
    recs = g.usage_records()
    assert recs, "graph must have intermediate tensors"
    plan = plan_graph(g)
    check_offsets(recs, type("A", (), {
        "strategy": plan.strategy, "offsets": plan.offsets,
        "total_size": plan.total_size})())


@pytest.mark.parametrize("case", sorted(CASES))
def test_arena_executor_matches_plain_execution(case):
    fn, args = CASES[case]
    ex = ArenaExecutor(fn, *args)
    got = ex(*args)
    want = fn(*args)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6
        ),
        got,
        want,
    )


def test_arena_is_smaller_than_naive():
    fn, args = CASES["mlp"]
    ex = ArenaExecutor(fn, *args)
    assert ex.stats.arena_bytes < ex.stats.naive_peak_bytes
    assert ex.stats.reduction > 1.5  # chains share aggressively


def test_executor_runs_many_times_same_arena():
    fn, args = CASES["residual"]
    ex = ArenaExecutor(fn, *args)
    buf_id = id(ex.arena.buf)
    for scale in (1.0, 2.0, -0.5):
        scaled = (args[0] * scale, args[1])
        got = ex(*scaled)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(fn(*scaled)), rtol=1e-5
        )
    assert id(ex.arena.buf) == buf_id  # no reallocation between runs


def test_arena_view_is_bounds_checked():
    """Regression: ``Arena.view`` used to hand out views past a tensor's
    planned slot, silently aliasing the NEXT tensor's bytes — ``store``
    checked, ``view`` did not."""
    from repro.core.planner import plan_records
    from repro.core.records import make_records
    from repro.runtime.arena import Arena

    # two 64 B tensors live simultaneously -> distinct adjacent slots
    plan = plan_records(make_records([(0, 1, 64), (0, 1, 64)]), use_cache=False)
    arena = Arena(plan)
    fits = arena.view(0, (16,), np.float32)  # exactly the planned 64 B
    assert fits.nbytes == 64

    neighbor = arena.store(1, np.full(16, 7.0, np.float32))
    with pytest.raises(ValueError, match="exceeds planned"):
        arena.view(0, (17,), np.float32)  # 68 B > 64 B slot
    with pytest.raises(ValueError, match="exceeds"):
        arena.view(0, (16,), np.float64)  # same count, fatter dtype
    np.testing.assert_array_equal(neighbor, np.full(16, 7.0, np.float32))

    # a stale layout offset pointing past the buffer is also refused
    arena.layout.offsets[0] = arena.buf.nbytes - 32
    with pytest.raises(ValueError, match="arena"):
        arena.view(0, (16,), np.float32)


def test_boundary_tensors_excluded():
    fn, args = CASES["mlp"]
    g = trace_graph(fn, *args)
    recs = g.usage_records()
    rec_ids = {r.tensor_id for r in recs}
    assert not (rec_ids & set(g.boundary_ids))
    # inputs (x, w1, w2, w3) and the final output are boundary
    assert len(g.boundary_ids) >= 5


def test_arena_executor_runs_full_model_forward():
    """The arena executor handles a REAL model graph (scan, attention,
    rope, GQA) — intermediates in one planned arena, allclose vs jit."""
    from repro.configs.base import get_reduced
    from repro.models.api import Model

    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)

    def fwd(params, tokens):
        logits, _ = model.forward(params, {"tokens": tokens})
        return logits

    ex = ArenaExecutor(fwd, params, tokens)
    got = ex(params, tokens)
    want = fwd(params, tokens)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )
    assert ex.stats.arena_bytes < ex.stats.naive_peak_bytes


def test_executor_accepts_precomputed_plan():
    """The AOT pipeline hands the executor a plan from a bundle: it must be
    used verbatim (no planner call) and rejected when it does not cover
    this graph's records — a stale artifact must never alias live bytes."""
    from repro.core.planner import plan_graph
    from repro.trace.jaxpr_liveness import trace_graph

    fn, args = CASES["mlp"]
    graph = trace_graph(fn, *args, expand_scan=False)
    plan = plan_graph(graph, mode="offsets", alignment=64)

    ex = ArenaExecutor(fn, *args, plan=plan)
    assert ex.plan is plan
    np.testing.assert_allclose(
        np.asarray(ex(*args)), np.asarray(fn(*args)), rtol=1e-5, atol=1e-6
    )

    other_fn, other_args = CASES["residual"]
    with pytest.raises(ValueError, match="does not match"):
        ArenaExecutor(other_fn, *other_args, plan=plan)


def test_arena_layout_validate_rejects_out_of_bounds():
    from repro.runtime.arena import Arena, ArenaLayout

    layout = ArenaLayout(total_size=64, offsets={0: 48}, sizes={0: 32})
    with pytest.raises(ValueError, match="outside"):
        Arena(layout)
