"""Order/fusion search: incremental-record correctness, topo validity,
determinism, and never-worse-than-baseline guarantees.

The searches are the outer loop the plan cache was built for, so these
tests also pin the loop's contract: every candidate costed through
``plan_records``, identical results for identical seeds, and results that
are real topological orders (or valid fused partitions) of the input.
"""

import collections
import random

import pytest

from repro.core.fusion_search import (
    FusionSearchResult,
    fuse_groups,
    fusion_search,
    internal_bytes,
)
from repro.core.graph import Graph, graph_from_records
from repro.core.order_search import (
    IncrementalRecords,
    memory_aware_topo_order,
    search_order,
    simulated_annealing_order,
)
from repro.core.plan_io import PlanCache
from repro.core.records import make_records
from repro.models.convnets import PAPER_NETWORKS

NETS = ["mobilenet_v1", "blazeface", "inception_v3"]


def _op_multiset(g: Graph):
    return collections.Counter((op.name, op.inputs, op.outputs) for op in g.ops)


def _random_graph(seed: int, n: int = 24) -> Graph:
    rng = random.Random(seed)
    recs = []
    for i in range(n):
        a = rng.randrange(12)
        b = rng.randrange(a, 12)
        recs.append((a, b, 64 * rng.randrange(1, 16)))
    return graph_from_records(make_records(recs), name=f"rand{seed}")


# ------------------------------------------------- incremental records


@pytest.mark.parametrize("net", ["mobilenet_v2", "inception_v3"])
def test_incremental_records_match_full_rebuild(net):
    """After any sequence of legal adjacent swaps, the incremental records
    equal a from-scratch extraction on the reordered graph."""
    g = PAPER_NETWORKS[net]()
    inc = IncrementalRecords(g)
    rng = random.Random(0)
    n = len(g.ops)
    for _ in range(300):
        k = rng.randrange(n - 1)
        if inc.can_swap(k):
            inc.swap(k)
    reordered = inc.reordered_graph()
    reordered.validate()
    assert sorted(inc.records()) == sorted(reordered.usage_records())


def test_incremental_swap_is_self_inverse():
    g = PAPER_NETWORKS["inception_v3"]()
    inc = IncrementalRecords(g)
    before_order = list(inc.order)
    before = sorted(inc.records())
    k = next(k for k in range(len(g.ops) - 1) if inc.can_swap(k))
    inc.swap(k)
    inc.swap(k)
    assert inc.order == before_order
    assert sorted(inc.records()) == before


def test_can_swap_refuses_dependent_pair():
    g = PAPER_NETWORKS["mobilenet_v1"]()  # pure chain: nothing may swap
    inc = IncrementalRecords(g)
    assert not any(inc.can_swap(k) for k in range(len(g.ops) - 1))


# ------------------------------------------------------- order search


@pytest.mark.parametrize("net", NETS)
def test_search_order_valid_topo_and_same_multiset(net):
    g = PAPER_NETWORKS[net]()
    res = search_order(g, iters=150, seed=0)
    res.graph.validate()
    assert _op_multiset(res.graph) == _op_multiset(g)
    assert res.graph.tensors == g.tensors
    assert res.graph.boundary_ids == g.boundary_ids
    assert sorted(res.order) == list(range(len(g.ops)))


@pytest.mark.parametrize("net", NETS)
def test_search_order_never_worse_than_baseline(net):
    res = search_order(PAPER_NETWORKS[net](), iters=150, seed=0)
    assert res.plan.total_size <= res.baseline_plan.total_size
    assert res.delta_bytes >= 0


def test_search_order_deterministic_for_fixed_seed():
    g = _random_graph(3)
    a = search_order(g, iters=200, seed=7)
    b = search_order(g, iters=200, seed=7)
    assert a.order == b.order
    assert a.plan.total_size == b.plan.total_size
    assert a.plan.offsets == b.plan.offsets


def test_search_order_counts_cache_traffic():
    cache = PlanCache()
    res = search_order(_random_graph(5), iters=200, seed=0, cache=cache)
    assert res.evaluations >= 2
    assert res.cache_hits + res.cache_misses == cache.hits + cache.misses
    assert 0.0 <= res.cache_hit_rate <= 1.0
    # annealing revisits record multisets: a warm rerun must be all hits
    rerun = search_order(_random_graph(5), iters=200, seed=0, cache=cache)
    assert rerun.cache_misses == 0 and rerun.cache_hit_rate == 1.0


def test_search_order_never_worse_even_with_proxy_objective():
    """The lower-bound proxy can prefer an order whose REAL plan is
    larger; the returned plan must still honor the never-worse contract."""
    for seed in range(6):
        g = _random_graph(seed)
        res = search_order(g, iters=200, seed=seed, objective="lower_bound")
        assert res.plan.total_size <= res.baseline_plan.total_size
        res.graph.validate()
        assert _op_multiset(res.graph) == _op_multiset(g)


def test_memory_aware_topo_order_valid_and_same_multiset():
    for seed in range(4):
        g = _random_graph(seed)
        g2 = memory_aware_topo_order(g)
        g2.validate()
        assert _op_multiset(g2) == _op_multiset(g)


def test_simulated_annealing_back_compat_wrapper():
    g = _random_graph(11)
    g2 = simulated_annealing_order(g, iters=100, seed=0)
    g2.validate()
    assert _op_multiset(g2) == _op_multiset(g)


# ------------------------------------------------------ fusion search


def test_fuse_groups_requires_contiguous_partition():
    g = PAPER_NETWORKS["mobilenet_v1"]()
    n = len(g.ops)
    with pytest.raises(ValueError):
        fuse_groups(g, [(0, 2), (1,)] + [(i,) for i in range(3, n)])


def test_fuse_groups_internalizes_only_fully_consumed_tensors():
    g = PAPER_NETWORKS["mobilenet_v1"]()
    fused = fuse_groups(g, [(0, 1)] + [(i,) for i in range(2, len(g.ops))])
    fused.validate()
    # the tensor flowing from op0 to op1 is consumed beyond the group
    # (op1's output feeds op2), so only tensors whose every consumer is
    # inside the group may vanish from the op list
    used = {t for op in fused.ops for t in (*op.inputs, *op.outputs)}
    for op in g.ops[2:]:
        for t in op.inputs:
            assert t in used
    assert fused.tensors == g.tensors  # specs are never dropped


@pytest.mark.parametrize("net", NETS)
def test_fusion_search_never_worse_and_valid(net):
    g = PAPER_NETWORKS[net]()
    res = fusion_search(g)
    assert isinstance(res, FusionSearchResult)
    res.graph.validate()
    assert res.plan.total_size <= res.baseline_plan.total_size
    # partition covers the op indices exactly, in order
    flat = [i for grp in res.groups for i in grp]
    assert flat == list(range(len(g.ops)))
    # planned tensors are a subset of the original intermediates
    orig = set(g.intermediate_ids())
    assert {r.tensor_id for r in res.plan.records} <= orig


def test_fusion_search_strictly_improves_mobilenet_v1():
    """The breadth peak of MobileNet v1 is a producer->consumer pair of
    large tensors no reordering can move — fusion internalizes it."""
    res = fusion_search(PAPER_NETWORKS["mobilenet_v1"]())
    assert res.delta_bytes > 0
    assert res.n_fused_groups >= 1
    assert res.internalized_bytes > 0


def test_fusion_search_respects_local_budget():
    g = PAPER_NETWORKS["mobilenet_v1"]()
    budget = 2**20  # 1 MiB: too small for the multi-MiB early tensors
    res = fusion_search(g, local_budget=budget)
    for grp in res.groups:
        if len(grp) > 1:
            assert internal_bytes(g, grp) <= budget
    # zero budget means nothing can fuse
    res0 = fusion_search(g, local_budget=0)
    assert res0.n_fused_groups == 0
    assert res0.plan.total_size == res0.baseline_plan.total_size


def test_fusion_search_deterministic():
    g = PAPER_NETWORKS["posenet"]()
    a = fusion_search(g)
    b = fusion_search(g)
    assert a.groups == b.groups
    assert a.plan.total_size == b.plan.total_size


def test_order_and_fusion_share_plan_cache():
    """The outer-sweep regime: re-running both searches against a warm
    shared cache is pure cache traffic."""
    g = PAPER_NETWORKS["blazeface"]()
    cache = PlanCache()
    search_order(g, iters=100, seed=0, cache=cache)
    fusion_search(g, cache=cache)
    o2 = search_order(g, iters=100, seed=0, cache=cache)
    f2 = fusion_search(g, cache=cache)
    assert o2.cache_misses == 0
    assert f2.cache_misses == 0
