"""Unit tests for paper §3 definitions + §4.1/§5.1 lower bounds."""

import pytest

from repro.core.records import (
    TensorUsageRecord,
    align,
    make_records,
    naive_consumption,
    offsets_lower_bound,
    operator_breadths,
    operator_profiles,
    positional_maximums,
    shared_objects_lower_bound,
)

# A small network in the spirit of the paper's Fig. 1/2. Triples are
# (first_op, last_op, size). Hand-checked numbers below.
FIG = [
    (0, 1, 32),  # t0
    (1, 4, 28),  # t1
    (2, 3, 36),  # t2
    (3, 5, 16),  # t3
    (4, 5, 8),   # t4
    (5, 7, 64),  # t5
    (6, 7, 10),  # t6
]


def test_align():
    assert align(1) == 64
    assert align(64) == 64
    assert align(65) == 128
    assert align(100, 8) == 104
    with pytest.raises(ValueError):
        align(10, 0)


def test_record_validation():
    with pytest.raises(ValueError):
        TensorUsageRecord(first_op=3, last_op=2, size=1)
    with pytest.raises(ValueError):
        TensorUsageRecord(first_op=0, last_op=0, size=0)


def test_overlap_is_closed_interval():
    a = TensorUsageRecord(0, 2, 4, tensor_id=0)
    b = TensorUsageRecord(2, 5, 4, tensor_id=1)
    c = TensorUsageRecord(3, 5, 4, tensor_id=2)
    assert a.overlaps(b) and b.overlaps(a)  # touch at op 2 => both live there
    assert not a.overlaps(c)


def test_operator_profiles_and_breadths():
    recs = make_records(FIG)
    profiles = operator_profiles(recs)
    assert len(profiles) == 8
    # op 0: only t0. op 3: t1, t2, t3 live.
    assert [r.tensor_id for r in profiles[0]] == [0]
    assert sorted(r.tensor_id for r in profiles[3]) == [1, 2, 3]
    # profile sorted by size desc: t2(36), t1(28), t3(16)
    assert [r.size for r in profiles[3]] == [36, 28, 16]
    breadths = operator_breadths(recs)
    assert breadths == [32, 60, 64, 80, 52, 88, 74, 74]


def test_positional_maximums_and_bounds():
    recs = make_records(FIG)
    pm = positional_maximums(recs)
    # depth 3 (ops 3 and 5 have 3 live tensors)
    # col0: max(32,32,36,36,28,64,64,64)=64
    # col1: max(28,28,28,16,28,16,10,10)=28
    # col2: max live third-largest: op3 -> 16, op5 -> 8
    assert pm == [64, 28, 16]
    assert shared_objects_lower_bound(recs) == 64 + 28 + 16
    assert offsets_lower_bound(recs) == 88  # op 5: 16+8+64
    assert naive_consumption(recs) == 32 + 28 + 36 + 16 + 8 + 64 + 10


def test_paper_stated_facts_shape():
    """Sanity on the definitions via the paper's own stated example facts:
    an operator's breadth is the sum of its profile sizes; the i-th
    positional maximum is a max over i-th largest profile entries."""
    recs = make_records([(0, 2, 36), (1, 3, 28), (2, 3, 16), (3, 4, 10)])
    profiles = operator_profiles(recs)
    # operator 2 sees 36, 28, 16 -> breadth 80 (paper's op-3 example value)
    assert sum(r.size for r in profiles[2]) == 80
    assert operator_breadths(recs)[2] == 80
    pm = positional_maximums(recs)
    assert pm[2] == 16  # paper: third positional maximum = max(16,...) = 16
