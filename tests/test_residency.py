"""State-residency tests: the planned layout IS the live layout.

The tentpole contract of the residency subsystem
(``runtime/residency.py``): with residency on (default), the engine's
whole cross-step state — per-slot KV caches + decode buffers — lives in
ONE device buffer of exactly ``StatePlan.total_size`` bytes, carved into
per-(slot, leaf) views by the plan's ``leaf_view_spec`` and
donate-threaded through the decode jit. Decode outputs must be
byte-identical to the XLA-allocated cache-pytree baseline
(``REPRO_STATE_RESIDENCY=off``) across architectures — attention,
SSM, and hybrid shared-attention caches all round-trip the arena.

Also covers the satellite failure modes: ``ArenaLayout`` materialization
from corrupt state plans (overlapping regions, offsets past the buffer)
and from v1 bundles (``state_plan=None``) raise clear errors.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.core.unified import (
    StateLeaf,
    plan_state,
    state_records_from_pytree,
)
from repro.models.api import Model
from repro.runtime.arena import Arena, ArenaLayout, DeviceArena
from repro.runtime.engine import InferenceEngine
from repro.runtime.residency import (
    PytreeState,
    ResidentState,
    StateResidency,
    residency_enabled,
)

ARCHS = ["qwen3-0.6b", "mamba2-2.7b", "zamba2-7b"]


def _setup(arch: str, n_slots: int = 2, max_len: int = 32):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(n_slots, max_len)
    sp = plan_state(
        state_records_from_pytree(caches, n_slots=n_slots),
        n_slots=n_slots, max_len=max_len,
    )
    return cfg, model, params, caches, sp


# --------------------------------------------------------- leaf_view_spec


def test_leaf_view_spec_addresses_every_cell():
    """The leaf addressing API: dense ids, one cell per (slot, leaf), at
    exactly slot_stride*slot + leaf.offset, payload within the planned
    slot, everything inside the buffer."""
    _, _, _, caches, sp = _setup("qwen3-0.6b")
    views = sp.leaf_view_spec()
    assert len(views) == sp.n_slots * len(sp.leaves)
    for i, view in enumerate(views):
        leaf = sp.leaves[view.leaf_index]
        assert view.tensor_id == i  # dense: slot * n_leaves + leaf_index
        assert view.slot == i // len(sp.leaves)
        assert view.path == leaf.path
        assert view.offset == view.slot * sp.slot_stride + leaf.offset
        assert view.slot_nbytes == leaf.slot_nbytes
        assert 0 < view.used_nbytes <= view.slot_nbytes
        assert view.offset + view.slot_nbytes <= sp.total_size
    # the legacy tuple view is the same cells
    for view, (tid, slot, leaf, off) in zip(views, sp.flat_entries()):
        assert (view.tensor_id, view.slot, view.offset) == (tid, slot, off)
        assert leaf.path == view.path


def test_state_layout_cells_are_disjoint():
    _, _, _, _, sp = _setup("mamba2-2.7b")
    layout = ArenaLayout.from_state_plan(sp)
    layout.validate()
    layout.validate_disjoint()  # state is all live at once: no aliasing
    assert layout.total_size == sp.total_size


# ------------------------------------------------------------ DeviceArena


def test_device_arena_store_view_round_trip():
    _, _, _, _, sp = _setup("qwen3-0.6b")
    arena = DeviceArena(ArenaLayout.from_state_plan(sp))
    buf = arena.allocate()
    assert buf.nbytes == sp.total_size
    view = sp.leaf_view_spec()[0]
    n = view.used_nbytes // 4
    value = jnp.arange(n, dtype=jnp.float32)
    buf = arena.store(buf, view.tensor_id, value)
    got = arena.view(buf, view.tensor_id, (n,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(value))
    # and other cells stayed zero
    other = sp.leaf_view_spec()[1]
    rest = arena.view(
        buf, other.tensor_id, (other.used_nbytes,), jnp.uint8
    )
    assert int(np.asarray(rest).sum()) == 0


def test_device_arena_enforces_the_same_bounds_contract_as_arena():
    """The jax twin must reject oversized views exactly like the numpy
    arena — a too-large view would silently alias the next slot."""
    _, _, _, _, sp = _setup("qwen3-0.6b")
    layout = ArenaLayout.from_state_plan(sp)
    device, host = DeviceArena(layout), Arena(layout)
    view = sp.leaf_view_spec()[0]
    too_big = view.slot_nbytes + 64
    with pytest.raises(ValueError, match="exceeds planned"):
        device.view(device.allocate(), view.tensor_id, (too_big,), jnp.uint8)
    with pytest.raises(ValueError, match="exceeds planned"):
        host.view(view.tensor_id, (too_big,), np.uint8)
    with pytest.raises(ValueError, match="exceeds planned"):
        device.store(
            device.allocate(), view.tensor_id,
            jnp.zeros((too_big,), jnp.uint8),
        )


# -------------------------------------------------- StateResidency binding


@pytest.mark.parametrize("arch", ARCHS)
def test_pack_unpack_round_trips_the_cache_pytree(arch):
    cfg, model, params, caches, sp = _setup(arch)
    res = StateResidency(sp, caches, n_slots=2)
    buf = res.init_buffer(caches)
    assert buf.nbytes == sp.total_size
    rebuilt = res.unpack(buf)
    for (p1, a), (p2, b) in zip(
        jax.tree_util.tree_flatten_with_path(caches)[0],
        jax.tree_util.tree_flatten_with_path(rebuilt)[0],
    ):
        assert p1 == p2
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pack of nonzero caches round-trips bytes exactly too
    nonzero = jax.tree_util.tree_map(
        lambda x: (jnp.arange(x.size, dtype=jnp.float32) % 7 + 1)
        .reshape(x.shape).astype(x.dtype),
        caches,
    )
    buf2 = jax.jit(res.pack)(nonzero, buf)
    for (_, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(nonzero)[0],
        jax.tree_util.tree_flatten_with_path(res.unpack(buf2))[0],
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_residency_rejects_foreign_plans_and_templates():
    _, model, _, caches, sp = _setup("qwen3-0.6b")
    # slot-count mismatch
    with pytest.raises(ValueError, match="slots"):
        StateResidency(sp, caches, n_slots=4)
    # a plan for a different model's cache pytree
    _, _, _, other_caches, other_sp = _setup("mamba2-2.7b")
    with pytest.raises(ValueError, match="does not cover"):
        StateResidency(other_sp, caches, n_slots=2)
    # dtype drift between plan and cache
    bad = dataclasses.replace(
        sp,
        leaves=[dataclasses.replace(l, dtype="float64") for l in sp.leaves],
    )
    with pytest.raises(ValueError, match="dtype"):
        StateResidency(bad, caches, n_slots=2)


# --------------------------------------- satellite: layout failure modes


def test_overlapping_state_regions_raise():
    """A corrupt state plan whose leaf slots alias must fail at
    materialization, before any bytes are shared."""
    _, _, _, _, sp = _setup("qwen3-0.6b")
    squashed = dataclasses.replace(
        sp,
        leaves=[dataclasses.replace(l, offset=0) for l in sp.leaves],
    )
    if len(squashed.leaves) < 2:
        pytest.skip("needs >= 2 leaves to overlap")
    with pytest.raises(ValueError, match="overlap"):
        ArenaLayout.from_state_plan(squashed)


def test_leaf_offset_past_total_size_raises():
    _, _, _, _, sp = _setup("qwen3-0.6b")
    pushed = dataclasses.replace(
        sp,
        leaves=[
            dataclasses.replace(sp.leaves[0], offset=sp.total_size),
            *sp.leaves[1:],
        ],
    )
    with pytest.raises(ValueError, match="outside"):
        ArenaLayout.from_state_plan(pushed)


def test_v1_bundle_state_materialization_raises_clearly():
    """A v1 bundle ships no state plan; asking for its state arena must
    say so, not die on an attribute lookup."""
    with pytest.raises(ValueError, match="v1 bundle"):
        ArenaLayout.from_state_plan(None)
    # the graceful path: a v1-shimmed UnifiedPlan materializes only the
    # activation half
    from repro.core.planner import plan_records
    from repro.core.records import make_records
    from repro.core.unified import UnifiedPlan

    up = UnifiedPlan(
        activation=plan_records(
            make_records([(0, 1, 128)]), use_cache=False
        ),
        state=None,
        fingerprint="v1-shim",
    )
    act, state = ArenaLayout.from_unified(up)
    assert act is not None and state is None


# ------------------------------------------------- backend differential


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_byte_identical_to_xla_allocated_baseline(arch):
    """Acceptance: with residency on, decode logits AND the cache state
    after every step are byte-identical to the XLA-allocated pytree
    baseline — across attention, SSM, and hybrid shared-attn caches."""
    cfg, model, params, caches, sp = _setup(arch)
    res = StateResidency(sp, caches, n_slots=2)
    resident = ResidentState(model, res, caches)
    baseline = PytreeState(model, caches)
    assert resident.live_bytes == sp.total_size

    rng = np.random.default_rng(0)
    for step in range(5):
        tok = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(2, 1)), jnp.int32
        )
        pos = jnp.full((2,), step, jnp.int32)
        act = jnp.ones((2,), bool)
        l_res = resident.decode(params, tok, pos, act)
        l_base = baseline.decode(params, tok, pos, act)
        np.testing.assert_array_equal(
            np.asarray(l_res), np.asarray(l_base),
            err_msg=f"{arch}: logits diverged at step {step}",
        )
        for (p, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(resident.caches)[0],
            jax.tree_util.tree_flatten_with_path(baseline.caches)[0],
        ):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{arch}: cache leaf {jax.tree_util.keystr(p)} "
                        f"diverged at step {step}",
            )
    # slot reset round-trips the arena identically too
    keep = np.array([True, False])
    resident.reset(keep)
    baseline.reset(keep)
    for (_, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(resident.caches)[0],
        jax.tree_util.tree_flatten_with_path(baseline.caches)[0],
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_serves_identical_tokens_with_residency_on_and_off(arch):
    """End-to-end differential: staggered requests, slot reuse, resets —
    the full serving loop emits the same tokens either way."""
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for residency in (True, False):
        engine = InferenceEngine(
            cfg, params, n_slots=2, max_len=48, state_residency=residency,
        )
        assert engine.memory_report.state_residency is residency
        rng = np.random.default_rng(7)
        for _ in range(5):
            engine.submit(
                rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                max_new_tokens=3,
            )
        done = engine.run_until_done()
        outs.append({r.request_id: r.tokens for r in done})
    assert outs[0] == outs[1]


# ----------------------------------------------------- engine integration


def test_engine_live_state_bytes_equal_planned():
    """Acceptance: ONE state allocation of exactly StatePlan.total_size."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=32)
    rep = engine.memory_report
    assert rep.state_residency
    assert rep.state_live_bytes == rep.state_planned_bytes
    assert rep.state_live_bytes == rep.state_plan.total_size
    assert engine.state.live_bytes == rep.state_plan.total_size
    assert engine.state.buf.dtype == jnp.uint8
    assert "state residency: ON" in rep.summary()
    # the per-slot figure is the exact plan region size, not a truncating
    # integer division of measured bytes
    assert rep.cache_bytes_per_slot == rep.state_plan.bytes_per_slot
    assert rep.cache_bytes_per_slot * engine.n_slots == (
        rep.state_plan.total_size
    )
    # serving does not grow the allocation: same buffer size after work
    engine.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
    engine.run_until_done()
    assert engine.state.live_bytes == rep.state_plan.total_size


def test_decode_consumes_the_donated_buffer():
    """The single-allocation claim is donation, not just sizing: after a
    wave, the PREVIOUS buffer value must be consumed (donated to XLA and
    reused in place), never left alive as a second state copy."""
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=32)
    planned = engine.memory_report.state_plan.total_size
    engine.submit(np.arange(4, dtype=np.int32), max_new_tokens=5)
    for _ in range(3):
        before = engine.state.buf
        engine.step()  # active request -> at least one decode wave ran
        assert before.is_deleted(), (
            "decode did not consume the donated state buffer — two live "
            "state copies instead of one"
        )
        assert engine.state.buf.nbytes == planned


def test_zero_init_buffer_equals_packed_init_cache():
    """The engine zero-inits the flat buffer without materializing a
    cache pytree; that must be byte-identical to packing the models'
    actual init_cache output (the all-zero contract)."""
    _, model, _, caches, sp = _setup("zamba2-7b")
    res = StateResidency(sp, caches, n_slots=2)
    zeroed = np.asarray(res.init_buffer())
    packed = np.asarray(res.init_buffer(caches))
    np.testing.assert_array_equal(zeroed, packed)


def test_env_escape_hatch_disables_residency(monkeypatch):
    assert residency_enabled(None)
    for off in ("off", "0", "false", "NO"):
        monkeypatch.setenv("REPRO_STATE_RESIDENCY", off)
        assert not residency_enabled(None)
        assert residency_enabled(True)  # explicit kwarg wins
    monkeypatch.setenv("REPRO_STATE_RESIDENCY", "off")
    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, n_slots=2, max_len=32)
    rep = engine.memory_report
    assert not rep.state_residency
    assert isinstance(engine.state, PytreeState)
    assert rep.state_live_bytes == sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(engine.caches)
    )
    assert "state residency: off" in rep.summary()
    # serving still works on the legacy path
    engine.submit(np.arange(3, dtype=np.int32), max_new_tokens=2)
    assert len(engine.run_until_done()) == 1


def test_bundle_served_engine_is_resident_with_zero_layout_work(tmp_path):
    """The residency buffer must come straight from the bundled StatePlan:
    zero traces, zero planner calls, zero state layouts — and live bytes
    equal to the artifact's own state total."""
    from repro.analysis import counters
    from repro.core.unified import PlanSession
    from repro.launch.compile import compile_and_publish

    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    compile_and_publish(cfg, tmp_path, n_slots=2, max_len=32)
    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls"
    ) as cap:
        engine = InferenceEngine(
            cfg, params, n_slots=2, max_len=32,
            session=PlanSession.from_manifest(tmp_path),
        )
    assert all(d == 0 for d in cap.deltas().values()), cap.deltas()
    rep = engine.memory_report
    assert rep.plan_source == "bundle"
    assert rep.state_residency
    assert rep.state_live_bytes == engine.plan_bundle.state_plan.total_size
    engine.submit(np.arange(3, dtype=np.int32), max_new_tokens=2)
    assert len(engine.run_until_done()) == 1
