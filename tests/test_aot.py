"""AOT decode executables (PlanBundle v3): zero-compile serving.

The tentpole contract, pinned end-to-end:

* a v3 bundle serves its first token with ZERO XLA compiles — the
  ``COMPILE_CALLS`` counter, same discipline as the zero-trace /
  zero-plan asserts;
* decode outputs are byte-identical to the lazily-compiled path, on
  both state backends (resident u8-buffer and plain cache pytree) and
  on the scan-block path;
* a v2 document degrades to lazy compile (DeprecationWarning, plans
  still served from the bundle — the fingerprint schema rolls
  separately from the bundle format);
* a stale pack (platform / jax-version / payload-integrity mismatch)
  is refused with one RuntimeWarning and falls back to lazy compile —
  never a crash, and never a partial load;
* ``decode_lint.lint_executables`` passes a fresh pack and flags an
  undeserializable payload.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.core.artifact import (
    BundleManifest,
    bucket_key,
    bundle_to_obj,
    expected_executable_entries,
    save_bundle,
)
from repro.core.unified import PlanSession
from repro.launch.compile import compile_and_publish
from repro.models.api import Model
from repro.runtime import residency
from repro.runtime.engine import InferenceEngine

N_SLOTS, MAX_LEN = 2, 32


@pytest.fixture(scope="module")
def cfg():
    return get_reduced("qwen3-0.6b")


@pytest.fixture(scope="module")
def params(cfg):
    return Model.for_config(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def bundle_dir(cfg, tmp_path_factory):
    d = tmp_path_factory.mktemp("aot_bundles")
    compile_and_publish(
        cfg, d, n_slots=N_SLOTS, max_len=MAX_LEN, measure_xla=False
    )
    return d


@pytest.fixture(scope="module")
def bundle(bundle_dir, cfg):
    return BundleManifest(bundle_dir).lookup(
        bucket_key(cfg, n_slots=N_SLOTS, max_len=MAX_LEN)
    )


def _serve(engine, max_new=3, n_requests=2):
    rng = np.random.default_rng(7)
    for _ in range(n_requests):
        engine.submit(
            rng.integers(0, 100, size=4).astype(np.int32),
            max_new_tokens=max_new,
        )
    return {r.request_id: list(r.tokens) for r in engine.run_until_done()}


def test_v3_bundle_serves_with_zero_compiles(cfg, params, bundle_dir):
    c0 = residency.COMPILE_CALLS
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_manifest(bundle_dir),
    )
    rep = engine.memory_report
    assert rep.plan_source == "bundle"
    assert rep.aot_warning is None
    assert rep.aot_executables == expected_executable_entries()
    assert "zero-compile" in rep.summary()
    tokens = _serve(engine)
    assert tokens and all(len(t) == 3 for t in tokens.values())
    assert residency.COMPILE_CALLS - c0 == 0


def test_aot_tokens_byte_identical_to_lazy(
    cfg, params, bundle, bundle_dir, tmp_path
):
    """The AOT executables ARE the programs the engine would have jitted
    — same bundle with the pack stripped must emit the same bytes, on
    both state backends."""
    stripped = tmp_path / "lazy.json"
    save_bundle(dataclasses.replace(bundle, executables=None), stripped)
    for residency_on in (True, False):
        kw = dict(
            n_slots=N_SLOTS, max_len=MAX_LEN, state_residency=residency_on
        )
        aot = InferenceEngine(
            cfg, params, session=PlanSession.from_manifest(bundle_dir), **kw
        )
        assert aot.memory_report.aot_executables
        lazy = InferenceEngine(
            cfg, params, session=PlanSession.from_bundle(stripped), **kw
        )
        assert lazy.memory_report.plan_source == "bundle"
        assert lazy.memory_report.aot_executables == []
        assert _serve(aot) == _serve(lazy), (
            f"AOT tokens diverged from lazy (residency={residency_on})"
        )


def test_aot_block_path_zero_compile_and_identical(cfg, params, tmp_path):
    """Full-K scan blocks run from the bundled block executable (zero
    compiles); tokens match the lazily-compiled block engine."""
    d = tmp_path / "blocks"
    compile_and_publish(
        cfg, d, n_slots=N_SLOTS, max_len=MAX_LEN, block_size=2,
        measure_xla=False,
    )
    c0 = residency.COMPILE_CALLS
    aot = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN, block_size=2,
        session=PlanSession.from_manifest(d),
    )
    assert "resident_block_2" in aot.memory_report.aot_executables
    tokens = _serve(aot, max_new=4)  # multiple of K: full blocks only
    assert residency.COMPILE_CALLS - c0 == 0
    lazy = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN, block_size=2
    )
    assert tokens == _serve(lazy, max_new=4)


def test_v2_bundle_degrades_to_lazy_compile(cfg, params, bundle, tmp_path):
    """Satellite: a v2 document still serves its PLANS from the bundle —
    only the executables are missing, so the engine pays lazy compiles
    (and nothing else) behind one DeprecationWarning."""
    obj = bundle_to_obj(bundle)
    obj["format_version"] = 2
    obj.pop("executables", None)
    f = tmp_path / "v2.json"
    f.write_text(json.dumps(obj, sort_keys=True, separators=(",", ":")))
    c0 = residency.COMPILE_CALLS
    with pytest.deprecated_call(match="format v2"):
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_bundle(f),
        )
    rep = engine.memory_report
    assert rep.plan_source == "bundle"  # fingerprint schema decoupled
    assert rep.aot_executables == []
    assert rep.aot_warning is None
    tokens = _serve(engine)
    assert tokens
    assert residency.COMPILE_CALLS - c0 >= 1  # the lazy decode compile


@pytest.mark.parametrize(
    "mutate, match",
    [
        (lambda p: dataclasses.replace(p, platform="notaplatform"),
         "platform"),
        (lambda p: dataclasses.replace(p, jax_version="0.0.0"), "jax"),
        (
            lambda p: dataclasses.replace(
                p,
                entries={
                    n: (
                        dataclasses.replace(e, sha256="0" * 64)
                        if n == sorted(p.entries)[0] else e
                    )
                    for n, e in p.entries.items()
                },
            ),
            "integrity",
        ),
    ],
    ids=["platform", "jax-version", "sha256"],
)
def test_stale_pack_refused_and_falls_back(
    cfg, params, bundle, tmp_path, mutate, match
):
    """A cross-platform / cross-jax / corrupted pack is refused whole —
    one RuntimeWarning, lazy compile, tokens still served."""
    f = tmp_path / "stale.json"
    save_bundle(
        dataclasses.replace(bundle, executables=mutate(bundle.executables)),
        f,
    )
    c0 = residency.COMPILE_CALLS
    with pytest.warns(RuntimeWarning, match=match):
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_bundle(f),
        )
    rep = engine.memory_report
    assert rep.plan_source == "bundle"  # the plans are still good
    assert rep.aot_executables == []  # all-or-nothing: no partial load
    assert "falling back to lazy compile" in rep.aot_warning
    assert _serve(engine)
    assert residency.COMPILE_CALLS - c0 >= 1


def test_lint_executables_passes_fresh_and_flags_corrupt(bundle):
    from repro.analysis import decode_lint

    # warning-severity findings are backend noise (the CPU scatter loops
    # show up as whole-state-buffer copies); the publish gate blocks on
    # errors, so that is what a fresh pack must be free of
    fresh = decode_lint.lint_executables(bundle)
    assert [f for f in fresh if f.severity == "error"] == []
    name = sorted(bundle.executables.entries)[0]
    broken = dataclasses.replace(
        bundle,
        executables=dataclasses.replace(
            bundle.executables,
            entries={
                **bundle.executables.entries,
                name: dataclasses.replace(
                    bundle.executables.entries[name], payload=b"garbage"
                ),
            },
        ),
    )
    findings = decode_lint.lint_executables(broken)
    assert any(f.code == "executable-load-failed" for f in findings)
