"""Static lint of compiled decode programs (repro.analysis.decode_lint).

Synthetic-HLO cases pin each finding code's trigger; the real-lowering
case proves the ISSUE acceptance invariant for a CI serving arch —
donation actually aliases the state buffer and the hot path contains no
device→host transfer — without running a single decode step.
"""

import pytest

from repro.analysis import decode_lint
from repro.analysis.decode_lint import DecodeProgram, parse_alias_table

STATE = 1024  # synthetic state-buffer size


def _module(body: str, *, alias: str = "{ {1}: (2, {}, may-alias) }") -> str:
    alias_attr = f", input_output_alias={alias}" if alias else ""
    return (
        f"HloModule jit_step, is_scheduled=true{alias_attr}\n"
        "\n"
        "ENTRY %main.10 (p0: f32[4], p1: s32[2,1], p2: u8[1024]) -> (f32[4], u8[1024]) {\n"
        "  %p0 = f32[4]{0} parameter(0)\n"
        "  %p1 = s32[2,1]{1,0} parameter(1)\n"
        "  %p2 = u8[1024]{0} parameter(2)\n"
        f"{body}"
        "  ROOT %tuple.1 = (f32[4]{0}, u8[1024]{0}) tuple(%p0, %p2)\n"
        "}\n"
    )


def _codes(findings):
    return {f.code for f in findings}


def test_parse_alias_table():
    hlo = _module("")
    assert parse_alias_table(hlo) == [((1,), 2, "may-alias")]
    multi = _module(
        "", alias="{ {0}: (0, {}, must-alias), {1, 0}: (2, {}, may-alias) }"
    )
    assert parse_alias_table(multi) == [
        ((0,), 0, "must-alias"),
        ((1, 0), 2, "may-alias"),
    ]
    assert parse_alias_table("HloModule bare\n") == []


def test_clean_program_passes():
    prog = DecodeProgram(label="t:step", hlo=_module(""), state_nbytes=STATE)
    assert decode_lint.lint_program(prog) == []


def test_state_not_donated():
    prog = DecodeProgram(
        label="t:step", hlo=_module("", alias=""), state_nbytes=STATE
    )
    assert _codes(decode_lint.lint_program(prog)) == {"state-not-donated"}


def test_state_param_missing():
    prog = DecodeProgram(
        label="t:step", hlo=_module(""), state_nbytes=STATE + 1
    )
    assert "state-param-missing" in _codes(decode_lint.lint_program(prog))


def test_host_transfer_codes():
    prog = DecodeProgram(
        label="t:step",
        hlo=_module(
            "  %tok = token[] after-all()\n"
            "  %of = token[] outfeed(%p0, %tok), outfeed_shape=f32[4]\n"
        ),
        state_nbytes=STATE,
    )
    assert "host-transfer" in _codes(decode_lint.lint_program(prog))

    prog = DecodeProgram(
        label="t:step",
        hlo=_module(
            '  %cc = f32[4]{0} custom-call(%p0), custom_call_target="MoveToHost"\n'
        ),
        state_nbytes=STATE,
    )
    assert "host-transfer" in _codes(decode_lint.lint_program(prog))

    prog = DecodeProgram(
        label="t:step",
        hlo=_module("  %h = f32[4]{0:S(5)} copy(%p0)\n"),
        state_nbytes=STATE,
    )
    assert "host-transfer" in _codes(decode_lint.lint_program(prog))


def test_whole_buffer_copy_is_warning_and_fusion_internal_is_exempt():
    prog = DecodeProgram(
        label="t:step",
        hlo=_module("  %cp = u8[1024]{0} copy(%p2)\n"),
        state_nbytes=STATE,
    )
    findings = decode_lint.lint_program(prog)
    assert _codes(findings) == {"state-buffer-copy"}
    assert all(f.severity == "warning" for f in findings)

    # the same copy inside a fusion body stays in registers: exempt
    fused = (
        "HloModule jit_step, is_scheduled=true, "
        "input_output_alias={ {1}: (2, {}, may-alias) }\n"
        "\n"
        "%fused_computation (fp: u8[1024]) -> u8[1024] {\n"
        "  %fp = u8[1024]{0} parameter(0)\n"
        "  ROOT %cp = u8[1024]{0} copy(%fp)\n"
        "}\n"
        "\n"
        "ENTRY %main.10 (p0: f32[4], p1: s32[2,1], p2: u8[1024]) -> (f32[4], u8[1024]) {\n"
        "  %p0 = f32[4]{0} parameter(0)\n"
        "  %p1 = s32[2,1]{1,0} parameter(1)\n"
        "  %p2 = u8[1024]{0} parameter(2)\n"
        "  %fu = u8[1024]{0} fusion(%p2), kind=kLoop, calls=%fused_computation\n"
        "  ROOT %tuple.1 = (f32[4]{0}, u8[1024]{0}) tuple(%p0, %fu)\n"
        "}\n"
    )
    prog = DecodeProgram(label="t:step", hlo=fused, state_nbytes=STATE)
    assert decode_lint.lint_program(prog) == []


def _while_module(*, trip_attr: str) -> str:
    return (
        "HloModule jit_block, input_output_alias={ {0}: (0, {}, may-alias) }\n"
        "\n"
        "%cond (cp: u8[1024]) -> pred[] {\n"
        "  %cp = u8[1024]{0} parameter(0)\n"
        "  ROOT %lt = pred[] constant(false)\n"
        "}\n"
        "\n"
        "%body (bp: u8[1024]) -> u8[1024] {\n"
        "  ROOT %bp = u8[1024]{0} parameter(0)\n"
        "}\n"
        "\n"
        "ENTRY %main.20 (p0: u8[1024]) -> u8[1024] {\n"
        "  %p0 = u8[1024]{0} parameter(0)\n"
        "  ROOT %w = u8[1024]{0} while(%p0), condition=%cond, body=%body"
        f"{trip_attr}\n"
        "}\n"
    )


def test_scan_shape_codes():
    good = DecodeProgram(
        label="t:block4",
        hlo=_while_module(
            trip_attr=', backend_config={"known_trip_count":{"n":"4"}}'
        ),
        state_nbytes=STATE,
        expect_trip=4,
    )
    assert decode_lint.lint_program(good) == []

    mismatch = DecodeProgram(
        label="t:block4",
        hlo=_while_module(
            trip_attr=', backend_config={"known_trip_count":{"n":"8"}}'
        ),
        state_nbytes=STATE,
        expect_trip=4,
    )
    assert "scan-trip-mismatch" in _codes(decode_lint.lint_program(mismatch))

    unknown = DecodeProgram(
        label="t:block4",
        hlo=_while_module(trip_attr=""),
        state_nbytes=STATE,
        expect_trip=4,
    )
    f = decode_lint.lint_program(unknown)
    assert "scan-trip-unknown" in _codes(f)
    assert all(x.severity == "warning" for x in f)

    unrolled = DecodeProgram(
        label="t:block4",
        hlo=_module(""),
        state_nbytes=STATE,
        expect_trip=4,
    )
    assert "scan-unrolled" in _codes(decode_lint.lint_program(unrolled))


def test_unparseable_hlo():
    prog = DecodeProgram(label="t:step", hlo="not hlo", state_nbytes=STATE)
    assert _codes(decode_lint.lint_program(prog)) == {"hlo-unparseable"}


# --------------------------------------------------------- real lowering


def test_real_decode_programs_pass_lint():
    """ISSUE acceptance, statically: the compiled decode step and scan
    block of a CI serving arch have their state-buffer donation aliased
    and zero host transfers. (scripts/ci.sh runs this for every CI arch;
    one arch here keeps the suite fast.)"""
    pytest.importorskip("jax")
    programs = decode_lint.lower_decode_programs(
        "qwen3-0.6b", n_slots=2, max_len=16, block=4
    )
    assert {p.label for p in programs} == {
        "qwen3-0.6b:step", "qwen3-0.6b:block4"
    }
    for prog in programs:
        # donation must be visible in the alias table before linting
        assert parse_alias_table(prog.hlo), prog.label
        findings = decode_lint.lint_program(prog)
        errors = [f for f in findings if f.severity == "error"]
        assert not errors, [f.render() for f in errors]
        assert not any(f.code == "host-transfer" for f in findings)
