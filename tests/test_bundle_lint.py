"""Bundle/manifest audit (repro.analysis.bundle_lint) + the publish gate.

One compiled manifest (module-scoped; three real buckets through
``launch/compile.py``) backs every case: the pristine directory audits
clean, each corruption — edited bundle bytes, index tamper, missing
file, sweep hole, stale fingerprint, slot mismatch — surfaces its
specific finding code, the CLI exit codes follow, and the pre-publish
gate refuses to publish a failing bundle (ISSUE acceptance).
"""

import dataclasses
import json
import shutil
from pathlib import Path

import pytest

from repro.analysis import bundle_lint
from repro.analysis.findings import LintGateError
from repro.analysis.lint import main as lint_main

ARCH = "qwen3-0.6b"


@pytest.fixture(scope="module")
def manifest_dir(tmp_path_factory):
    """Three buckets: slots 2 × lens {16, 32} plus slots 4 × len 16 — the
    (4, 32) cell is intentionally missing, so the full directory carries
    exactly one coverage-gap warning and zero errors."""
    jax = pytest.importorskip("jax")  # noqa: F841 — compile path needs jax
    from repro.configs.base import get_reduced
    from repro.launch.compile import compile_and_publish

    d = tmp_path_factory.mktemp("bundles")
    cfg = get_reduced(ARCH)
    for n_slots, max_len in [(2, 16), (2, 32), (4, 16)]:
        compile_and_publish(
            cfg, str(d), n_slots=n_slots, max_len=max_len, measure_xla=False
        )
    return d


def _copy(manifest_dir, tmp_path) -> Path:
    dst = tmp_path / "m"
    shutil.copytree(manifest_dir, dst)
    return dst


def _index(d: Path) -> dict:
    return json.loads((d / "manifest.json").read_text())


def _write_index(d: Path, obj: dict) -> None:
    (d / "manifest.json").write_text(json.dumps(obj))


def _codes(report):
    return {f.code for f in report.findings}


def test_pristine_manifest_has_only_the_planted_gap(manifest_dir):
    report = bundle_lint.lint_manifest(manifest_dir)
    assert not report.errors, [f.render() for f in report.errors]
    assert _codes(report) == {"coverage-gap"}
    [gap] = report.warnings
    assert "slots4|len32" in gap.message
    assert len(report.checked) >= 4  # 3 buckets + coverage


def test_complete_grid_is_strict_clean(manifest_dir, tmp_path):
    d = _copy(manifest_dir, tmp_path)
    idx = _index(d)
    idx["buckets"] = {
        k: v for k, v in idx["buckets"].items() if "slots4" not in k
    }
    _write_index(d, idx)
    report = bundle_lint.lint_manifest(d)
    assert report.ok(strict=True), report.render()
    assert lint_main(["--strict", "bundles", str(d)]) == 0


def test_edited_bundle_file_breaks_content_address(manifest_dir, tmp_path):
    d = _copy(manifest_dir, tmp_path)
    key, entry = sorted(_index(d)["buckets"].items())[0]
    path = d / entry["file"]
    obj = json.loads(path.read_text())
    obj["max_len"] += 1  # in-place edit: address no longer matches content
    path.write_text(json.dumps(obj))
    report = bundle_lint.lint_manifest(d)
    codes = _codes(report)
    assert "content-address-mismatch" in codes
    # the shape edit also de-coheres the bucket and the state plan
    assert {"bucket-key-mismatch", "state-len-mismatch"} & codes
    assert lint_main(["bundles", str(d)]) == 1


def test_index_fingerprint_tamper(manifest_dir, tmp_path):
    d = _copy(manifest_dir, tmp_path)
    idx = _index(d)
    key = sorted(idx["buckets"])[0]
    idx["buckets"][key]["fingerprint"] = "0" * 64
    idx["buckets"][key]["total_size"] += 7
    _write_index(d, idx)
    codes = _codes(bundle_lint.lint_manifest(d))
    assert {"index-fingerprint-mismatch", "index-total-mismatch"} <= codes


def test_missing_bundle_file(manifest_dir, tmp_path):
    d = _copy(manifest_dir, tmp_path)
    entry = sorted(_index(d)["buckets"].items())[0][1]
    (d / entry["file"]).unlink()
    report = bundle_lint.lint_manifest(d)
    assert "missing-file" in _codes(report)


def test_stale_fingerprint_on_loaded_bundle(manifest_dir):
    from repro.core.artifact import load_bundle

    entry = sorted(_index(manifest_dir)["buckets"].items())[0][1]
    bundle = load_bundle(manifest_dir / entry["file"])
    assert bundle_lint.lint_bundle(bundle) == []
    stale = dataclasses.replace(bundle, fingerprint="f" * 64)
    codes = {f.code for f in bundle_lint.lint_bundle(stale)}
    assert codes == {"fingerprint-stale"}


def test_state_slots_mismatch(manifest_dir):
    from repro.core.artifact import load_bundle

    entry = sorted(_index(manifest_dir)["buckets"].items())[0][1]
    bundle = load_bundle(manifest_dir / entry["file"])
    bad_state = dataclasses.replace(
        bundle.state_plan, n_slots=bundle.state_plan.n_slots + 1
    )
    mutated = dataclasses.replace(bundle, state_plan=bad_state)
    codes = {f.code for f in bundle_lint.lint_bundle(mutated)}
    assert "state-slots-mismatch" in codes


def test_unknown_format_version(manifest_dir, tmp_path):
    d = _copy(manifest_dir, tmp_path)
    entry = sorted(_index(d)["buckets"].items())[0][1]
    path = d / entry["file"]
    obj = json.loads(path.read_text())
    obj["format_version"] = 99
    path.write_text(json.dumps(obj))
    findings = bundle_lint.lint_bundle_file(path)
    assert {f.code for f in findings} == {"format-unknown"}


def test_publish_gate_refuses_failing_bundle(monkeypatch, tmp_path):
    """compile.py must refuse to publish when the gate reports an error:
    nothing lands in the manifest directory."""
    pytest.importorskip("jax")
    from repro.analysis.findings import Finding
    from repro.configs.base import get_reduced
    from repro.launch import compile as compile_mod

    def poisoned(bundle, **kwargs):
        return [
            Finding(
                pass_name="bundle_lint", code="fingerprint-stale",
                message="injected for the gate test", where="test",
            )
        ]

    monkeypatch.setattr(bundle_lint, "lint_bundle", poisoned)
    out = tmp_path / "refused"
    cfg = get_reduced(ARCH)
    with pytest.raises(LintGateError) as exc:
        compile_mod.compile_and_publish(
            cfg, str(out), n_slots=2, max_len=16, measure_xla=False
        )
    assert "refusing to publish" in str(exc.value)
    assert exc.value.report.errors
    assert not out.exists() or not any(out.iterdir())

    # --no-lint escape hatch: same compile publishes with the gate off
    res = compile_mod.compile_and_publish(
        cfg, str(out), n_slots=2, max_len=16, measure_xla=False, lint=False
    )
    assert (out / "manifest.json").is_file()
    assert res.bundle.state_plan is not None


def test_cli_json_output(manifest_dir):
    import io
    from contextlib import redirect_stdout

    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = lint_main(["--json", "bundles", str(manifest_dir)])
    assert rc == 0  # the planted coverage gap is warning-severity
    obj = json.loads(buf.getvalue())
    assert obj["errors"] == 0
    assert obj["warnings"] == 1
    assert obj["findings"][0]["code"] == "coverage-gap"

    # under --strict the same warning fails the run
    with redirect_stdout(io.StringIO()):
        assert lint_main(["--strict", "bundles", str(manifest_dir)]) == 1
