"""The instrumentation-counter registry (repro.analysis.counters)."""

from repro.analysis import counters


def test_registry_reads_and_resets():
    from repro.core import planner
    from repro.core.records import make_records

    counters.reset(("plan_calls",))
    assert counters.read("plan_calls") == 0
    planner.plan_records(
        make_records([(0, 1, 64)]), use_cache=False, graph_name="counters-t1"
    )
    assert counters.read("plan_calls") == 1
    snap = counters.snapshot(("plan_calls", "state_plan_calls"))
    assert snap["plan_calls"] == 1
    counters.reset(("plan_calls",))
    assert counters.read("plan_calls") == 0


def test_capture_deltas_without_reset():
    from repro.core import planner
    from repro.core.records import make_records

    recs = make_records([(0, 1, 64), (1, 2, 32)])
    planner.plan_records(recs, use_cache=False, graph_name="counters-t2")
    before = counters.read("plan_calls")
    with counters.capture("plan_calls", "state_plan_calls") as outer:
        planner.plan_records(recs, use_cache=False, graph_name="counters-t3")
        with counters.capture("plan_calls") as inner:
            planner.plan_records(
                recs, use_cache=False, graph_name="counters-t4"
            )
        assert inner.delta("plan_calls") == 1
        assert outer.delta("plan_calls") == 2
        assert outer.delta("state_plan_calls") == 0
    assert outer.deltas()["plan_calls"] == 2
    # capture never resets the underlying globals
    assert counters.read("plan_calls") == before + 2


def test_capture_defaults_to_full_registry():
    with counters.capture() as cap:
        pass
    assert set(cap.deltas()) == set(counters.REGISTRY)
    assert all(d == 0 for d in cap.deltas().values())
