"""Kernel VMEM budgets via the paper's planner (DESIGN.md §3 item iii)."""

import pytest

from repro.kernels.ops import flash_decode_auto
from repro.kernels.vmem_plan import VMEM_BYTES, plan_flash_decode_vmem


@pytest.mark.parametrize("G,D", [(1, 64), (8, 128), (16, 256)])
def test_auto_block_sizing_fits_vmem(G, D):
    """The block_t that flash_decode_auto would pick must plan under the
    16 MiB VMEM budget with double buffering."""
    budget = 4 * 2**20
    per_pos = 2 * D * 2
    block_t = max(128, min(2048, budget // per_pos // 128 * 128))
    vp = plan_flash_decode_vmem(G=G, D=D, block_t=block_t)
    assert vp.fits, vp.summary()
    # double buffering means >= 2 kv tiles co-resident: plan must be at
    # least 4 tile sizes (2x k + 2x v) but sharing keeps it well under
    # naive co-residency of all records
    assert vp.plan.total_size <= vp.plan.naive_size


def test_oversized_block_is_caught():
    vp = plan_flash_decode_vmem(G=8, D=256, block_t=32768)
    assert not vp.fits  # 4 x 16 MiB of K/V tiles cannot fit


def test_planner_beats_naive_on_kernel_records():
    vp = plan_flash_decode_vmem(G=8, D=128, block_t=1024)
    # score/exp tiles and the retiring k/v tiles share offsets
    assert vp.plan.total_size < vp.plan.naive_size

def test_fusion_budget_derives_from_vmem_model():
    """The fusion search's kernel-local scratch budget must come from the
    VMEM model here, not a hard-coded constant: total VMEM minus the
    pipeline reserve the kernels keep resident."""
    from repro.core.fusion_search import DEFAULT_LOCAL_BUDGET, default_local_budget
    from repro.kernels.vmem_plan import (
        VMEM_BYTES,
        VMEM_PIPELINE_RESERVE_BYTES,
        fusion_scratch_budget,
    )

    assert fusion_scratch_budget() == VMEM_BYTES - VMEM_PIPELINE_RESERVE_BYTES
    assert 0 < fusion_scratch_budget() < VMEM_BYTES
    assert default_local_budget() == fusion_scratch_budget()
    assert DEFAULT_LOCAL_BUDGET == fusion_scratch_budget()
    # the reserve covers the largest planned flash-decode step (the state
    # actually co-resident with fused scratch)
    vp = plan_flash_decode_vmem(G=8, D=128, block_t=1024)
    assert vp.plan.total_size <= VMEM_PIPELINE_RESERVE_BYTES
