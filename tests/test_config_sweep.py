"""Overlap safety at scale: every model config × every registered strategy.

Sweeps each REDUCED config in ``src/repro/configs/`` through
``trace_graph`` → ``plan_graph``/``plan_records`` and asserts, via the
independent checker in ``repro.core.validate``, that no two
simultaneously-live tensors ever share bytes — for every strategy name
registered in the planner, both modes.
"""

import pytest

from graph_gen import config_records
from repro.configs.base import ARCH_IDS
from repro.core.planner import (
    OFFSET_STRATEGIES,
    SHARED_OBJECT_STRATEGIES,
    plan_records,
)
from repro.core.records import TensorUsageRecord
from repro.core.validate import check_offsets, check_shared_objects

# min_cost_flow is O(n³)-ish (successive shortest paths over a dense
# bipartite graph) — sound but impractical on multi-hundred-record
# graphs; it stays covered by the small-instance property/unit tests.
SO_SWEEP = sorted(set(SHARED_OBJECT_STRATEGIES) - {"min_cost_flow"})
OFF_SWEEP = sorted(OFFSET_STRATEGIES)


def _offsets_view(plan):
    """Re-wrap a MemoryPlan for the independent offset checker."""
    from repro.core.offsets import OffsetAssignment

    return OffsetAssignment(plan.strategy, plan.offsets, plan.total_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("strategy", OFF_SWEEP)
def test_offsets_strategies_overlap_free(arch, strategy):
    recs = list(config_records(arch))
    plan = plan_records(
        recs, mode="offsets", strategy=strategy, graph_name=arch,
        use_cache=False,
    )
    check_offsets(recs, _offsets_view(plan))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("strategy", SO_SWEEP)
def test_shared_object_strategies_overlap_free(arch, strategy):
    recs = list(config_records(arch))
    plan = plan_records(
        recs, mode="shared_objects", strategy=strategy, graph_name=arch,
        use_cache=False,
    )
    assert plan.shared_objects is not None
    check_shared_objects(recs, plan.shared_objects)
    # the contiguous-objects conversion must be overlap-free too
    check_offsets(recs, _offsets_view(plan))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_auto_plan_cached_across_engine_constructions(arch):
    """The serving-path pattern: repeat plan_records on an unchanged graph
    must come from the cache (near-free auto-strategy sweeps)."""
    from repro.core.plan_io import PlanCache

    recs = list(config_records(arch))
    cache = PlanCache()
    first = plan_records(recs, strategy="auto", cache=cache)
    second = plan_records(recs, strategy="auto", cache=cache)
    assert not first.cache_hit and second.cache_hit
    assert second.total_size == first.total_size
    assert second.offsets == first.offsets


def test_records_are_wellformed_for_all_configs():
    for arch in ARCH_IDS:
        recs = config_records(arch)
        assert recs, arch
        for r in recs:
            assert isinstance(r, TensorUsageRecord)
            assert r.size % 64 == 0, "sizes must be alignment-rounded"
