"""Fast-path engagement tests for full-scale planning.

The 220-graph differential harness (``test_differential_planner``) runs
the production strategies at their DEFAULT thresholds, where the corpus
record sets are small enough that the vectorized arena engine never
engages. These tests force each engine explicitly:

* the numpy batch gap search (``BestFitArena(vector_threshold=0)``) must
  be byte-identical to the scalar tree walk over the whole corpus, for
  every offsets strategy and for raw arena placement sequences;
* the heap-based ``greedy_by_size_improved`` stage loop must survive the
  adversarial shapes its tie-breaking proof leans on (mass size ties,
  one single positional-maximum stage);
* 0-byte records are rejected at the record type itself, so neither the
  fast paths nor the frozen oracle can diverge on them (rejection
  parity by construction);
* an optional hypothesis property test re-states scalar-vs-vectorized
  equality over the generator families, plus a seeded random variant
  that runs even without hypothesis installed.
"""

import random

import pytest

from graph_gen import GENERATORS, generate
from repro.core import baselines, interval_set, offsets, reference, shared_objects
from repro.core.interval_set import BestFitArena
from repro.core.records import TensorUsageRecord

N_SEEDS = 55  # same corpus shape as test_differential_planner: 4 x 55

CASES = [(kind, seed) for kind in sorted(GENERATORS) for seed in range(N_SEEDS)]

OFFSET_STRATEGIES = {
    "greedy_by_size": offsets.greedy_by_size_offsets,
    "greedy_by_breadth": offsets.greedy_by_breadth_offsets,
    "strip_packing_bestfit": baselines.strip_packing_bestfit,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order_offsets,
}


def _arena_trace(recs, *, vector_threshold, first_fit=False):
    """Placement-order offsets + running totals for one arena engine."""
    arena = BestFitArena(
        first_fit=first_fit, vector_threshold=vector_threshold
    )
    trace = []
    for rec in recs:
        arena.place(rec)
        trace.append((rec.tensor_id, arena.offsets[rec.tensor_id], arena.total))
    return trace


def _assert_engines_match(recs, tag):
    big = 1 << 30  # scalar engine only
    for first_fit in (False, True):
        scalar = _arena_trace(recs, vector_threshold=big, first_fit=first_fit)
        vector = _arena_trace(recs, vector_threshold=0, first_fit=first_fit)
        assert scalar == vector, (
            f"{tag} first_fit={first_fit}: vectorized arena diverged"
        )


@pytest.mark.parametrize("kind,seed", CASES)
def test_vectorized_arena_corpus_byte_equality(kind, seed, monkeypatch):
    """Every offsets strategy, full corpus: forcing the numpy engine on
    from the first query must reproduce the scalar result exactly."""
    recs = generate(kind, seed)
    monkeypatch.setattr(interval_set, "VECTOR_THRESHOLD", 1 << 30)
    scalar = {
        name: fn(recs) for name, fn in OFFSET_STRATEGIES.items()
    }
    monkeypatch.setattr(interval_set, "VECTOR_THRESHOLD", 0)
    for name, fn in OFFSET_STRATEGIES.items():
        got = fn(recs)
        want = scalar[name]
        assert got.offsets == want.offsets, f"{name} {kind}/{seed}"
        assert got.total_size == want.total_size, f"{name} {kind}/{seed}"


@pytest.mark.parametrize("kind", sorted(GENERATORS))
def test_vectorized_arena_placement_traces(kind):
    """Raw arena API, both fit policies: per-placement offsets and
    running totals are identical between engines (stronger than final
    assignments — divergence is pinned to the first bad placement)."""
    for seed in range(8):
        recs = generate(kind, seed)
        _assert_engines_match(recs, f"{kind}/{seed}")


def test_vectorized_arena_mid_stream_handoff():
    """An arena that crosses the engagement threshold mid-sequence (the
    production path: scalar while sparse, vectorized once dense) must
    match the always-scalar trace too."""
    recs = generate("uniform", 3) + generate("ties", 4)
    recs = [
        TensorUsageRecord(r.first_op, r.last_op, r.size, tensor_id=i)
        for i, r in enumerate(recs)
    ]
    scalar = _arena_trace(recs, vector_threshold=1 << 30)
    handoff = _arena_trace(recs, vector_threshold=4)
    assert scalar == handoff


def _equal_size_records(n=64, size=4096, seed=0):
    rng = random.Random(seed)
    recs = []
    for i in range(n):
        a = rng.randrange(n)
        recs.append(
            TensorUsageRecord(a, min(a + rng.randrange(1, 6), n), size, tensor_id=i)
        )
    return recs


def test_heap_improved_many_equal_sizes():
    """Mass size ties exercise the heap's secondary ordering: the oracle
    breaks (gap, position, object) ties lexicographically, and equal
    sizes make every candidate pair a near-tie."""
    for seed in range(20):
        recs = _equal_size_records(seed=seed)
        fast = shared_objects.greedy_by_size_improved(recs)
        oracle = reference.greedy_by_size_improved(recs)
        assert fast.assignment == oracle.assignment, f"seed {seed}"
        assert [o.size for o in fast.objects] == [
            o.size for o in oracle.objects
        ], f"seed {seed}"


def test_heap_improved_single_stage():
    """All records share one op, so there is exactly one positional
    maximum — the whole problem is one stage and the heap loop must
    drain it in oracle order."""
    rng = random.Random(7)
    recs = [
        TensorUsageRecord(0, 1, rng.randrange(1, 64) * 64, tensor_id=i)
        for i in range(128)
    ]
    fast = shared_objects.greedy_by_size_improved(recs)
    oracle = reference.greedy_by_size_improved(recs)
    assert fast.assignment == oracle.assignment
    assert [o.size for o in fast.objects] == [o.size for o in oracle.objects]
    # one stage, fully conflicting: every tensor needs its own object
    assert len(fast.objects) == len(recs)


def test_zero_byte_records_rejected_before_any_planner():
    """Rejection parity by construction: size <= 0 never reaches either
    implementation because the record type itself refuses it."""
    with pytest.raises(ValueError):
        TensorUsageRecord(0, 1, 0, tensor_id=0)
    with pytest.raises(ValueError):
        TensorUsageRecord(0, 1, -64, tensor_id=0)


def test_scalar_vs_vectorized_random_property():
    """Seeded random property sweep (always runs): arbitrary record
    streams placed through both engines stay byte-identical."""
    rng = random.Random(0xC0FFEE)
    for case in range(40):
        n = rng.randrange(2, 80)
        n_ops = rng.randrange(2, 40)
        recs = [
            TensorUsageRecord(
                a := rng.randrange(n_ops),
                min(a + rng.randrange(0, 8), n_ops),
                rng.randrange(1, 1 << 12) * 64,
                tensor_id=i,
            )
            for i in range(n)
        ]
        _assert_engines_match(recs, f"random/{case}")


def test_scalar_vs_vectorized_hypothesis_property():
    """Hypothesis restatement of the same property over the generator
    families (skips cleanly where hypothesis is not installed)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings

    from graph_gen import hypothesis_records

    @settings(max_examples=60, deadline=None)
    @given(hypothesis_records())
    def check(recs):
        _assert_engines_match(recs, "hypothesis")

    check()