"""The sweep-line certifier against the O(n²) oracle and on its own.

Three layers of evidence that ``repro.analysis.soundness`` can be
trusted as the fast publish gate:

* **verdict agreement** — across the full differential corpus (4
  generator families × 55 seeds = 220 record sets, both planning modes)
  and the traced decode graphs of every model config, the certifier and
  ``repro.core.validate`` agree: valid plans produce zero findings and a
  clean oracle pass. (tests/test_analysis_mutation.py proves agreement
  on the *invalid* side with seeded corruptions.)
* **targeted fault detection** — each finding code fires on a minimal
  hand-built instance, so codes stay stable and meaningful.
* **scale** — a 50k-record plan certifies in well under the 5 s budget
  the O(n²) oracle cannot meet (it is quadratic in the tens of
  thousands of simultaneously-live tensors this shape creates).
"""

import random
import time

import pytest

from graph_gen import GENERATORS, config_records, generate
from repro.analysis import soundness
from repro.analysis.soundness import _SweepSet
from repro.configs.base import ARCH_IDS
from repro.core import offsets as offsets_mod
from repro.core import shared_objects as so_mod
from repro.core.records import TensorUsageRecord, make_records
from repro.core.validate import check_offsets, check_shared_objects

N_SEEDS = 55
CASES = [(kind, seed) for kind in sorted(GENERATORS) for seed in range(N_SEEDS)]


def _codes(findings):
    return {f.code for f in findings}


# ------------------------------------------------------- verdict agreement


@pytest.mark.parametrize("kind,seed", CASES)
def test_certifier_and_oracle_agree_on_corpus(kind, seed):
    recs = generate(kind, seed)

    asn = offsets_mod.greedy_by_size_offsets(recs)
    check_offsets(recs, asn)  # oracle verdict: valid
    findings = soundness.certify_offsets(recs, asn.offsets, asn.total_size)
    assert not findings, [f.render() for f in findings]

    so = so_mod.greedy_by_size(recs)
    check_shared_objects(recs, so)
    findings = soundness.certify_shared_objects(recs, so)
    assert not findings, [f.render() for f in findings]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_certifier_passes_config_graph_plans(arch):
    from repro.core.planner import plan_records

    recs = list(config_records(arch))
    for mode in ("offsets", "shared_objects"):
        plan = plan_records(recs, mode=mode, graph_name=f"{arch}-{mode}")
        findings = soundness.certify_plan(plan)
        assert not findings, [f.render() for f in findings]


def test_certifier_passes_real_state_plan():
    jax = pytest.importorskip("jax")
    from repro.core.unified import plan_state, state_records_from_pytree
    from repro.models.api import Model
    from repro.configs.base import get_reduced

    cfg = get_reduced("qwen3-0.6b")
    model = Model.for_config(cfg)
    caches = jax.eval_shape(lambda: model.init_cache(2, 32))
    sp = plan_state(
        state_records_from_pytree(caches, n_slots=2), n_slots=2, max_len=32
    )
    findings = soundness.certify_state_plan(sp)
    assert not findings, [f.render() for f in findings]


# --------------------------------------------------- targeted fault codes


def test_offsets_fault_codes():
    recs = make_records([(0, 2, 64), (1, 3, 32)])

    # coverage: a missing tensor short-circuits everything else
    assert _codes(soundness.certify_offsets(recs, {0: 0}, 96)) == {"coverage"}

    # negative offset + collision at the same address
    f = soundness.certify_offsets(recs, {0: -1, 1: -1}, 96)
    assert {"negative-offset", "arena-collision"} <= _codes(f)

    # spill past the arena end
    f = soundness.certify_offsets(recs, {0: 0, 1: 80}, 96)
    assert "arena-spill" in _codes(f)

    # bounds: larger than the naive sum / smaller than peak breadth
    assert "bounds" in _codes(soundness.certify_offsets(recs, {0: 0, 1: 64}, 128))
    ok = soundness.certify_offsets(recs, {0: 0, 1: 64}, 96)
    assert not ok


def test_offsets_collision_not_masked_by_first_report():
    # three tensors piled on the same bytes: every colliding PAIR that the
    # sweep's neighbor checks see must be reported (dedup is per pair)
    recs = make_records([(0, 5, 16), (0, 5, 16), (0, 5, 16)])
    f = soundness.certify_offsets(recs, {0: 0, 1: 0, 2: 0}, 48)
    collisions = [x for x in f if x.code == "arena-collision"]
    assert len(collisions) >= 2


def test_shared_objects_fault_codes():
    from repro.core.shared_objects import SharedObject, SharedObjectsAssignment

    recs = make_records([(0, 2, 64), (1, 3, 32)])
    # both tensors (overlapping in time) forced into one object
    asn = SharedObjectsAssignment(
        strategy="synthetic",
        objects=[SharedObject(object_id=0, size=64)],
        assignment={0: 0, 1: 0},
    )
    assert "object-collision" in _codes(
        soundness.certify_shared_objects(recs, asn)
    )

    # undersized object for its largest tensor
    asn = SharedObjectsAssignment(
        strategy="synthetic",
        objects=[SharedObject(object_id=0, size=48),
                 SharedObject(object_id=1, size=32)],
        assignment={0: 0, 1: 1},
    )
    assert "object-size-mismatch" in _codes(
        soundness.certify_shared_objects(recs, asn)
    )

    assert _codes(
        soundness.certify_shared_objects(recs, SharedObjectsAssignment(
            strategy="synthetic", objects=[], assignment={0: 0}
        ))
    ) == {"coverage"}


def test_state_plan_fault_codes():
    from repro.core.unified import StateLeaf, StatePlan

    def plan(**kw):
        base = dict(
            n_slots=2, max_len=16, alignment=64,
            leaves=[
                StateLeaf(path="a", shape=(2, 8, 8), dtype="float32",
                          slot_nbytes=256, offset=0),
                StateLeaf(path="b", shape=(2, 4, 4), dtype="float32",
                          slot_nbytes=64, offset=256),
            ],
            slot_stride=320, total_size=640,
        )
        base.update(kw)
        return StatePlan(**base)

    assert not soundness.certify_state_plan(plan())

    assert _codes(soundness.certify_state_plan(plan(alignment=0))) == {
        "state-alignment"
    }
    assert "state-total-mismatch" in _codes(
        soundness.certify_state_plan(plan(total_size=641))
    )
    assert "state-stride-unaligned" in _codes(
        soundness.certify_state_plan(
            plan(slot_stride=321, total_size=642)
        )
    )
    # slot_nbytes disagrees with shape x dtype: cannot self-certify
    bad = plan()
    bad.leaves[0] = StateLeaf(path="a", shape=(2, 8, 8), dtype="float32",
                              slot_nbytes=192, offset=0)
    assert "state-leaf-size" in _codes(soundness.certify_state_plan(bad))
    # leaf past the slot stride
    bad = plan()
    bad.leaves[1] = StateLeaf(path="b", shape=(2, 4, 4), dtype="float32",
                              slot_nbytes=64, offset=288)
    assert "state-leaf-spill" in _codes(soundness.certify_state_plan(bad))
    # two leaves on the same bytes
    bad = plan()
    bad.leaves[1] = StateLeaf(path="b", shape=(2, 4, 4), dtype="float32",
                              slot_nbytes=64, offset=128)
    assert "state-leaf-collision" in _codes(soundness.certify_state_plan(bad))


# ------------------------------------------------------------ sweep set


def test_sweep_set_neighbor_checks_match_brute_force():
    """Randomized differential for the core data structure: against a
    pairwise-disjoint resident set, the (pred, succ) neighbor check must
    flag a newcomer exactly when brute force finds an overlap."""
    rng = random.Random(7)
    s = _SweepSet()
    resident: list[tuple[int, int, int]] = []
    for tid in range(4000):
        off = rng.randrange(0, 60_000)
        item = (off, off + rng.randrange(1, 24), tid)
        pred, succ = s.add(item)
        flagged = any(
            o is not None and o[0] < item[1] and item[0] < o[1]
            for o in (pred, succ)
        )
        brute = any(o < item[1] and item[0] < e for o, e, _ in resident)
        assert flagged == brute, (item, pred, succ)
        if brute:
            s.remove(item)  # keep the resident set disjoint
        else:
            resident.append(item)
    assert len(s) == len(resident)
    # tear down through the chunked structure too
    rng.shuffle(resident)
    for item in resident:
        s.remove(item)
    assert len(s) == 0
    with pytest.raises(KeyError):
        s.remove((0, 1, 99))


# ----------------------------------------------------------------- scale


def test_certifier_scales_to_50k_records():
    """ISSUE acceptance: a >50k-record plan certifies in < 5 s. The layout
    is a naive prefix-sum (all address intervals disjoint), which keeps
    tens of thousands of tensors simultaneously live — the regime where
    the O(n²) oracle is unusable and the chunked sweep set earns its keep.
    """
    rng = random.Random(11)
    n = 50_001
    recs = []
    total = 0
    layout = {}
    for tid in range(n):
        a = rng.randrange(0, 4000)
        b = min(a + rng.randrange(0, 800), 4199)
        size = rng.randrange(1, 2048)
        recs.append(TensorUsageRecord(a, b, size, tensor_id=tid))
        layout[tid] = total
        total += size
    t0 = time.perf_counter()
    findings = soundness.certify_offsets(recs, layout, total)
    wall = time.perf_counter() - t0
    assert not findings, [f.render() for f in findings[:3]]
    assert wall < 5.0, f"certify took {wall:.2f}s on {n} records"
