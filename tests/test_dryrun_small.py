"""Dry-run machinery on a small in-process mesh (8 fake devices).

The full 512-device production dry-run runs via
``python -m repro.launch.dryrun --all`` (results in EXPERIMENTS.md §Dry-run);
here we verify the same build path lowers+compiles for every arch on a
(2, 4) mesh inside pytest, using a subprocess so the forced device count
never leaks into other tests.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.configs.base import ARCH_IDS

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs.base import get_reduced
from repro.launch.mesh import ShardingCtx
from repro.launch.roofline import count_params
from repro.launch.hlo_analysis import analyze
from repro.models.api import Model, ShapeSpec
from repro.launch.train import make_train_step
from repro.optim import adamw

arch = {arch!r}
cfg = get_reduced(arch)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh, cfg)
model = Model.for_config(cfg)
shape = ShapeSpec("small_train", seq_len=32, global_batch=4, kind="train")
params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
p_shard = ctx.param_shardings(params_shape)
batch = model.input_specs(shape)
b_shard = ctx.batch_shardings(batch)
opt_shape = jax.eval_shape(lambda: adamw.init_state(params_shape))
o_shard = {{
    "step": ctx.replicated(opt_shape["step"]),
    "m": ctx.param_shardings(opt_shape["m"]),
    "v": ctx.param_shardings(opt_shape["v"]),
}}
step = make_train_step(model, adamw.AdamWConfig(), constrain=ctx.constrain, remat=True)
with mesh:
    compiled = jax.jit(
        step, in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
    ).lower(params_shape, opt_shape, batch).compile()
    mem = compiled.memory_analysis()
cost = analyze(compiled.as_text())
print(json.dumps({{
    "ok": True,
    "flops": cost.flops,
    "bytes": cost.bytes,
    "temp": getattr(mem, "temp_size_in_bytes", 0),
}}))
"""


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_small_mesh_dryrun(arch):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"{arch} dry-run failed:\n{r.stderr[-3000:]}"
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["flops"] > 0 and res["temp"] > 0
