"""HLO cost analyzer: validated against XLA cost_analysis (loop-free) and
against hand-computed costs for scans (trip-count multiplication — the
thing XLA's analysis gets wrong; see launch/hlo_analysis.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo, xla_cost_analysis


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_loop_free_matmul_matches_xla():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a, b):
        return (a @ b).sum()

    c = _compile(f, x, x)
    ours = analyze(c.as_text())
    xla = xla_cost_analysis(c)["flops"]
    assert ours.flops == pytest.approx(xla, rel=0.05)
    assert ours.unknown_trip_loops == 0


def test_scan_flops_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def make(n):
        def g(a, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, a, None, length=n)
            return h.sum()
        return g

    c10 = analyze(_compile(make(10), x, x).as_text())
    c40 = analyze(_compile(make(40), x, x).as_text())
    matmul = 2 * 128**3
    assert c10.flops == pytest.approx(10 * matmul, rel=0.05)
    assert c40.flops == pytest.approx(40 * matmul, rel=0.05)
    # XLA's own analysis does NOT scale (documents why we built this)
    xla10 = xla_cost_analysis(_compile(make(10), x, x))["flops"]
    xla40 = xla_cost_analysis(_compile(make(40), x, x))["flops"]
    assert xla10 == pytest.approx(xla40, rel=0.01)


def test_nested_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def g(a, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, a, None, length=5)
        return h.sum()

    cost = analyze(_compile(g, x, x).as_text())
    assert cost.flops == pytest.approx(15 * 2 * 64**3, rel=0.05)


def test_collective_bytes_counted_with_trips():
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def g(a, w):
        def body(h, _):
            h = h @ w
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P(None, None))
            )
            return h, None
        h, _ = jax.lax.scan(body, a, None, length=4)
        return h.sum()

    # single-device: no collectives expected; parser must handle cleanly
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    cost = analyze(_compile(g, x, x).as_text())
    assert cost.collective_bytes == 0


def test_parse_hlo_structure():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = _compile(lambda a: jnp.sin(a) @ a, x)
    comps, entry = parse_hlo(c.as_text())
    assert entry is not None
    assert any(i.opcode == "dot" for cm in comps.values() for i in cm.instructions) or \
           any("dot" in i.opcode for cm in comps.values() for i in cm.instructions)
    ent = comps[entry]
    assert ent.instructions, "entry computation parsed"