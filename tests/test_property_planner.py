"""Hypothesis property tests over random usage-record sets.

Invariants (for EVERY strategy, paper's and baselines'):
  * plans are valid (independent checker re-derives constraints)
  * lower_bound <= total <= naive
  * Shared-Objects -> Offsets conversion preserves total and validity
  * greedy strategies match the exact branch-and-bound optimum on tiny
    instances within the known-greedy gap (and never beat it)
"""

import collections

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import baselines, extensions, offsets, optimal, shared_objects
from repro.core.fusion_search import fusion_search
from repro.core.graph import graph_from_records
from repro.core.offsets import from_shared_objects
from repro.core.order_search import memory_aware_topo_order, search_order
from repro.core.records import TensorUsageRecord
from repro.core.validate import check_offsets, check_shared_objects

ALL_SO = {
    **shared_objects.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order,
    "min_cost_flow": baselines.min_cost_flow_assignment,
    "greedy_by_conflict": extensions.greedy_by_conflict,
}
ALL_OFF = {
    **offsets.STRATEGIES,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order_offsets,
    "strip_packing_bestfit": baselines.strip_packing_bestfit,
    "best_of_all": extensions.offsets_best_of_all,
}


@st.composite
def usage_records(draw, max_tensors=24, max_ops=16, max_size=512):
    n = draw(st.integers(min_value=1, max_value=max_tensors))
    recs = []
    for i in range(n):
        a = draw(st.integers(min_value=0, max_value=max_ops - 1))
        b = draw(st.integers(min_value=a, max_value=max_ops - 1))
        s = draw(st.integers(min_value=1, max_value=max_size))
        recs.append(TensorUsageRecord(first_op=a, last_op=b, size=s, tensor_id=i))
    return recs


@settings(max_examples=120, deadline=None)
@given(usage_records())
def test_all_shared_object_strategies_valid(recs):
    for name, fn in ALL_SO.items():
        asn = fn(recs)
        check_shared_objects(recs, asn)


@settings(max_examples=120, deadline=None)
@given(usage_records())
def test_all_offset_strategies_valid(recs):
    for name, fn in ALL_OFF.items():
        asn = fn(recs)
        check_offsets(recs, asn)


@settings(max_examples=100, deadline=None)
@given(usage_records())
def test_conversion_preserves_total(recs):
    for fn in shared_objects.STRATEGIES.values():
        so = fn(recs)
        off = from_shared_objects(so)
        check_offsets(recs, off)
        assert off.total_size == so.total_size


@settings(max_examples=60, deadline=None)
@given(usage_records(max_tensors=9, max_ops=8, max_size=64))
def test_greedy_vs_optimal_shared_objects(recs):
    opt = optimal.optimal_shared_objects_total(recs)
    for name, fn in shared_objects.STRATEGIES.items():
        total = fn(recs).total_size
        assert total >= opt, f"{name} beat the optimum: {total} < {opt}"
        # greedy is near-optimal on tiny instances (paper's observation);
        # allow 2x slack so the test documents rather than flakes
        assert total <= 2 * opt, f"{name} far from optimum: {total} vs {opt}"


@settings(max_examples=60, deadline=None)
@given(usage_records(max_tensors=9, max_ops=8, max_size=64))
def test_greedy_vs_optimal_offsets(recs):
    opt = optimal.optimal_offsets_total(recs)
    for name, fn in offsets.STRATEGIES.items():
        total = fn(recs).total_size
        assert total >= opt, f"{name} beat the optimum: {total} < {opt}"
        assert total <= 2 * opt, f"{name} far from optimum: {total} vs {opt}"


@settings(max_examples=40, deadline=None)
@given(usage_records(max_tensors=12, max_ops=10, max_size=64))
def test_order_searches_return_valid_topo_orders(recs):
    """Every graph returned by the order searches is a topological order
    of the input with an identical op multiset and tensor table, and the
    annealing result is deterministic for a fixed seed."""
    g = graph_from_records(recs)
    ops = collections.Counter(
        (op.name, op.inputs, op.outputs) for op in g.ops
    )
    res = search_order(g, iters=40, seed=3)
    for out in (memory_aware_topo_order(g), res.graph):
        out.validate()
        assert collections.Counter(
            (op.name, op.inputs, op.outputs) for op in out.ops
        ) == ops
        assert out.tensors == g.tensors
        # intervals may legitimately change; the planned tensor multiset
        # (ids + sizes) must not
        assert sorted(
            (r.tensor_id, r.size) for r in out.usage_records(alignment=1)
        ) == sorted(
            (r.tensor_id, r.size) for r in g.usage_records(alignment=1)
        )
    assert res.plan.total_size <= res.baseline_plan.total_size
    again = search_order(g, iters=40, seed=3)
    assert again.order == res.order


@settings(max_examples=30, deadline=None)
@given(usage_records(max_tensors=10, max_ops=8, max_size=64))
def test_fusion_search_valid_and_never_worse(recs):
    """The fused graph is valid, plans only original intermediates, and
    its planned arena never exceeds the unfused baseline."""
    g = graph_from_records(recs)
    res = fusion_search(g, max_group_ops=3)
    res.graph.validate()
    assert res.plan.total_size <= res.baseline_plan.total_size
    assert {r.tensor_id for r in res.plan.records} <= set(g.intermediate_ids())
    assert [i for grp in res.groups for i in grp] == list(range(len(g.ops)))


@settings(max_examples=60, deadline=None)
@given(usage_records(max_tensors=16, max_ops=12))
def test_graph_roundtrip(recs):
    """graph_from_records reproduces the records (alignment=1)."""
    g = graph_from_records(recs)
    back = {r.tensor_id: r for r in g.usage_records(alignment=1)}
    for r in recs:
        b = back[r.tensor_id]
        assert (b.first_op, b.last_op, b.size) == (r.first_op, r.last_op, r.size)
