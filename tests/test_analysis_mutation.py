"""Mutation harness: seeded corruptions must never slip past the gate.

Takes valid plans from the differential corpus, injects one fault at a
time — shifted offsets, shrunk object sizes, truncated lifetimes,
swapped StatePlan leaf offsets — and asserts

* the sweep-line certifier flags EVERY injected fault (error-severity
  finding with the expected code), and
* for the activation-side mutations, the O(n²) oracle twin
  (``repro.core.validate``) reaches the same verdict — the invalid half
  of the byte-for-byte verdict agreement that
  tests/test_analysis_soundness.py proves on valid plans.

Pristine plans from the same corpus must certify clean, so the harness
also guards against an over-eager certifier that would "catch" every
mutation by rejecting everything.
"""

import random

import pytest

from graph_gen import GENERATORS, generate
from repro.analysis import soundness
from repro.core import offsets as offsets_mod
from repro.core import shared_objects as so_mod
from repro.core.records import TensorUsageRecord
from repro.core.validate import (
    PlanValidationError,
    check_offsets,
    check_shared_objects,
)

MUT_CASES = [(kind, seed) for kind in sorted(GENERATORS) for seed in range(12)]


def _error_codes(findings):
    return {f.code for f in findings if f.severity == "error"}


def _oracle_offsets_verdict(recs, offsets, total_size):
    asn = offsets_mod.OffsetAssignment(
        strategy="mutated", offsets=offsets, total_size=total_size
    )
    try:
        check_offsets(recs, asn)
        return True
    except PlanValidationError:
        return False


# ------------------------------------------------------------- mutations


@pytest.mark.parametrize("kind,seed", MUT_CASES)
def test_shifted_offset_is_caught(kind, seed):
    recs = generate(kind, seed)
    asn = offsets_mod.greedy_by_size_offsets(recs)
    assert not soundness.certify_offsets(recs, asn.offsets, asn.total_size)

    pair = next(
        (
            (a, b)
            for i, a in enumerate(recs)
            for b in recs[i + 1 :]
            if a.overlaps(b)
        ),
        None,
    )
    if pair is None:
        pytest.skip("no simultaneously-live pair in this record set")
    a, b = pair
    mutated = dict(asn.offsets)
    mutated[b.tensor_id] = mutated[a.tensor_id]  # pile b onto a's bytes
    findings = soundness.certify_offsets(recs, mutated, asn.total_size)
    assert "arena-collision" in _error_codes(findings), (
        a, b, [f.render() for f in findings]
    )
    assert not _oracle_offsets_verdict(recs, mutated, asn.total_size)


@pytest.mark.parametrize("kind,seed", MUT_CASES)
def test_shrunk_object_size_is_caught(kind, seed):
    import dataclasses

    recs = generate(kind, seed)
    asn = so_mod.greedy_by_size(recs)
    assert not soundness.certify_shared_objects(recs, asn)

    shrunk = dataclasses.replace(
        asn,
        objects=[dataclasses.replace(asn.objects[0], size=asn.objects[0].size - 1)]
        + asn.objects[1:],
    )
    findings = soundness.certify_shared_objects(recs, shrunk)
    assert "object-size-mismatch" in _error_codes(findings)
    with pytest.raises(PlanValidationError):
        check_shared_objects(recs, shrunk)


def test_truncated_lifetime_is_caught():
    """Plan against truncated lifetimes, validate against the true ones:
    the planner legitimately packs the shortened tensor against a real
    neighbor, so the certifier (and the oracle) must reject the plan for
    the records as they actually are. Not every record set yields a
    colliding layout after one truncation, so sweep the corpus and
    require a healthy number of injected faults — every one caught, with
    oracle agreement on every verdict."""
    faults = 0
    for kind, seed in MUT_CASES:
        recs = generate(kind, seed)
        victim = max(recs, key=lambda r: r.last_op - r.first_op)
        if victim.last_op == victim.first_op:
            continue
        truncated = [
            TensorUsageRecord(r.first_op, r.first_op, r.size, tensor_id=r.tensor_id)
            if r.tensor_id == victim.tensor_id
            else r
            for r in recs
        ]
        asn = offsets_mod.greedy_by_size_offsets(truncated)
        findings = soundness.certify_offsets(recs, asn.offsets, asn.total_size)
        oracle_ok = _oracle_offsets_verdict(recs, asn.offsets, asn.total_size)
        assert oracle_ok == (not _error_codes(findings)), (
            kind, seed, [f.render() for f in findings]
        )
        if not oracle_ok:
            faults += 1
            assert _error_codes(findings) <= {"arena-collision", "bounds"}
    assert faults >= len(MUT_CASES) // 4, (
        f"only {faults} of {len(MUT_CASES)} truncations produced a fault — "
        f"the harness is not exercising the certifier"
    )


@pytest.mark.parametrize("seed", range(8))
def test_swapped_state_leaf_offsets_are_caught(seed):
    from repro.core.unified import StateRecord, plan_state

    rng = random.Random(seed)
    n_slots = 2
    sizes = rng.sample([128, 256, 512, 1024, 2048], k=3)
    records = [
        StateRecord(
            path=f"leaf{i}", shape=(n_slots, s // (4 * n_slots)),
            dtype="float32", nbytes=s,
        )
        for i, s in enumerate(sizes)
    ]
    sp = plan_state(records, n_slots=n_slots, max_len=16)
    assert not soundness.certify_state_plan(sp)

    # swap the offsets of two different-sized leaves: the larger one now
    # overruns into its neighbor (or past the stride)
    import dataclasses

    leaves = sorted(sp.leaves, key=lambda l: l.slot_nbytes)
    small, big = leaves[0], leaves[-1]
    assert small.slot_nbytes != big.slot_nbytes
    swapped = [
        dataclasses.replace(
            leaf,
            offset=(
                big.offset if leaf.path == small.path
                else small.offset if leaf.path == big.path
                else leaf.offset
            ),
        )
        for leaf in sp.leaves
    ]
    mutated = dataclasses.replace(sp, leaves=swapped)
    codes = _error_codes(soundness.certify_state_plan(mutated))
    assert codes & {"state-leaf-collision", "state-leaf-spill"}, codes


def test_shrunk_state_leaf_is_caught():
    import dataclasses

    from repro.core.unified import StateRecord, plan_state

    sp = plan_state(
        [
            StateRecord(path="kv", shape=(2, 64), dtype="float32", nbytes=512),
            StateRecord(path="conv", shape=(2, 16), dtype="float32", nbytes=128),
        ],
        n_slots=2,
        max_len=16,
    )
    leaves = [dataclasses.replace(sp.leaves[0], slot_nbytes=sp.leaves[0].slot_nbytes // 2)]
    leaves += sp.leaves[1:]
    mutated = dataclasses.replace(sp, leaves=leaves)
    assert "state-leaf-size" in _error_codes(
        soundness.certify_state_plan(mutated)
    )


# ------------------------------------------------- paged-plan mutations


def _paged_plan(page_size=64, page_pool=None):
    from repro.core.unified import StateRecord, plan_paged_state

    n_slots = 2
    records = [
        StateRecord(path="kv", shape=(n_slots, 16, 8), dtype="float32",
                    nbytes=n_slots * 16 * 8 * 4),
        StateRecord(path="ssm", shape=(n_slots, 24), dtype="float32",
                    nbytes=n_slots * 24 * 4),
    ]
    return plan_paged_state(
        records, n_slots=n_slots, max_len=16, page_size=page_size,
        page_pool=page_pool, axes={"kv": (0, 1), "ssm": (0, None)},
    )


def test_paged_pristine_certifies_clean():
    for page in (64, 100, 4096):
        assert not soundness.certify_state_plan(_paged_plan(page))


@pytest.mark.parametrize(
    "mutate,code",
    [
        # pile pool page 1 onto page 0's bytes
        (lambda sp: {"page_offsets": [sp.page_offsets[0]]
                     + sp.page_offsets[1:-1] + [sp.page_offsets[0]]},
         "paged-page-collision"),
        # steal the reserved null page at physical offset 0
        (lambda sp: {"page_offsets": [0] + sp.page_offsets[1:]},
         "paged-page-collision"),
        # knock a page off its alignment
        (lambda sp: {"page_offsets": [sp.page_offsets[0] + 1]
                     + sp.page_offsets[1:]},
         "paged-page-unaligned"),
        # push the last page past the physical end of the pool buffer
        (lambda sp: {"page_offsets": sp.page_offsets[:-1]
                     + [sp.phys_total_size]},
         "paged-page-spill"),
        # drop a token span: leaves and spans fall out of step
        (lambda sp: {"token_spans": sp.token_spans[:-1]},
         "paged-span-size"),
        # shrink a span's row count: it no longer covers the leaf payload
        (lambda sp: {"token_spans": [(1, 8, 32)] + sp.token_spans[1:]},
         "paged-span-size"),
        # declare an empty pool
        (lambda sp: {"n_pages_pool": 0, "page_offsets": []},
         "paged-pool-empty"),
        (lambda sp: {"page_size": 0}, "paged-page-size"),
    ],
)
def test_paged_mutation_is_caught(mutate, code):
    import dataclasses

    sp = _paged_plan()
    mutated = dataclasses.replace(sp, **mutate(sp))
    codes = _error_codes(soundness.certify_state_plan(mutated))
    assert code in codes, codes


def test_paged_pool_too_small_for_one_slot_warns():
    sp = _paged_plan(page_pool=2)  # pages_per_slot is far above 2
    findings = soundness.certify_state_plan(sp)
    assert not _error_codes(findings), "a short pool is legal, not unsound"
    assert "paged-pool-short" in {
        f.code for f in findings if f.severity == "warning"
    }
