"""End-to-end tests for the compile→artifact→serve pipeline.

Covers the serving side of the unified planning API:
* ``launch/serve.py`` — end-to-end smoke on a reduced config (submit →
  run_until_done → token counts + slot-reuse audit) plus bucket
  auto-selection against a multi-bucket manifest;
* the plan-artifact path: engine construction from a v2 ``PlanBundle``
  (through ``PlanSession``) must perform NO jaxpr trace, NO planner call,
  and NO cross-step state layout (asserted via the instrumentation
  counters — both halves ship in the bundle), must produce a
  byte-identical ``MemoryPlan`` to the plan-at-construction path, and
  must degrade gracefully (one-line warning, plan-at-construction
  fallback) on fingerprint mismatch, a corrupt artifact, or a v1 bundle
  read by this v2 engine;
* the deprecated plan-source kwargs, which must keep working behind a
  ``DeprecationWarning``.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.analysis import counters
from repro.configs.base import get_reduced
from repro.core import plan_io
from repro.core.artifact import bucket_key, bundle_to_obj
from repro.core.unified import PlanSession
from repro.launch import serve
from repro.launch.compile import compile_and_publish
from repro.models.api import Model
from repro.runtime.engine import InferenceEngine

ARCH = "qwen3-0.6b"
N_SLOTS, MAX_LEN = 2, 48


def _counters():
    # the no-work-at-serving-time discipline, via the analysis registry
    return counters.snapshot(("trace_calls", "plan_calls", "state_plan_calls"))


@pytest.fixture(scope="module")
def cfg():
    return get_reduced(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return Model.for_config(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def bundle_dir(cfg, tmp_path_factory):
    d = tmp_path_factory.mktemp("bundles")
    compile_and_publish(
        cfg, d, n_slots=N_SLOTS, max_len=MAX_LEN, command="pytest"
    )
    return d


# ----------------------------------------------------------- serve driver


def test_serve_end_to_end_smoke():
    stats = serve.run([
        "--arch", ARCH, "--requests", "5", "--prompt-len", "4",
        "--max-new", "4", "--slots", "2", "--max-len", "48",
    ])
    assert stats["requests"] == 5
    assert stats["tokens"] == 5 * 4
    assert all(len(t) == 4 for t in stats["tokens_per_request"].values())
    assert stats["plan_source"] in ("planned", "cache")
    assert stats["cold_start_s"] > 0
    # unified accounting is part of the driver's report now
    assert stats["state_total_bytes"] > 0
    assert stats["unified_total_bytes"] == (
        stats["plan_total_bytes"] + stats["state_total_bytes"]
    )
    # slot-reuse audit: 5 requests over 2 slots must reuse slots, and no
    # two requests may overlap on one slot (the §4 invariant). serve.run
    # itself audits via shared_objects.from_slot_log (raises on overlap).
    log = stats["slot_log"]
    assert len(log) == 5
    by_slot: dict[int, list[tuple[int, int]]] = {}
    for slot, first, last, _rid in log:
        by_slot.setdefault(slot, []).append((first, last))
    assert any(len(v) > 1 for v in by_slot.values())


def test_serve_from_bundle_dir(bundle_dir):
    stats = serve.run([
        "--arch", ARCH, "--requests", "3", "--prompt-len", "4",
        "--max-new", "3", "--slots", str(N_SLOTS), "--max-len", str(MAX_LEN),
        "--plan-bundle", str(bundle_dir), "--compare-cold-start",
    ])
    assert stats["plan_source"] == "bundle"
    assert stats["bundle_warning"] is None
    assert stats["tokens"] == 3 * 3
    assert stats["cold_start_noartifact_s"] is not None
    assert stats["effective_max_len"] == MAX_LEN


def test_serve_auto_selects_nearest_bucket(cfg, tmp_path):
    """Acceptance: a multi-bucket manifest serves a request whose max_len
    has NO exact compiled match from the nearest compiled bucket — with
    zero traces, zero planner calls, and zero state layouts."""
    for max_len in (64, 128):
        compile_and_publish(
            cfg, tmp_path, n_slots=N_SLOTS, max_len=max_len, command="pytest"
        )
    before = _counters()
    stats = serve.run([
        "--arch", ARCH, "--requests", "2", "--prompt-len", "3",
        "--max-new", "2", "--slots", str(N_SLOTS), "--max-len", "96",
        "--plan-bundle", str(tmp_path),
    ])
    assert _counters() == before, (
        "bucket auto-selection traced/planned/laid out state"
    )
    assert stats["plan_source"] == "bundle"
    assert stats["requested_max_len"] == 96
    assert stats["effective_max_len"] == 128  # nearest compiled >= 96
    assert stats["tokens"] == 2 * 2
    # --exact-bucket turns selection off: miss -> fallback with the
    # readable bucket listing
    stats = serve.run([
        "--arch", ARCH, "--requests", "1", "--prompt-len", "3",
        "--max-new", "2", "--slots", str(N_SLOTS), "--max-len", "96",
        "--plan-bundle", str(tmp_path), "--exact-bucket",
    ])
    assert stats["plan_source"] in ("planned", "cache")
    assert "compiled buckets" in stats["bundle_warning"]


def test_serve_auto_selects_bigger_slot_pool(cfg, tmp_path):
    """Satellite: a fleet compiled only at slots=4 serves a slots=2
    request from the bigger pool (slots are the §4 shared objects — a
    wider pool is admissible, just wasteful) with zero traces/plans/state
    layouts; the engine reports the effective pool size."""
    compile_and_publish(cfg, tmp_path, n_slots=4, max_len=MAX_LEN,
                        command="pytest")
    before = _counters()
    stats = serve.run([
        "--arch", ARCH, "--requests", "3", "--prompt-len", "3",
        "--max-new", "2", "--slots", "2", "--max-len", str(MAX_LEN),
        "--plan-bundle", str(tmp_path),
    ])
    assert _counters() == before
    assert stats["plan_source"] == "bundle"
    assert stats["requested_slots"] == 2
    assert stats["effective_slots"] == 4
    assert stats["tokens"] == 3 * 2
    # one state allocation sized by the SERVED bucket's plan
    assert stats["state_live_bytes"] == stats["state_planned_bytes"]
    # --exact-bucket still disables the substitution
    stats = serve.run([
        "--arch", ARCH, "--requests", "1", "--prompt-len", "3",
        "--max-new", "2", "--slots", "2", "--max-len", str(MAX_LEN),
        "--plan-bundle", str(tmp_path), "--exact-bucket",
    ])
    assert stats["plan_source"] in ("planned", "cache")
    assert stats["effective_slots"] == 2


def test_serve_compile_first(tmp_path):
    out = tmp_path / "artifacts"
    stats = serve.run([
        "--arch", ARCH, "--requests", "2", "--prompt-len", "3",
        "--max-new", "2", "--slots", "2", "--max-len", "32",
        "--plan-bundle", str(out), "--compile-first",
    ])
    assert stats["plan_source"] == "bundle"
    assert (out / "manifest.json").exists()


# ------------------------------------------------------ artifact serving


def test_engine_from_bundle_no_trace_no_plan_no_state_layout(
    cfg, params, bundle_dir
):
    with counters.capture(
        "trace_calls", "plan_calls", "state_plan_calls"
    ) as cap:
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_manifest(bundle_dir),
        )
    assert cap.delta("trace_calls") == 0, "bundle path traced a jaxpr"
    assert cap.delta("plan_calls") == 0, "bundle path invoked the planner"
    assert cap.delta("state_plan_calls") == 0, (
        "bundle path laid out the cross-step state"
    )
    rep = engine.memory_report
    assert rep.plan_source == "bundle"
    assert rep.bundle_warning is None
    assert "precompiled bundle" in rep.summary()
    assert engine.plan_bundle is not None
    # BOTH halves came from the artifact
    assert rep.state_plan is not None
    assert rep.state_plan == engine.plan_bundle.state_plan
    assert engine.unified_plan.total_size == engine.plan_bundle.total_size
    # the arena is materialized straight from the stored offsets, and the
    # state layout from the stored slot/KV plan
    assert engine.activation_arena.nbytes == max(rep.activation_plan.total_size, 1)
    engine.state_layout.validate()
    assert engine.state_layout.total_size == rep.state_plan.total_size


def test_bundle_plan_byte_identical_to_construction_plan(cfg, params, bundle_dir):
    eng_b = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_manifest(bundle_dir),
    )
    eng_p = InferenceEngine(cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN)
    a = plan_io.plan_to_obj(eng_b.memory_report.activation_plan)
    b = plan_io.plan_to_obj(eng_p.memory_report.activation_plan)
    # wall time is measurement, not plan content
    a["plan_wall_s"] = b["plan_wall_s"] = 0.0
    ja = json.dumps(a, sort_keys=True, separators=(",", ":"))
    jb = json.dumps(b, sort_keys=True, separators=(",", ":"))
    assert ja == jb
    # the engine-side state layout matches the bundled one too
    from repro.core.unified import state_plan_to_obj

    assert state_plan_to_obj(eng_b.memory_report.state_plan) == (
        state_plan_to_obj(eng_p.memory_report.state_plan)
    )


def test_bundle_engine_serves_identical_tokens(cfg, params, bundle_dir):
    engines = [
        InferenceEngine(cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                        session=PlanSession.from_manifest(bundle_dir)),
        InferenceEngine(cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN),
    ]
    outs = []
    for eng in engines:
        rng = np.random.default_rng(7)
        for _ in range(3):
            eng.submit(rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                       max_new_tokens=3)
        done = eng.run_until_done()
        outs.append({r.request_id: r.tokens for r in done})
    assert outs[0] == outs[1]


def test_fingerprint_mismatch_falls_back_with_warning(cfg, params, bundle_dir):
    """An exact-bucket session must not serve a bundle whose fingerprint
    disagrees with the requested bucket; the engine plans at construction
    and says why in one line."""
    from repro.core.artifact import BundleManifest

    # grab the (valid) bundle and re-publish it under the bucket the engine
    # will look up for max_len=32 — fingerprint still says max_len=48
    man = BundleManifest(bundle_dir)
    good = man.lookup(bucket_key(cfg, n_slots=N_SLOTS, max_len=MAX_LEN))
    wrong_key = bucket_key(cfg, n_slots=N_SLOTS, max_len=32)
    man.publish(wrong_key, good)
    traces0 = counters.read("trace_calls")
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=32,
        session=PlanSession.from_manifest(bundle_dir, nearest=False),
    )
    rep = engine.memory_report
    assert rep.plan_source in ("planned", "cache")
    assert rep.bundle_warning is not None
    assert "fingerprint mismatch" in rep.bundle_warning
    assert "WARNING" in rep.summary()
    assert counters.read("trace_calls") > traces0  # fallback really replanned
    # and the engine still serves
    engine.submit(np.arange(3, dtype=np.int32), max_new_tokens=2)
    assert len(engine.run_until_done()) == 1

    # the SAME situation with auto-selection on is admissible: the len=48
    # bundle (a self-consistent longer bucket) serves the len=32 request
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=32,
        session=PlanSession.from_manifest(bundle_dir),
    )
    assert engine.memory_report.plan_source == "bundle"
    assert engine.max_len == MAX_LEN


def test_missing_and_corrupt_bundles_fall_back(cfg, params, tmp_path):
    # missing bucket in an empty manifest dir — the warning lists what
    # exists (here: nothing)
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_manifest(tmp_path),
    )
    assert engine.memory_report.plan_source in ("planned", "cache")
    assert "unusable" in engine.memory_report.bundle_warning
    assert "manifest is empty" in engine.memory_report.bundle_warning
    # corrupt single-file bundles: garbage, valid-JSON-wrong-shape — all
    # must degrade to plan-at-construction, never crash serving
    for name, text in (("bad.json", "{not json"),
                       ("list.json", "[1, 2, 3]"),
                       ("shallow.json", '{"format_version": 2}')):
        bad = tmp_path / name
        bad.write_text(text)
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_bundle(bad),
        )
        assert engine.memory_report.bundle_warning is not None, name
        assert engine.memory_report.plan_source in ("planned", "cache")


def test_v1_bundle_on_v2_engine_falls_back(
    cfg, params, bundle_dir, tmp_path, monkeypatch
):
    """Satellite: a v1 document loads through the shim (DeprecationWarning)
    but its fingerprint hashed schema v1 — a current engine must refuse it
    and plan at construction, preserving the fallback semantics."""
    from repro.core import artifact
    from repro.core.artifact import BundleManifest

    good = BundleManifest(bundle_dir).lookup(
        bucket_key(cfg, n_slots=N_SLOTS, max_len=MAX_LEN)
    )
    with monkeypatch.context() as m:
        # what decode_fingerprint produced when this build wrote v1 (the
        # fingerprint schema rolls independently of the bundle format, so
        # v2 documents keep matching a v3 engine — only the v1-era hash
        # is stale)
        m.setattr(artifact, "FINGERPRINT_SCHEMA_VERSION", 1)
        v1_fp = artifact.decode_fingerprint(
            cfg, n_slots=N_SLOTS, max_len=MAX_LEN
        )
    obj = bundle_to_obj(good)
    obj["format_version"] = 1
    obj["fingerprint"] = v1_fp
    for key in ("state_plan", "n_layers", "d_model"):
        del obj[key]
    f = tmp_path / "v1.json"
    f.write_text(json.dumps(obj, sort_keys=True, separators=(",", ":")))

    with pytest.deprecated_call(match="format v1"):
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_bundle(f),
        )
    rep = engine.memory_report
    assert rep.plan_source in ("planned", "cache")
    assert "fingerprint mismatch" in rep.bundle_warning
    # the fallback still produced a full unified plan
    assert rep.state_plan is not None
    engine.submit(np.arange(3, dtype=np.int32), max_new_tokens=2)
    assert len(engine.run_until_done()) == 1


def test_legacy_plan_bundle_kwarg_warns_and_serves(cfg, params, bundle_dir):
    """The deprecated kwargs keep working behind a DeprecationWarning and
    exact-bucket semantics."""
    with pytest.deprecated_call(match="session=PlanSession"):
        engine = InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            plan_bundle=bundle_dir,
        )
    assert engine.memory_report.plan_source == "bundle"
    with pytest.raises(ValueError, match="not both"):
        InferenceEngine(
            cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
            session=PlanSession.from_manifest(bundle_dir),
            plan_bundle=bundle_dir,
        )


def test_verify_bundle_checks_graph_fingerprint(cfg, params, bundle_dir, tmp_path):
    """The config fingerprint cannot see model-code changes;
    verify_graph=True trades the zero-trace cold start for a structural
    check of the stored graph fingerprint against a fresh trace."""
    from repro.core.artifact import BundleManifest, save_bundle

    good = BundleManifest(bundle_dir).lookup(
        bucket_key(cfg, n_slots=N_SLOTS, max_len=MAX_LEN)
    )
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_bundle(good, verify_graph=True),
    )
    assert engine.memory_report.plan_source == "bundle"

    tampered = dataclasses.replace(good, graph_fingerprint="0" * 64)
    f = tmp_path / "tampered.json"
    save_bundle(tampered, f)
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_bundle(f, verify_graph=True),
    )
    rep = engine.memory_report
    assert rep.plan_source in ("planned", "cache")
    assert "graph fingerprint mismatch" in rep.bundle_warning


def test_bundle_carries_xla_temp_measurement(cfg, params, bundle_dir):
    """compile.py measures XLA's temp allocation offline so bundle-served
    reports keep the planned-vs-XLA validation line."""
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_manifest(bundle_dir),
    )
    prov = engine.plan_bundle.provenance
    assert "xla_temp_bytes" in prov
    assert engine.memory_report.xla_temp_bytes == prov["xla_temp_bytes"]


def test_searched_bundle_is_served_and_never_worse(cfg, params, tmp_path):
    res = compile_and_publish(
        cfg, tmp_path, n_slots=N_SLOTS, max_len=MAX_LEN,
        search=True, search_iters=60, fusion_rounds=10,
    )
    assert res.bundle.plan.total_size <= res.greedy_plan.total_size
    engine = InferenceEngine(
        cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        session=PlanSession.from_manifest(tmp_path),
    )
    rep = engine.memory_report
    assert rep.plan_source == "bundle"
    assert rep.activation_plan.total_size == res.bundle.plan.total_size
    prov = engine.plan_bundle.provenance
    assert prov["searched_total_bytes"] <= prov["greedy_total_bytes"]
    # searched plans still serve correct tokens
    engine.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
    done = engine.run_until_done()
    assert len(done) == 1 and len(done[0].tokens) == 3
