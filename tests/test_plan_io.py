"""Plan serialization + content-addressed plan cache (core/plan_io)."""

import dataclasses

import pytest

from repro.core import plan_io
from repro.core.graph import graph_from_records
from repro.core.planner import plan_graph, plan_records
from repro.core.records import TensorUsageRecord, make_records

RECS = [
    (0, 1, 64), (1, 3, 128), (2, 4, 64), (4, 5, 256), (0, 5, 32), (3, 3, 512),
]


def _plans_equal(a, b) -> bool:
    if (a.graph_name, a.strategy, a.records, a.offsets, a.total_size,
            a.lower_bound, a.naive_size) != \
       (b.graph_name, b.strategy, b.records, b.offsets, b.total_size,
            b.lower_bound, b.naive_size):
        return False
    if (a.shared_objects is None) != (b.shared_objects is None):
        return False
    if a.shared_objects is not None:
        sa, sb = a.shared_objects, b.shared_objects
        if sa.assignment != sb.assignment or sa.strategy != sb.strategy:
            return False
        if [(o.object_id, o.size, o.intervals) for o in sa.objects] != \
           [(o.object_id, o.size, o.intervals) for o in sb.objects]:
            return False
    return True


# ----------------------------------------------------------- round-trips


@pytest.mark.parametrize("mode,strategy", [
    ("offsets", "auto"),
    ("offsets", "greedy_by_size"),
    ("shared_objects", "greedy_by_size_improved"),
])
def test_json_roundtrip(mode, strategy):
    plan = plan_records(
        make_records(RECS), mode=mode, strategy=strategy, use_cache=False
    )
    text = plan_io.plan_to_json(plan)
    back = plan_io.plan_from_json(text)
    assert _plans_equal(plan, back)
    # canonical: serializing the deserialized plan is byte-identical
    assert plan_io.plan_to_json(back) == text


def test_save_load_file(tmp_path):
    plan = plan_records(make_records(RECS), use_cache=False)
    path = tmp_path / "plan.json"
    plan_io.save_plan(plan, path)
    assert _plans_equal(plan_io.load_plan(path), plan)


def test_unknown_format_version_rejected():
    plan = plan_records(make_records(RECS), use_cache=False)
    obj = plan_io.plan_to_obj(plan)
    obj["format_version"] = plan_io.PLAN_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format version"):
        plan_io.plan_from_obj(obj)


# ------------------------------------------------------------- signatures


def test_signature_ignores_record_order_and_graph_name():
    recs = make_records(RECS)
    shuffled = list(reversed(recs))
    k1 = plan_io.plan_signature(recs, mode="offsets", strategy="auto")
    k2 = plan_io.plan_signature(shuffled, mode="offsets", strategy="auto")
    assert k1 == k2


def test_signature_sensitive_to_inputs():
    recs = make_records(RECS)
    base = plan_io.plan_signature(recs, mode="offsets", strategy="auto")
    assert plan_io.plan_signature(recs, mode="offsets", strategy="greedy_by_size") != base
    assert plan_io.plan_signature(recs, mode="shared_objects", strategy="auto") != base
    grown = recs[:-1] + [dataclasses.replace(recs[-1], size=recs[-1].size + 64)]
    assert plan_io.plan_signature(grown, mode="offsets", strategy="auto") != base


# ------------------------------------------------------------------ cache


def test_cache_hit_returns_equivalent_plan():
    cache = plan_io.PlanCache()
    recs = make_records(RECS)
    p1 = plan_records(recs, strategy="auto", cache=cache)
    p2 = plan_records(recs, strategy="auto", cache=cache, graph_name="renamed")
    assert not p1.cache_hit
    assert p2.cache_hit
    assert p2.graph_name == "renamed"
    assert p2.offsets == p1.offsets and p2.total_size == p1.total_size
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1


def test_cache_miss_on_strategy_change():
    cache = plan_io.PlanCache()
    recs = make_records(RECS)
    plan_records(recs, strategy="greedy_by_size", cache=cache)
    p = plan_records(recs, strategy="greedy_by_breadth", cache=cache)
    assert not p.cache_hit
    assert cache.stats["misses"] == 2


def test_cache_invalidated_by_alignment_change():
    graph = graph_from_records(make_records(RECS), name="g")
    cache = plan_io.PlanCache()
    p64 = plan_graph(graph, alignment=64, cache=cache)
    p1 = plan_graph(graph, alignment=1, cache=cache)
    assert not p1.cache_hit, "different alignment must not share a cache entry"
    again = plan_graph(graph, alignment=64, cache=cache)
    assert again.cache_hit and again.total_size == p64.total_size


def test_cache_result_is_isolated_from_caller_mutation():
    cache = plan_io.PlanCache()
    recs = make_records(RECS)
    p1 = plan_records(recs, cache=cache)
    p1.offsets[recs[0].tensor_id] = 10**9  # caller scribbles on its copy
    p2 = plan_records(recs, cache=cache)
    assert p2.offsets[recs[0].tensor_id] != 10**9


def test_disk_cache_persists_across_instances(tmp_path):
    recs = make_records(RECS)
    c1 = plan_io.PlanCache(tmp_path)
    p1 = plan_records(recs, cache=c1)
    assert not p1.cache_hit
    c2 = plan_io.PlanCache(tmp_path)  # fresh process, same directory
    p2 = plan_records(recs, cache=c2)
    assert p2.cache_hit
    assert _plans_equal(
        dataclasses.replace(p2, cache_hit=False, plan_wall_s=p1.plan_wall_s), p1
    )


def test_disk_cache_write_failure_is_nonfatal(tmp_path):
    """A broken cache dir must not fail the planning call (best-effort
    tier). A path under a regular file fails mkdir even when running as
    root (permission bits would not)."""
    blocker = tmp_path / "blocker"
    blocker.write_text("")
    cache = plan_io.PlanCache(blocker / "sub")
    p = plan_records(make_records(RECS), cache=cache)
    assert not p.cache_hit and p.total_size > 0
    # memory tier still works despite the dead disk tier
    assert plan_records(make_records(RECS), cache=cache).cache_hit


def test_disk_cache_ignores_corrupt_entry(tmp_path):
    recs = make_records(RECS)
    cache = plan_io.PlanCache(tmp_path)
    key = plan_io.plan_signature(recs, mode="offsets", strategy="auto")
    (tmp_path / f"{key}.json").write_text("{not json")
    p = plan_records(recs, cache=cache)
    assert not p.cache_hit  # corrupt entry treated as a miss, then rewritten
    assert plan_records(recs, cache=plan_io.PlanCache(tmp_path)).cache_hit


def _fill_disk_cache(cache, n, start=0):
    """Write n distinct single-record plans; returns their disk paths in
    write (mtime) order, artificially spaced so eviction order is exact."""
    import os as _os

    from repro.core.planner import _cache_strategy_key

    paths = []
    for i in range(start, start + n):
        recs = [TensorUsageRecord(0, i + 1, 64 * (i + 1), tensor_id=i)]
        plan_records(recs, cache=cache)
        key = plan_io.plan_signature(
            recs, mode="offsets", strategy=_cache_strategy_key("offsets", "auto")
        )
        path = cache.cache_dir / f"{key}.json"
        assert path.exists()
        _os.utime(path, (1_000_000 + i, 1_000_000 + i))
        paths.append(path)
    return paths


def test_disk_cache_evicts_oldest_when_over_cap(tmp_path):
    cache = plan_io.PlanCache(tmp_path, max_disk_bytes=1)  # everything over cap
    paths = _fill_disk_cache(cache, 4)
    # each put evicted all OLDER entries; the newest write always survives
    assert not any(p.exists() for p in paths[:-1])
    assert paths[-1].exists()


def test_disk_cache_cap_keeps_newest_entries(tmp_path):
    cache = plan_io.PlanCache(tmp_path)
    probe = _fill_disk_cache(cache, 1)[0]
    per_entry = probe.stat().st_size
    cache.max_disk_bytes = int(per_entry * 2.5)  # room for ~2 entries
    paths = _fill_disk_cache(cache, 3, start=1)
    alive = [p for p in [probe, *paths] if p.exists()]
    total = sum(p.stat().st_size for p in alive)
    assert total <= cache.max_disk_bytes
    assert paths[-1].exists(), "the just-written entry is never evicted"
    assert not probe.exists(), "oldest mtime goes first"


def test_disk_cache_cap_via_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "1")
    cache = plan_io.PlanCache(tmp_path)
    paths = _fill_disk_cache(cache, 3)
    assert sum(p.exists() for p in paths) == 1
    # invalid / non-positive values disable eviction rather than raise
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "not-a-number")
    _fill_disk_cache(plan_io.PlanCache(tmp_path), 3, start=3)
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "0")
    _fill_disk_cache(plan_io.PlanCache(tmp_path), 3, start=6)


def test_disk_cache_eviction_cross_process_safe(tmp_path):
    """Another process evicting an entry must look like a plain miss to a
    cache that still remembers it on disk only — and eviction itself must
    shrug off files vanishing mid-scan."""
    writer = plan_io.PlanCache(tmp_path)
    recs = make_records(RECS)
    plan_records(recs, cache=writer)
    # a second process with a tiny cap floods the dir and evicts our entry
    evictor = plan_io.PlanCache(tmp_path, max_disk_bytes=1)
    _fill_disk_cache(evictor, 2)
    reader = plan_io.PlanCache(tmp_path)  # fresh process, cold memory tier
    p = plan_records(recs, cache=reader)
    assert not p.cache_hit  # evicted -> miss -> re-planned and re-cached
    assert plan_records(recs, cache=reader).cache_hit  # memory tier intact


def test_signature_includes_planner_revision(monkeypatch):
    recs = make_records(RECS)
    base = plan_io.plan_signature(recs, mode="offsets", strategy="auto")
    monkeypatch.setattr(plan_io, "PLANNER_REVISION", plan_io.PLANNER_REVISION + 1)
    assert plan_io.plan_signature(recs, mode="offsets", strategy="auto") != base


def test_auto_key_spells_out_portfolio():
    from repro.core.planner import _cache_strategy_key

    assert _cache_strategy_key("offsets", "greedy_by_size") == "greedy_by_size"
    auto = _cache_strategy_key("offsets", "auto")
    assert auto.startswith("auto[") and "strip_packing_bestfit" in auto
    assert _cache_strategy_key("shared_objects", "auto") != auto


def test_default_cache_env_var_read_late(tmp_path, monkeypatch):
    """REPRO_PLAN_CACHE_DIR set after import must still enable the disk
    tier (the env is re-read per call, not frozen at import time)."""
    from repro.core.planner import _cache_strategy_key

    monkeypatch.setenv("REPRO_PLAN_CACHE_DIR", str(tmp_path))
    recs = [TensorUsageRecord(2, 9, 192, tensor_id=3)]
    plan_records(recs)
    key = plan_io.plan_signature(
        recs, mode="offsets", strategy=_cache_strategy_key("offsets", "auto")
    )
    assert (tmp_path / f"{key}.json").exists()


def test_default_cache_used_by_plan_records():
    recs = [TensorUsageRecord(0, 3, 4096, tensor_id=7),
            TensorUsageRecord(1, 2, 8192, tensor_id=11)]
    before = plan_io.default_cache().stats["hits"]
    plan_records(recs)
    p = plan_records(recs)
    assert p.cache_hit
    assert plan_io.default_cache().stats["hits"] > before
