"""roofline.count_params must track the real parameter counts."""

import jax
import pytest

from repro.configs.base import ARCH_IDS, get_reduced
from repro.launch.roofline import active_params, count_params, model_flops
from repro.models.api import INPUT_SHAPES, Model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_count_params_matches_init(arch):
    cfg = get_reduced(arch)
    model = Model.for_config(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    real = sum(
        int(x.size) for x in jax.tree_util.tree_leaves(shapes)
    )
    est = count_params(cfg)
    # analytic count ignores norm scales / dt biases (tiny): within 2%
    assert est == pytest.approx(real, rel=0.02), (
        f"{arch}: analytic {est} vs real {real}"
    )


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b", "granite-moe-3b-a800m"])
def test_active_params_less_than_total_for_moe(arch):
    cfg = get_reduced(arch)
    assert active_params(cfg) < count_params(cfg)


def test_model_flops_scaling():
    cfg = get_reduced("qwen3-0.6b")
    train = model_flops(cfg, INPUT_SHAPES["train_4k"])
    prefill = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    decode = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert train > prefill > decode > 0
    # train is 3x the forward cost at equal token counts; token counts
    # differ by 8x here (256*4k vs 32*32k equal!) -> train = 3x prefill
    assert train / prefill == pytest.approx(3.0, rel=0.01)