"""SSD chunking math vs the brute-force O(S²) recurrence.

y_t = C_t · ( Σ_{m≤t} exp(Σ_{i=m+1..t} dA_i) · dt_m · B_m ⊗ x_m )  (+ state)

Chaining ssd_chunk_ref across chunks (and the Pallas kernel across chunks)
must match this exactly — validates the within-chunk decay, the
inter-chunk state hand-off, and the model's mamba_prefill scan.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk import ssd_chunk


def brute_force_ssd(x, dt, dA, Bm, Cm, state0):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    ys = []
    state = state0.astype(jnp.float64)
    x64, dt64, dA64 = x.astype(jnp.float64), dt.astype(jnp.float64), dA.astype(jnp.float64)
    B64, C64 = Bm.astype(jnp.float64), Cm.astype(jnp.float64)
    for t in range(S):
        decay = jnp.exp(dA64[:, t])  # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x64[:, t] * dt64[:, t][..., None], B64[:, t]
        )
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, C64[:, t]))
    return jnp.stack(ys, axis=1), state


def _inputs(key, B, S, H, P, N):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    dA = -jnp.exp(jax.random.normal(ks[2], (B, S, H)) * 0.3) * dt
    Bm = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    state = jax.random.normal(ks[5], (B, H, P, N)) * 0.3
    return x, dt, dA, Bm, Cm, state


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chained_ref_matches_brute_force(chunk):
    B, S, H, P, N = 1, 64, 2, 8, 4
    x, dt, dA, Bm, Cm, state = _inputs(jax.random.PRNGKey(0), B, S, H, P, N)
    want_y, want_state = brute_force_ssd(x, dt, dA, Bm, Cm, state)
    ys = []
    st = state
    for c in range(S // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        y, st = ssd_chunk_ref(x[:, sl], dt[:, sl], dA[:, sl], Bm[:, sl], Cm[:, sl], st)
        ys.append(y)
    got_y = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y, np.float32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_state, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_chained_kernel_matches_brute_force():
    B, S, H, P, N = 1, 64, 2, 8, 4
    chunk = 16
    x, dt, dA, Bm, Cm, state = _inputs(jax.random.PRNGKey(1), B, S, H, P, N)
    want_y, want_state = brute_force_ssd(x, dt, dA, Bm, Cm, state)
    ys = []
    st = state
    for c in range(S // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        y, st = ssd_chunk(x[:, sl], dt[:, sl], dA[:, sl], Bm[:, sl], Cm[:, sl],
                          st, interpret=True)
        ys.append(y)
    got_y = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y, np.float32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_state, np.float32),
                               rtol=2e-4, atol=2e-4)