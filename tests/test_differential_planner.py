"""Differential-test oracle harness (the contract of the fast planner).

``repro.core.reference`` froze the seed's naive O(k·n²) implementations;
the production strategies were rebuilt on the shared interval-overlap
engine (``repro.core.interval_set``). The rebuild is a pure data-structure
swap — iteration order and tie-breaking are preserved exactly — so the
check here is the strongest possible one: **identical assignments and
offsets**, not just identical totals, over

* 4 generator families × 55 seeds = 220 randomized record sets, and
* the traced forward graphs of all 10 model configs in
  ``src/repro/configs/``,

plus independent overlap-freedom validation (``repro.core.validate``
re-derives the constraints from first principles) so a shared bug cannot
vouch for itself.
"""

import pytest

from graph_gen import GENERATORS, config_records, generate
from repro.configs.base import ARCH_IDS
from repro.core import baselines, extensions, offsets, reference, shared_objects
from repro.core.validate import check_offsets, check_shared_objects

N_SEEDS = 55  # 4 families x 55 = 220 randomized record sets

FAST_SO = {
    "greedy_by_size": shared_objects.greedy_by_size,
    "greedy_by_size_improved": shared_objects.greedy_by_size_improved,
    "greedy_by_breadth": shared_objects.greedy_by_breadth,
    "greedy_by_conflict": extensions.greedy_by_conflict,
}
FAST_OFF = {
    "greedy_by_size": offsets.greedy_by_size_offsets,
    "greedy_by_breadth": offsets.greedy_by_breadth_offsets,
    "strip_packing_bestfit": baselines.strip_packing_bestfit,
    "tflite_greedy_in_order": baselines.tflite_greedy_in_order_offsets,
}

CASES = [(kind, seed) for kind in sorted(GENERATORS) for seed in range(N_SEEDS)]


def _assert_shared_objects_match(recs, tag):
    for name, fast_fn in FAST_SO.items():
        fast = fast_fn(recs)
        ref = reference.REFERENCE_SHARED_OBJECT_STRATEGIES[name](recs)
        check_shared_objects(recs, fast)
        assert fast.total_size == ref.total_size, (
            f"{tag}/{name}: fast total {fast.total_size} != "
            f"oracle {ref.total_size}"
        )
        assert fast.assignment == ref.assignment, (
            f"{tag}/{name}: fast assignment diverged from oracle"
        )
        assert [o.size for o in fast.objects] == [o.size for o in ref.objects], (
            f"{tag}/{name}: object sizes diverged from oracle"
        )


def _assert_offsets_match(recs, tag):
    for name, fast_fn in FAST_OFF.items():
        fast = fast_fn(recs)
        ref = reference.REFERENCE_OFFSET_STRATEGIES[name](recs)
        check_offsets(recs, fast)
        assert fast.total_size == ref.total_size, (
            f"{tag}/{name}: fast total {fast.total_size} != "
            f"oracle {ref.total_size}"
        )
        assert fast.offsets == ref.offsets, (
            f"{tag}/{name}: fast offsets diverged from oracle"
        )


@pytest.mark.parametrize("kind,seed", CASES)
def test_shared_objects_match_oracle(kind, seed):
    recs = generate(kind, seed)
    _assert_shared_objects_match(recs, f"{kind}[{seed}]")


@pytest.mark.parametrize("kind,seed", CASES)
def test_offsets_match_oracle(kind, seed):
    recs = generate(kind, seed)
    _assert_offsets_match(recs, f"{kind}[{seed}]")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_graphs_match_oracle(arch):
    """Every model config's real traced graph, both modes, all strategies."""
    recs = list(config_records(arch))
    assert len(recs) > 50, f"{arch}: suspiciously small graph ({len(recs)})"
    _assert_shared_objects_match(recs, arch)
    _assert_offsets_match(recs, arch)


@pytest.mark.parametrize(
    "kind,seed", [(k, s) for k in sorted(GENERATORS) for s in range(10)]
)
def test_incremental_planner_single_stage_matches_oracle(kind, seed):
    """dynamic.IncrementalPlanner rode the BestFitArena rewrite; a single
    extend() over all records is by construction Greedy-by-Size offsets,
    so pin it to the frozen oracle (hypothesis-free coverage — the
    property tests for it skip when hypothesis is absent)."""
    from repro.core.dynamic import IncrementalPlanner

    recs = generate(kind, seed)
    inc = IncrementalPlanner()
    inc.extend(recs)
    asn = inc.as_assignment()
    check_offsets(recs, asn)
    ref = reference.greedy_by_size_offsets(recs)
    assert asn.offsets == ref.offsets
    assert asn.total_size == ref.total_size


def test_incremental_planner_staged_overlap_free():
    from repro.core.dynamic import IncrementalPlanner

    for seed in range(20):
        recs = generate("uniform", seed)
        mid = len(recs) // 2
        inc = IncrementalPlanner()
        inc.extend(recs[:mid])
        frozen = dict(inc.offsets)
        inc.extend(recs[mid:])
        check_offsets(recs, inc.as_assignment())
        # stage-0 placements must never move (live buffers can't relocate)
        assert all(inc.offsets[t] == off for t, off in frozen.items())


def test_oracle_is_frozen_seed_behavior():
    """Pin a tiny known instance so oracle regressions are loud: the
    paper's Fig. 2-style example planned by the seed implementation."""
    from repro.core.records import make_records

    fig = make_records(
        [(0, 1, 32), (1, 4, 28), (2, 3, 36), (3, 5, 16),
         (4, 5, 8), (5, 7, 64), (6, 7, 10)]
    )
    assert reference.greedy_by_size(fig).total_size == \
        shared_objects.greedy_by_size(fig).total_size
    assert reference.greedy_by_size_offsets(fig).offsets == \
        offsets.greedy_by_size_offsets(fig).offsets
