"""Plan-bundle artifact tests: serialization, fingerprints, manifest.

The artifact layer is the contract between the offline compiler and every
future serving process, so these tests pin the properties serving relies
on: byte-determinism (content addressing must be stable across
recompiles), version gating (v1 and v2 load through one-warning shims,
newer versions are rejected), the v3 AOT-executable payload (content
addressing, base64 round trip, expected-entry naming), fingerprint
sensitivity (any graph-shaping change re-keys), manifest dedup,
corrupt-index quarantine + rebuild, bucket auto-selection
(``lookup_nearest``) including the one-shot legacy-index upgrade, and
lost-update safety of concurrent ``publish()``.
"""

import dataclasses
import hashlib
import json
import multiprocessing
import warnings
from concurrent.futures import ProcessPoolExecutor

import pytest

from repro.configs.base import get_reduced
from repro.core.artifact import (
    BUNDLE_FORMAT_VERSION,
    BundleManifest,
    ExecutablePack,
    PlanBundle,
    block_entry_name,
    bucket_key,
    executable_entry,
    expected_executable_entries,
    bundle_bucket_key,
    bundle_from_json,
    bundle_from_obj,
    bundle_to_json,
    bundle_to_obj,
    decode_fingerprint,
    graph_fingerprint,
    load_bundle,
    parse_bucket_key,
    resolve_bundle,
    save_bundle,
    unified_from_bundle,
)
from repro.core.graph import GraphBuilder
from repro.core.planner import plan_records
from repro.core.unified import StateRecord, plan_state


def _small_graph(scale: int = 1):
    b = GraphBuilder("tiny")
    x = b.input((4 * scale, 4), "x")
    h = b.op("matmul", [x], (4 * scale, 8))
    g = b.op("gelu", [h], (4 * scale, 8))
    out = b.op("proj", [g, h], (4 * scale, 2))
    b.mark_output(out)
    return b.build()


def _state_plan(n_slots=2, max_len=64):
    return plan_state(
        [
            StateRecord(path="['kv']", shape=(n_slots, 8), dtype="float32",
                        nbytes=n_slots * 8 * 4),
            StateRecord(path="['ssm']", shape=(n_slots, 4), dtype="float32",
                        nbytes=n_slots * 4 * 4),
        ],
        n_slots=n_slots,
        max_len=max_len,
    )


def _bundle(cfg=None, n_slots=2, max_len=64, **overrides) -> PlanBundle:
    cfg = cfg or get_reduced("qwen3-0.6b")
    g = _small_graph()
    plan = plan_records(
        g.usage_records(), graph_name=g.name, use_cache=False
    )
    fields = dict(
        fingerprint=decode_fingerprint(cfg, n_slots=n_slots, max_len=max_len),
        graph_fingerprint=graph_fingerprint(g),
        arch=cfg.name,
        n_slots=n_slots,
        max_len=max_len,
        dtype=cfg.dtype,
        plan=plan,
        state_plan=_state_plan(n_slots=n_slots, max_len=max_len),
        n_layers=cfg.n_layers,
        d_model=cfg.d_model,
        order=[0, 2, 1],
        fusion_groups=[[0], [1, 2]],
        provenance={"tool": "test", "greedy_total_bytes": plan.total_size},
    )
    fields.update(overrides)
    return PlanBundle(**fields)


def test_bundle_json_round_trip():
    b = _bundle()
    b2 = bundle_from_json(bundle_to_json(b))
    assert bundle_to_obj(b2) == bundle_to_obj(b)
    assert b2.order == [0, 2, 1]
    assert b2.fusion_groups == [[0], [1, 2]]
    assert b2.plan.total_size == b.plan.total_size
    assert b2.plan.offsets == b.plan.offsets


def test_bundle_encoding_is_byte_deterministic():
    """Content addressing relies on it: the same compiled plan must encode
    to the same bytes, regardless of planning wall time."""
    b = _bundle()
    slow = dataclasses.replace(b, plan=dataclasses.replace(b.plan, plan_wall_s=1.23))
    assert bundle_to_json(b) == bundle_to_json(slow)
    assert bundle_to_json(b) == bundle_to_json(bundle_from_json(bundle_to_json(b)))


def test_bundle_rejects_unknown_version():
    obj = bundle_to_obj(_bundle())
    obj["format_version"] = BUNDLE_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format version"):
        bundle_from_obj(obj)


def test_bundle_v2_round_trips_unified_plan():
    """Acceptance: a v2 bundle round-trips a UnifiedPlan — activation
    offsets + cross-step state offsets — byte-deterministically."""
    b = _bundle()
    text = bundle_to_json(b)
    b2 = bundle_from_json(text)
    assert bundle_to_json(b2) == text  # byte-deterministic round trip
    up = unified_from_bundle(b2)
    assert up.fingerprint == b.fingerprint
    assert up.activation.offsets == b.plan.offsets
    assert up.state == b.state_plan
    assert up.total_size == b.plan.total_size + b.state_plan.total_size
    assert up.total_size == b.total_size
    assert "unified" in b.summary()


def _pack() -> ExecutablePack:
    return ExecutablePack(
        platform="cpu",
        jax_version="0.0.test",
        entries={
            n: executable_entry(f"payload-{n}".encode())
            for n in expected_executable_entries()
        },
    )


def test_bundle_v3_round_trips_executables():
    """A v3 bundle round-trips its AOT executable pack byte-
    deterministically, with per-entry content addressing intact."""
    b = _bundle(executables=_pack())
    text = bundle_to_json(b)
    b2 = bundle_from_json(text)
    assert bundle_to_json(b2) == text
    pack = b2.executables
    assert pack.platform == "cpu" and pack.jax_version == "0.0.test"
    assert sorted(pack.entries) == expected_executable_entries()
    entry = pack.entries["resident_decode"]
    assert entry.payload == b"payload-resident_decode"
    assert entry.nbytes == len(entry.payload)
    assert entry.sha256 == hashlib.sha256(entry.payload).hexdigest()
    assert pack.nbytes == sum(e.nbytes for e in pack.entries.values())
    assert "AOT executable" in b.summary()


def test_bundle_v2_loads_through_shim_with_warning():
    """v2 documents (plans but no executables) still load — one
    DeprecationWarning, ``executables=None`` — and keep BOTH plan halves,
    so a v3 engine serves them with lazy compile only (the fingerprint
    schema rolled separately; exercised end-to-end in test_aot)."""
    obj = bundle_to_obj(_bundle(executables=_pack()))
    obj["format_version"] = 2
    obj.pop("executables", None)
    with pytest.deprecated_call(match="format v2"):
        b = bundle_from_obj(json.loads(json.dumps(obj)))
    assert b.executables is None
    assert b.state_plan is not None
    assert unified_from_bundle(b).state is not None


def test_expected_executable_entries_cover_block_path():
    assert expected_executable_entries() == [
        "pytree_decode", "pytree_reset", "resident_decode", "resident_reset",
    ]
    assert block_entry_name("resident", 4) == "resident_block_4"
    blk = expected_executable_entries(block_size=4)
    assert set(blk) == set(expected_executable_entries()) | {
        "pytree_block_4", "resident_block_4",
    }
    assert blk == sorted(blk)


def test_bundle_v1_loads_through_shim_with_warning():
    """v1 documents (no state plan, no bucket shape fields) still load —
    one DeprecationWarning, ``state_plan=None`` — and their fingerprints
    hashed format v1, so a v2 engine never serves them (fallback
    semantics preserved; exercised end-to-end in test_serve)."""
    obj = bundle_to_obj(_bundle())
    obj["format_version"] = 1
    for key in ("state_plan", "n_layers", "d_model"):
        del obj[key]
    with pytest.deprecated_call(match="format v1"):
        b = bundle_from_obj(json.loads(json.dumps(obj)))
    assert b.state_plan is None
    assert b.n_layers == 0 and b.d_model == 0
    assert bundle_bucket_key(b) is None  # shape fields unknown
    assert unified_from_bundle(b).state is None


def test_bucket_key_parses_and_rebuilds():
    cfg = get_reduced("qwen3-0.6b")
    key = bucket_key(cfg, n_slots=2, max_len=64)
    parsed = parse_bucket_key(key)
    assert parsed == {
        "arch": cfg.name, "n_layers": cfg.n_layers, "d_model": cfg.d_model,
        "n_slots": 2, "max_len": 64, "dtype": cfg.dtype,
        "page_size": None, "prefill_len": None,
    }
    paged_key = bucket_key(cfg, n_slots=2, max_len=64, page_size=1024)
    assert parse_bucket_key(paged_key)["page_size"] == 1024
    pf_key = bucket_key(cfg, n_slots=2, max_len=64, prefill_len=48)
    assert pf_key.endswith("|pf48")
    assert parse_bucket_key(pf_key)["prefill_len"] == 48
    both = bucket_key(cfg, n_slots=2, max_len=64, page_size=1024,
                      prefill_len=48)
    parsed_both = parse_bucket_key(both)
    assert parsed_both["page_size"] == 1024
    assert parsed_both["prefill_len"] == 48
    assert parse_bucket_key("free-form-key") is None
    assert bundle_bucket_key(_bundle(cfg)) == key


def test_decode_fingerprint_covers_graph_shaping_inputs():
    cfg = get_reduced("qwen3-0.6b")
    fp = decode_fingerprint(cfg, n_slots=2, max_len=64)
    assert fp == decode_fingerprint(cfg, n_slots=2, max_len=64)
    assert fp != decode_fingerprint(cfg, n_slots=4, max_len=64)
    assert fp != decode_fingerprint(cfg, n_slots=2, max_len=128)
    assert fp != decode_fingerprint(
        dataclasses.replace(cfg, d_model=cfg.d_model * 2), n_slots=2, max_len=64
    )
    assert fp != decode_fingerprint(get_reduced("mamba2-2.7b"), n_slots=2, max_len=64)
    # the citation string cannot shape a tensor: configs differing only in
    # `source` share one bundle (the advertised bucket family)
    assert fp == decode_fingerprint(
        dataclasses.replace(cfg, source="elsewhere"), n_slots=2, max_len=64
    )


def test_graph_fingerprint_is_structural():
    g = _small_graph()
    assert graph_fingerprint(g) == graph_fingerprint(_small_graph())
    assert graph_fingerprint(g) != graph_fingerprint(_small_graph(scale=2))


def test_manifest_publish_lookup_and_dedup(tmp_path):
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    key = bucket_key(cfg, n_slots=2, max_len=64)
    b = _bundle(cfg)
    path = man.publish(key, b, command="pytest")
    assert path.exists()
    got = man.lookup(key)
    assert got is not None and bundle_to_obj(got) == bundle_to_obj(b)
    assert man.lookup("no-such-bucket") is None

    # a second bucket with the identical compiled payload shares one file
    other_key = bucket_key(cfg, n_slots=2, max_len=64) + "|alias"
    path2 = man.publish(other_key, b, command="pytest")
    assert path2 == path
    files = [p for p in tmp_path.glob("bundle-*.json")]
    assert len(files) == 1
    entries = man.buckets()
    assert entries[key]["file"] == entries[other_key]["file"]
    assert entries[key]["command"] == "pytest"


def test_manifest_corruption_is_quarantined_and_rebuilt(tmp_path):
    """A truncated/garbage manifest.json must not crash publish(): the
    index is quarantined (.corrupt-<ts>) and rebuilt from the
    bundle-*.json files on disk (v2 bundles carry their bucket shape
    fields for exactly this)."""
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    k64 = bucket_key(cfg, n_slots=2, max_len=64)
    k128 = bucket_key(cfg, n_slots=2, max_len=128)
    b64 = _bundle(cfg, n_slots=2, max_len=64)
    b128 = _bundle(cfg, n_slots=2, max_len=128)
    man.publish(k64, b64, command="pytest")
    man.publish(k128, b128, command="pytest")

    for garbage in ('{"format_version": 1, "buck', "[]", '"not an index"'):
        (tmp_path / "manifest.json").write_text(garbage)
        with pytest.warns(RuntimeWarning, match="rebuilt 2 bucket"):
            buckets = man.buckets()
        assert set(buckets) == {k64, k128}
        assert buckets[k64]["fingerprint"] == b64.fingerprint
    # the corrupt files were quarantined, not deleted
    assert list(tmp_path.glob("manifest.json.corrupt-*"))
    # and a subsequent publish works on the rebuilt index
    k32 = bucket_key(cfg, n_slots=2, max_len=32)
    man.publish(k32, _bundle(cfg, n_slots=2, max_len=32))
    assert set(man.buckets()) == {k32, k64, k128}
    # lookups round-trip through the rebuilt index
    got = man.lookup(k128)
    assert bundle_to_obj(got) == bundle_to_obj(b128)


def test_manifest_rejects_newer_index_version(tmp_path):
    (tmp_path / "manifest.json").write_text(
        json.dumps({"format_version": 99, "buckets": {}})
    )
    with pytest.raises(ValueError, match="format version"):
        BundleManifest(tmp_path).buckets()


def test_lookup_nearest_picks_smallest_admissible_max_len(tmp_path):
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    for max_len in (64, 128, 256):
        man.publish(
            bucket_key(cfg, n_slots=2, max_len=max_len),
            _bundle(cfg, n_slots=2, max_len=max_len),
        )
    # exact hit wins
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=128)
    assert b.max_len == 128 and key.endswith("len128|" + cfg.dtype)
    # no exact bucket: nearest compiled max_len >= requested
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=96)
    assert b.max_len == 128
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=32)
    assert b.max_len == 64
    # nothing admissible: longer than every compiled bucket
    assert man.lookup_nearest(cfg, n_slots=2, max_len=512) is None
    # slot pools SMALLER than requested are never admissible (a request
    # needs at least its slot count; only bigger pools substitute)
    assert man.lookup_nearest(cfg, n_slots=4, max_len=64) is None
    # dtype must match exactly
    other = dataclasses.replace(cfg, dtype="bfloat16")
    assert man.lookup_nearest(other, n_slots=2, max_len=64) is None


def test_lookup_nearest_admits_bigger_slot_pools(tmp_path):
    """Satellite: slots are the §4 shared objects — a bigger compiled pool
    is admissible (just wasteful), so a fleet swept at slots=4 serves a
    slots=2 request. Tie-break is footprint-aware: the smallest
    unified_total among admissible buckets wins."""
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    for n_slots in (4, 8):
        man.publish(
            bucket_key(cfg, n_slots=n_slots, max_len=64),
            _bundle(cfg, n_slots=n_slots, max_len=64),
        )
    # no slots=2 bucket compiled: the smallest admissible pool serves
    # (slots=4 has the smaller state plan, hence the smaller unified total)
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=64)
    assert b.n_slots == 4
    assert "slots4" in key
    # exact bucket still wins outright when it exists
    man.publish(
        bucket_key(cfg, n_slots=2, max_len=64),
        _bundle(cfg, n_slots=2, max_len=64),
    )
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=64)
    assert b.n_slots == 2
    # both dimensions substitute together: slots=3/len=96 is served by the
    # smallest-footprint bucket covering both
    key, b = man.lookup_nearest(cfg, n_slots=3, max_len=63)
    assert b.n_slots == 4 and b.max_len == 64


def test_lookup_nearest_tie_breaks_on_unified_total(tmp_path):
    """Between admissible buckets the SMALLEST unified footprint wins,
    even when a longer max_len bucket happens to be leaner than a wider
    slot pool."""
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    lean = _bundle(cfg, n_slots=2, max_len=128)
    fat = _bundle(cfg, n_slots=8, max_len=64)
    assert lean.total_size < fat.total_size
    man.publish(bucket_key(cfg, n_slots=2, max_len=128), lean)
    man.publish(bucket_key(cfg, n_slots=8, max_len=64), fat)
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=64)
    assert b.n_slots == 2 and b.max_len == 128, key
    # entries published at this revision carry the unified total, so
    # selection ranks them without loading bundle files; older entries
    # fall back to one memoized load per manifest handle
    assert man.buckets()[key]["unified_total"] == lean.total_size
    # an entry whose bundle file is unreadable must LOSE the ranking,
    # not win it with a zero footprint
    bad_key = bucket_key(cfg, n_slots=4, max_len=64)
    index = json.loads((tmp_path / "manifest.json").read_text())
    index["buckets"][bad_key] = {"file": "bundle-missing.json",
                                 "fingerprint": "x"}
    (tmp_path / "manifest.json").write_text(json.dumps(index))
    key, b = man.lookup_nearest(cfg, n_slots=2, max_len=64)
    assert b.n_slots == 2 and b.max_len == 128, key


def test_lookup_nearest_upgrades_legacy_index_once(tmp_path, monkeypatch):
    """Satellite: a pre-``unified_total`` manifest is upgraded ONCE — the
    first nearest lookup loads each legacy bundle, stamps its unified
    footprint into the index, and persists it, so later handles rank
    admissible buckets without re-reading any bundle file."""
    from repro.core import artifact

    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    for max_len in (64, 128):
        man.publish(
            bucket_key(cfg, n_slots=2, max_len=max_len),
            _bundle(cfg, n_slots=2, max_len=max_len),
        )
    index = json.loads((tmp_path / "manifest.json").read_text())
    for entry in index["buckets"].values():
        del entry["unified_total"]
    (tmp_path / "manifest.json").write_text(json.dumps(index))

    key, b = BundleManifest(tmp_path).lookup_nearest(
        cfg, n_slots=2, max_len=96
    )
    assert b.max_len == 128
    ondisk = json.loads((tmp_path / "manifest.json").read_text())
    assert all(
        isinstance(e["unified_total"], int)
        for e in ondisk["buckets"].values()
    )

    loads = []
    real = artifact.load_bundle
    monkeypatch.setattr(
        artifact, "load_bundle", lambda p: (loads.append(p), real(p))[1]
    )
    key, b = BundleManifest(tmp_path).lookup_nearest(
        cfg, n_slots=2, max_len=96
    )
    assert b.max_len == 128
    # only the selected winner is read — ranking came from the index
    assert len(loads) == 1


def test_resolve_bundle_miss_lists_compiled_buckets(tmp_path):
    """Satellite: a manifest miss is a readable message naming the buckets
    that DO exist, not a silent fallback one-liner."""
    cfg = get_reduced("qwen3-0.6b")
    man = BundleManifest(tmp_path)
    k64 = bucket_key(cfg, n_slots=2, max_len=64)
    man.publish(k64, _bundle(cfg, n_slots=2, max_len=64))
    with pytest.raises(FileNotFoundError) as exc:
        resolve_bundle(tmp_path, cfg, n_slots=8, max_len=64)
    assert k64 in str(exc.value)
    assert "compiled buckets" in str(exc.value)
    # nearest mode: same readable miss when nothing is admissible
    with pytest.raises(FileNotFoundError) as exc:
        resolve_bundle(tmp_path, cfg, n_slots=2, max_len=512, nearest=True)
    assert k64 in str(exc.value)
    # empty manifests say so
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError, match="manifest is empty"):
        resolve_bundle(empty, cfg, n_slots=2, max_len=64)


def _publish_one(args):
    """Worker for the concurrent-publish test (module-level: picklable)."""
    directory, max_len = args
    cfg = get_reduced("qwen3-0.6b")
    bundle = _bundle(cfg, n_slots=2, max_len=max_len)
    BundleManifest(directory).publish(
        bucket_key(cfg, n_slots=2, max_len=max_len), bundle, command="worker"
    )
    return max_len


def test_concurrent_publish_keeps_every_bucket(tmp_path):
    """Satellite: N processes publishing distinct buckets into ONE
    manifest (the flock'd read-modify-write) must not drop each other's
    entries — the fleet-sweep failure mode the lock exists for."""
    cfg = get_reduced("qwen3-0.6b")
    max_lens = [32, 48, 64, 96, 128, 192, 256, 384]
    # spawn, not fork: the test session has imported jax, whose thread
    # pools make forked children deadlock-prone
    with ProcessPoolExecutor(
        max_workers=4, mp_context=multiprocessing.get_context("spawn")
    ) as pool:
        done = list(pool.map(_publish_one, [(str(tmp_path), m) for m in max_lens]))
    assert sorted(done) == max_lens
    buckets = BundleManifest(tmp_path).buckets()
    expected = {bucket_key(cfg, n_slots=2, max_len=m) for m in max_lens}
    assert expected <= set(buckets)
    for key in expected:
        entry = buckets[key]
        assert (tmp_path / entry["file"]).exists()
        assert entry["command"] == "worker"


def test_resolve_bundle_accepts_bundle_file_and_dir(tmp_path):
    cfg = get_reduced("qwen3-0.6b")
    b = _bundle(cfg)
    # passthrough
    assert resolve_bundle(b, cfg, n_slots=2, max_len=64) is b
    # single file
    f = tmp_path / "one.json"
    save_bundle(b, f)
    assert bundle_to_obj(load_bundle(f)) == bundle_to_obj(b)
    got = resolve_bundle(f, cfg, n_slots=2, max_len=64)
    assert bundle_to_obj(got) == bundle_to_obj(b)
    # manifest dir
    man_dir = tmp_path / "bundles"
    BundleManifest(man_dir).publish(
        bucket_key(cfg, n_slots=2, max_len=64), b
    )
    got = resolve_bundle(man_dir, cfg, n_slots=2, max_len=64)
    assert bundle_to_obj(got) == bundle_to_obj(b)
    # missing bucket (different serving shape) -> explicit error
    with pytest.raises(FileNotFoundError, match="no bundle"):
        resolve_bundle(man_dir, cfg, n_slots=8, max_len=64)
